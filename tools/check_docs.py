"""Docs checker: code fences in ``docs/*.md`` must RUN, links must resolve.

Two checks, used by the CI ``docs`` job and (in ``--no-exec`` form) by the
tier-1 test ``tests/test_docs.py``:

1. **Fences** — every ```` ```python ```` fence in ``docs/*.md`` that
   contains an ``import`` is executed with ``PYTHONPATH=src`` in a fresh
   interpreter; a non-zero exit fails the check.  Fences whose info string
   contains ``noexec`` (e.g. ```` ```python noexec ````) are only
   syntax-checked — use that for illustrative fragments with free
   variables.  README fences are syntax-checked only (they are quick-start
   fragments by design).
2. **Links** — every relative markdown link ``[...](path)`` in
   ``README.md`` and ``docs/*.md`` must point at an existing file or
   directory (anchors are stripped; ``http(s)://``, ``mailto:`` and
   pure-anchor links are ignored).

Usage:
    python tools/check_docs.py [--no-exec] [--verbose]
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOCS = ROOT / "docs"
FENCE_RE = re.compile(r"^```(\S*)[ \t]*(.*)$")
LINK_RE = re.compile(r"\[[^\]\[]*\]\(([^)\s]+)\)")


def extract_fences(md_path: Path) -> list[tuple[int, str, str]]:
    """Return (first_line_no, info_string, code) per fenced block."""
    fences = []
    lines = md_path.read_text().splitlines()
    i = 0
    while i < len(lines):
        m = FENCE_RE.match(lines[i])
        if m and lines[i].startswith("```") and lines[i].strip() != "```":
            info = (m.group(1) + " " + m.group(2)).strip()
            body, start = [], i + 1
            i += 1
            while i < len(lines) and lines[i].strip() != "```":
                body.append(lines[i])
                i += 1
            fences.append((start + 1, info, "\n".join(body)))
        i += 1
    return fences


def _is_python(info: str) -> bool:
    return info.split()[0] in ("python", "py") if info else False


def _should_exec(info: str, code: str) -> bool:
    return ("noexec" not in info.split()
            and re.search(r"^(import|from)\s+\w", code, re.M) is not None)


def check_fences(*, run: bool = True, verbose: bool = False) -> list[str]:
    errors = []
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(ROOT / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    targets = sorted(DOCS.glob("*.md")) + [ROOT / "README.md"]
    for md in targets:
        exec_ok = run and md.parent == DOCS     # README: syntax-check only
        for line, info, code in extract_fences(md):
            if not _is_python(info):
                continue
            rel = md.relative_to(ROOT)
            try:
                compile(code, f"{rel}:{line}", "exec")
            except SyntaxError as e:
                errors.append(f"{rel}:{line}: fence does not parse: {e}")
                continue
            if not (exec_ok and _should_exec(info, code)):
                continue
            if verbose:
                print(f"running {rel}:{line} ...", flush=True)
            with tempfile.NamedTemporaryFile(
                    "w", suffix=".py", delete=False) as f:
                f.write(code)
                tmp = f.name
            try:
                r = subprocess.run([sys.executable, tmp], env=env,
                                   capture_output=True, text=True,
                                   timeout=900, cwd=ROOT)
                if r.returncode != 0:
                    tail = (r.stderr or r.stdout).strip().splitlines()[-8:]
                    errors.append(f"{rel}:{line}: fence FAILED "
                                  f"(exit {r.returncode}):\n  "
                                  + "\n  ".join(tail))
                elif verbose:
                    print(f"  ok ({rel}:{line})")
            finally:
                os.unlink(tmp)
    return errors


def check_links(verbose: bool = False) -> list[str]:
    errors = []
    for md in [ROOT / "README.md"] + sorted(DOCS.glob("*.md")):
        for m in LINK_RE.finditer(md.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#")[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if ROOT not in resolved.parents and resolved != ROOT:
                # Relative links that escape the repo are site-relative on
                # GitHub (the CI badge's ../../actions/...) — nothing in the
                # tree to verify them against, so they are skipped, not
                # failed.
                continue
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
            elif verbose:
                print(f"link ok: {md.name} -> {target}")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--no-exec", action="store_true",
                    help="syntax-check fences instead of executing them")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    errors = check_links(verbose=args.verbose)
    errors += check_fences(run=not args.no_exec, verbose=args.verbose)
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    if not errors:
        mode = "syntax-checked" if args.no_exec else "executed"
        print(f"docs OK (links resolved, fences {mode})")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
