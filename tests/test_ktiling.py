"""K-tiled digit-serial kernel: streaming correctness + chunk-aware early
termination soundness (the bound must cover unseen K chunks as well as unseen
digit planes), automatic block-size selection, bf16 weights, batched entry.

The kernel consumes the quantized activations (M, K) directly and derives
digit planes in-kernel; the oracle (``dslot_matmul_ref``) still evaluates
over an explicitly materialized ``make_planes`` tensor — agreement between
the two is what pins the fused encoding."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.dslot_matmul import (dslot_matmul_pallas,
                                        dslot_matmul_pallas_batched,
                                        select_block_k)
from repro.kernels.ops import dslot_matmul, dslot_prepare
from repro.kernels.ref import dslot_matmul_ref, make_planes


def _dyadic_w(rng, K, N, denom=128, lo=-64, hi=65):
    """Weights on the 2^-7 grid: every partial product and sum is exactly
    representable in f32 (well under 2^24 ulps), so ANY accumulation order —
    whole-K, chunked, reference — produces bit-identical results."""
    return jnp.asarray(rng.integers(lo, hi, size=(K, N)) / denom, jnp.float32)


@pytest.mark.parametrize("block_k", [None, 96, 48, 32, 16, 40])
def test_bitexact_across_block_k_sweep(block_k):
    rng = np.random.default_rng(0)
    aq = jnp.asarray(rng.integers(0, 256, (64, 96)), jnp.int32)
    w = _dyadic_w(rng, 96, 64)
    ref = dslot_matmul_ref(make_planes(aq, 8), w, 8, relu=True)
    out = dslot_matmul_pallas(aq, w, n_bits=8, relu=True,
                              block_m=32, block_n=32, block_k=block_k)
    np.testing.assert_array_equal(np.asarray(out.out), np.asarray(ref))


@pytest.mark.parametrize("n_planes", [2, 4, 8])
def test_bitexact_truncated_planes_tiled(n_planes):
    """Static-precision truncation interacts with the chunk-aware bound via
    the 2^(n_bits - D) term — must stay exact for every D."""
    rng = np.random.default_rng(n_planes)
    aq = jnp.asarray(rng.integers(-255, 256, (32, 64)), jnp.int32)
    w = _dyadic_w(rng, 64, 32)
    ref = dslot_matmul_ref(make_planes(aq, 8, n_planes=n_planes), w, 8,
                           relu=True)
    out = dslot_matmul_pallas(aq, w, n_bits=8, n_planes=n_planes, relu=True,
                              block_m=16, block_n=16, block_k=16)
    np.testing.assert_array_equal(np.asarray(out.out), np.asarray(ref))


def test_negative_first_chunk_positive_overall_must_not_terminate():
    """Adversarial: the first K chunk drives every accumulator strongly
    negative, later chunks recover to a positive SOP.  A bound unaware of the
    unseen K chunks would kill the tile after chunk 0; the chunk-aware bound
    must keep it alive and the result exact."""
    rng = np.random.default_rng(42)
    M, K, N, bk = 16, 32, 16, 16
    aq = jnp.asarray(rng.integers(64, 256, (M, K)), jnp.int32)   # positive
    w = np.empty((K, N), np.float32)
    w[:bk] = -64 / 128.0      # chunk 0: uniformly negative columns
    w[bk:] = 80 / 128.0       # chunk 1: stronger positive columns
    w = jnp.asarray(w)
    ref = dslot_matmul_ref(make_planes(aq, 8), w, 8, relu=True)
    assert float(jnp.min(ref)) > 0.0, "workload must be positive overall"
    out = dslot_matmul_pallas(aq, w, n_bits=8, relu=True,
                              block_m=16, block_n=16, block_k=bk)
    # termination never fired (output positive everywhere) and all planes ran
    np.testing.assert_array_equal(np.asarray(out.out), np.asarray(ref))
    assert (np.asarray(out.planes_used) == 8).all()


def test_tiled_planes_used_only_leq_untiled():
    """Tiling adds intermediate bound checks whose bound coincides with the
    untiled one at each plane's last chunk — so a tiled run may terminate a
    tile EARLIER (mid-plane) but never later, and never changes the output."""
    rng = np.random.default_rng(7)
    aq = jnp.asarray(rng.integers(0, 256, (64, 96)), jnp.int32)
    w = rng.normal(0, 0.04, (96, 64)).astype(np.float32)
    w[:, :32] -= 0.08                       # clustered dead columns
    ref = dslot_matmul_ref(make_planes(aq, 8), jnp.asarray(w), 8, relu=True)
    untiled = dslot_matmul_pallas(aq, jnp.asarray(w), n_bits=8,
                                  relu=True, block_m=32, block_n=32,
                                  block_k=96)
    assert np.asarray(untiled.planes_used).min() < 8, \
        "workload must actually terminate somewhere"
    for bk in (48, 32, 16):
        tiled = dslot_matmul_pallas(aq, jnp.asarray(w), n_bits=8,
                                    relu=True, block_m=32, block_n=32,
                                    block_k=bk)
        np.testing.assert_allclose(np.asarray(tiled.out), np.asarray(ref),
                                   atol=1e-2)
        assert (np.asarray(tiled.planes_used)
                <= np.asarray(untiled.planes_used)).all(), bk


def test_terminated_tiles_are_zero_and_sound():
    rng = np.random.default_rng(3)
    aq = jnp.asarray(rng.integers(0, 256, (64, 64)), jnp.int32)
    w = rng.normal(0, 0.04, (64, 64)).astype(np.float32)
    w[:, :32] -= 0.08
    ref = np.asarray(dslot_matmul_ref(make_planes(aq, 8), jnp.asarray(w), 8,
                                      relu=True))
    out = dslot_matmul_pallas(aq, jnp.asarray(w), n_bits=8, relu=True,
                              block_m=32, block_n=32, block_k=16)
    pu = np.asarray(out.planes_used)
    assert pu.min() < 8
    for i in range(pu.shape[0]):
        for j in range(pu.shape[1]):
            if pu[i, j] < 8:
                tile = ref[i * 32:(i + 1) * 32, j * 32:(j + 1) * 32]
                assert (tile == 0).all(), (i, j)


def test_k_not_multiple_of_block_k_pads():
    rng = np.random.default_rng(5)
    aq = jnp.asarray(rng.integers(0, 256, (32, 72)), jnp.int32)  # 72 % 32 != 0
    w = _dyadic_w(rng, 72, 32)
    ref = dslot_matmul_ref(make_planes(aq, 8), w, 8, relu=True)
    out = dslot_matmul_pallas(aq, w, n_bits=8, relu=True,
                              block_m=16, block_n=16, block_k=32)
    np.testing.assert_array_equal(np.asarray(out.out), np.asarray(ref))


def test_bf16_weights_tiled():
    rng = np.random.default_rng(11)
    aq = jnp.asarray(rng.integers(0, 256, (32, 64)), jnp.int32)
    # 2^-7-grid values with tiny integer numerators are exact in bf16 too
    w32 = _dyadic_w(rng, 64, 32)
    wb = w32.astype(jnp.bfloat16)
    assert (np.asarray(wb.astype(jnp.float32)) == np.asarray(w32)).all()
    ref = dslot_matmul_ref(make_planes(aq, 8), w32, 8, relu=True)
    out = dslot_matmul_pallas(aq, wb, n_bits=8, relu=True,
                              block_m=16, block_n=16, block_k=16)
    np.testing.assert_array_equal(np.asarray(out.out), np.asarray(ref))


def test_narrow_q_dtypes_match_int32():
    """The execute path stores q at the narrowest width that holds the
    range; the kernel widens in VMEM — the dtype must never change digits."""
    rng = np.random.default_rng(21)
    a = rng.integers(-127, 128, (32, 32))
    w = _dyadic_w(rng, 32, 32)
    base = dslot_matmul_pallas(jnp.asarray(a, jnp.int32), w, n_bits=8,
                               block_m=16, block_n=16, block_k=16)
    for dt in (jnp.int8, jnp.int16):
        out = dslot_matmul_pallas(jnp.asarray(a, dt), w, n_bits=8,
                                  block_m=16, block_n=16, block_k=16)
        np.testing.assert_array_equal(np.asarray(out.out),
                                      np.asarray(base.out))


def test_batched_entry_matches_per_sample():
    rng = np.random.default_rng(13)
    w = _dyadic_w(rng, 48, 32)
    batch_q = jnp.asarray(rng.integers(0, 256, (3, 32, 48)), jnp.int32)
    out = dslot_matmul_pallas_batched(batch_q, w, n_bits=8, relu=True,
                                      block_m=16, block_n=16, block_k=16)
    assert out.out.shape == (3, 32, 32)
    assert out.planes_used.shape == (3, 2, 2)
    for b in range(3):
        single = dslot_matmul_pallas(batch_q[b], w, n_bits=8, relu=True,
                                     block_m=16, block_n=16, block_k=16)
        np.testing.assert_array_equal(np.asarray(out.out[b]),
                                      np.asarray(single.out))
        np.testing.assert_array_equal(np.asarray(out.planes_used[b]),
                                      np.asarray(single.planes_used))


def test_batched_entry_runtime_precision_and_prepared_tables():
    """The batched entry forwards runtime precision, per-request budgets and
    the PREPARED |W| colsum tables — results identical to per-sample calls
    that pass the same (so batched serving callers never recompute
    colsums)."""
    rng = np.random.default_rng(19)
    B, M, K, N, bk = 3, 32, 48, 32, 16
    w = _dyadic_w(rng, K, N)
    batch_q = jnp.asarray(rng.integers(-255, 256, (B, M, K)), jnp.int32)
    prep = dslot_prepare(np.asarray(w), block_m=16, block_n=16, block_k=bk,
                         backend="pallas")
    budgets = jnp.asarray([3, 8, 5], jnp.int32)                  # per request
    npl = jnp.max(budgets)
    out = dslot_matmul_pallas_batched(
        batch_q, prep.w, n_bits=8, relu=True, block_m=16, block_n=16,
        block_k=bk, n_planes_rt=npl, row_budget=budgets,
        suffix_colsum=prep.suffix_colsum, total_colsum=prep.total_colsum)
    for b in range(B):
        single = dslot_matmul_pallas(
            batch_q[b], prep.w, n_bits=8, relu=True, block_m=16, block_n=16,
            block_k=bk, n_planes_rt=npl,
            row_budget=jnp.full((M,), budgets[b], jnp.int32),
            suffix_colsum=prep.suffix_colsum, total_colsum=prep.total_colsum)
        np.testing.assert_array_equal(np.asarray(out.out[b]),
                                      np.asarray(single.out))
        np.testing.assert_array_equal(np.asarray(out.planes_used[b]),
                                      np.asarray(single.planes_used))
    # a (B, M) per-row budget matrix is accepted too and matches the (B,) one
    out2 = dslot_matmul_pallas_batched(
        batch_q, prep.w, n_bits=8, relu=True, block_m=16, block_n=16,
        block_k=bk, n_planes_rt=npl,
        row_budget=jnp.broadcast_to(budgets[:, None], (B, M)),
        suffix_colsum=prep.suffix_colsum, total_colsum=prep.total_colsum)
    np.testing.assert_array_equal(np.asarray(out.out), np.asarray(out2.out))


def test_select_block_k_respects_budget():
    # whole K fits comfortably -> untiled fast path
    assert select_block_k(256, 128, 128, 4) == 256
    # constrained budget -> lane-aligned chunk strictly below K
    bk = select_block_k(65536, 128, 128, 4, budget=2 * 1024 * 1024)
    assert bk < 65536 and bk % 128 == 0 and bk >= 128
    fixed = 2 * 128 * 128 * 4 + 2 * 128 * 4
    assert fixed + bk * (128 + 128 * 4) <= 2 * 1024 * 1024
    # a wider activation dtype shrinks the chunk (working set now counts the
    # quantized block at its storage width, not an int8 plane)
    bk16 = select_block_k(65536, 128, 128, 4, act_itemsize=2,
                          budget=2 * 1024 * 1024)
    assert bk16 <= bk
    # an output tile that alone blows the budget is a hard error
    with pytest.raises(ValueError):
        select_block_k(1024, 1024, 1024, 4, budget=1024 * 1024)


def test_explicit_block_k_over_budget_raises():
    q = jnp.ones((128, 65536), jnp.int32)
    w = jnp.ones((65536, 128), jnp.float32)
    with pytest.raises(ValueError, match="VMEM budget"):
        dslot_matmul_pallas(q, w, block_m=128, block_n=128,
                            block_k=65536)


def test_ops_backends_agree_under_tiling():
    rng = np.random.default_rng(17)
    x = jnp.asarray(np.maximum(rng.normal(0.2, 0.5, (64, 48)), 0),
                    jnp.float32)
    w = rng.normal(0, 0.04, (48, 64)).astype(np.float32)
    w[:, :32] -= 0.08
    for bk in (None, 16, 24):
        o1, s1 = dslot_matmul(x, jnp.asarray(w), backend="jnp",
                              block_m=32, block_n=32, block_k=bk)
        o2, s2 = dslot_matmul(x, jnp.asarray(w), backend="pallas",
                              block_m=32, block_n=32, block_k=bk)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
        np.testing.assert_array_equal(np.asarray(s1.planes_used),
                                      np.asarray(s2.planes_used))
