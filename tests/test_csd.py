"""CSD/Booth nonzero-digit enumeration prototype (``core.csd``).

Pins the recoding's value-exactness over the full quantization range at
every width, the canonical-form properties (digits in {-1,0,+1}, no two
adjacent nonzeros, minimal weight vs binary), and the integer-domain
matmul equality against both a plain ``q @ w`` and the MSDF plane oracle
``kernels.ref.csd_matmul_ref`` — the bit-exactness contract the
``bench_kernel.py --msr-profile`` head-to-head gates on.
"""

import jax.numpy as jnp
import numpy as np

from repro.core.csd import (binary_digit_count, csd_matmul,
                            csd_planes_nonzero, csd_recode,
                            essential_digit_count)
from repro.kernels.ref import csd_matmul_ref, make_planes

from _hyp import given, settings, st


def _reconstruct(planes, n_bits):
    scales = 2 ** (n_bits - np.arange(n_bits + 1))
    return (np.asarray(planes, np.int64) * scales.reshape(
        (-1,) + (1,) * (planes.ndim - 1))).sum(axis=0)


def test_csd_exact_full_range_every_width():
    for n_bits in range(2, 9):
        q = jnp.arange(-(2 ** n_bits - 1), 2 ** n_bits, dtype=jnp.int32)
        planes = csd_recode(q, n_bits)
        assert planes.shape == (n_bits + 1, q.shape[0])
        np.testing.assert_array_equal(_reconstruct(planes, n_bits),
                                      np.asarray(q))
        p = np.asarray(planes)
        assert set(np.unique(p)) <= {-1, 0, 1}
        nz = p != 0
        assert not (nz[1:] & nz[:-1]).any(), f"adjacent nonzeros @ {n_bits}"


@settings(max_examples=16, deadline=None)
@given(n_bits=st.integers(2, 8), seed=st.integers(0, 2**16))
def test_csd_minimal_weight_vs_binary(n_bits, seed):
    """CSD is the minimal-weight signed-digit form: never more nonzero
    digits than plain binary, strictly fewer in expectation."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(-(2 ** n_bits - 1), 2 ** n_bits,
                                 size=(64,)), jnp.int32)
    planes = csd_recode(q, n_bits)
    assert int(essential_digit_count(planes)) <= \
        int(binary_digit_count(q, n_bits))


def test_csd_matmul_integer_exact():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.integers(-255, 256, size=(16, 24)), jnp.int32)
    w_q = jnp.asarray(rng.integers(-127, 128, size=(24, 8)), jnp.int32)
    out, nz_planes = csd_matmul(q, w_q, 8)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(q) @ np.asarray(w_q))
    assert 0 < int(nz_planes) <= 9


def test_csd_matmul_ref_matches_integer_product():
    """The kernels-side oracle (f32 MSDF plane evaluation) is exact on
    integer-valued weights and agrees with the core integer path."""
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.integers(0, 256, size=(8, 16)), jnp.int32)
    w_q = rng.integers(-15, 16, size=(16, 6))
    planes = csd_recode(q, 8)
    y_ref = csd_matmul_ref(planes, jnp.asarray(w_q, jnp.float32), 8)
    y_int, _ = csd_matmul(q, jnp.asarray(w_q, jnp.int32), 8)
    np.testing.assert_array_equal(np.asarray(y_ref),
                                  np.asarray(y_int).astype(np.float32))


def test_csd_sparser_than_dense_planes():
    """Work accounting on a realistic activation profile: essential CSD
    digits < nonzero binary digits < dense digit slots the plane scan
    issues; all-zero inputs need zero planes."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(np.clip(np.round(np.abs(rng.normal(
        size=(32, 32))) * 40), 0, 255), jnp.int32)
    csd = csd_recode(q, 8)
    dense = make_planes(q, 8)
    essential = int(essential_digit_count(csd))
    binary = int(essential_digit_count(dense))
    assert essential <= binary < 8 * q.size
    assert int(binary_digit_count(q, 8)) == binary
    assert int(csd_planes_nonzero(csd_recode(jnp.zeros((4, 4),
                                             jnp.int32), 8))) == 0
