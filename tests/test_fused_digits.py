"""Fused in-kernel MSDF digit encoding: property pinning against the
materializing reference encoder, and per-row budget-vector semantics.

Two contracts from the fusion PR:

* ``sd_digit_plane`` (the arithmetic the kernels inline: shift/mask/sign on
  the quantized value) must reproduce ``ref.make_planes`` digit-for-digit
  over the FULL representable integer range at every ``n_bits`` and every
  truncation depth — the encoder was deleted from the hot path, so this
  equivalence is the only thing keeping the kernels honest.
* the per-row budget vector (SMEM in the Pallas kernel, in-scan mask in the
  jnp replay) must be indistinguishable from the pre-fusion semantics of
  zero-masking each row's digit planes outside the kernel — outputs AND
  ``row_planes_used``.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops
from repro.kernels.dslot_matmul import dslot_matmul_pallas, q_storage_dtype
from repro.kernels.ops import dslot_execute, dslot_prepare
from repro.kernels.ref import dslot_matmul_ref, make_planes, sd_digit_plane

from _hyp import given, settings, st  # hypothesis or skip-shim


# ------------------------------------------------ digit-extraction pinning

@settings(max_examples=40, deadline=None)
@given(n_bits=st.integers(1, 8), n_planes=st.integers(1, 8))
def test_digit_plane_bitexact_full_range(n_bits, n_planes):
    """Every representable value, every plane, every width: the arithmetic
    extraction equals the materializing encoder digit-for-digit."""
    n_planes = min(n_planes, n_bits)
    q = jnp.arange(-(2 ** n_bits - 1), 2 ** n_bits, dtype=jnp.int32)
    planes = make_planes(q, n_bits, n_planes=n_planes)
    fused = jnp.stack([sd_digit_plane(q, n_bits, d)
                       for d in range(n_planes)])
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(planes))


@pytest.mark.parametrize("n_bits", list(range(1, 9)))
def test_digit_plane_bitexact_full_range_deterministic(n_bits):
    """Deterministic version of the property above (runs without
    hypothesis): all values, all truncation depths, at each width."""
    q = jnp.arange(-(2 ** n_bits - 1), 2 ** n_bits, dtype=jnp.int32)
    for n_planes in range(1, n_bits + 1):
        planes = make_planes(q, n_bits, n_planes=n_planes)
        fused = jnp.stack([sd_digit_plane(q, n_bits, d)
                           for d in range(n_planes)])
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(planes))


def test_digit_plane_traced_index():
    """``d`` may be a traced scalar (the kernels derive it from the grid /
    scan step) — same digits as the python-int path."""
    q = jnp.arange(-255, 256, dtype=jnp.int32)
    for d in range(8):
        np.testing.assert_array_equal(
            np.asarray(sd_digit_plane(q, 8, jnp.asarray(d, jnp.int32))),
            np.asarray(sd_digit_plane(q, 8, d)))


@pytest.mark.parametrize("n_bits,signed,expect", [
    (8, False, jnp.uint8), (8, True, jnp.int8),
    (7, False, jnp.uint8), (16, False, jnp.uint16), (12, True, jnp.int16),
])
def test_q_storage_dtype_holds_range(n_bits, signed, expect):
    dt = q_storage_dtype(n_bits, signed)
    assert dt == jnp.dtype(expect)
    qmax = 2 ** (n_bits - 1) - 1 if signed else 2 ** n_bits - 1
    assert qmax <= jnp.iinfo(dt).max
    if signed:
        assert -qmax >= jnp.iinfo(dt).min


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), n_bits=st.integers(2, 8))
def test_kernel_encoding_matches_materialized_oracle(seed, n_bits):
    """The Pallas kernel's in-kernel extraction against the oracle that
    consumes an explicitly materialized plane tensor, signed values
    included."""
    rng = np.random.default_rng(seed)
    lim = 2 ** n_bits - 1
    aq = jnp.asarray(rng.integers(-lim, lim + 1, (16, 16)), jnp.int32)
    w = jnp.asarray(rng.integers(-64, 65, (16, 16)) / 128.0, jnp.float32)
    out = dslot_matmul_pallas(aq, w, n_bits=n_bits, relu=True,
                              block_m=16, block_n=16, block_k=16)
    ref = dslot_matmul_ref(make_planes(aq, n_bits), w, n_bits, relu=True)
    np.testing.assert_array_equal(np.asarray(out.out), np.asarray(ref))


# ------------------------------------- per-row budgets == zero-masked planes

def _zero_masked_reference(x, w, prep, budget):
    """The PRE-FUSION per-row semantics, reproduced outside the kernels:
    quantize, materialize ALL digit planes, zero each row's planes beyond
    its budget, evaluate the plane sum (f32, MSDF order), relu,
    dequantize."""
    q, step = ops.quantize_activations(x, n_bits=prep.n_bits,
                                       signed=prep.signed,
                                       scale=prep.x_scale)
    planes = make_planes(q, prep.n_bits)
    D = planes.shape[0]
    rmask = jnp.arange(D)[:, None] < jnp.clip(budget, 1, D)[None, :]
    planes = planes * rmask[:, :, None].astype(planes.dtype)
    return dslot_matmul_ref(planes, w, prep.n_bits, relu=True) * step


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_row_budget_vector_equals_zero_masked_planes(backend):
    rng = np.random.default_rng(0)
    M, K, N = 32, 32, 32
    x = jnp.asarray(np.maximum(rng.normal(0.2, 0.5, (M, K)), 0), jnp.float32)
    w = jnp.asarray(rng.integers(-8, 9, (K, N)) / 128.0, jnp.float32)
    prep = dslot_prepare(w, block_m=16, block_n=16, block_k=16,
                         backend=backend)
    budget = jnp.asarray(rng.integers(1, 9, M), jnp.int32)
    out, stats = dslot_execute(prep, x, n_planes=budget)
    ref = _zero_masked_reference(x, w, prep, budget)
    # dyadic weights + exact digit sums: termination only ever zeroes tiles
    # that are provably zero, so the kernel equals the full plane sum
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-7)
    assert stats.row_planes_used.shape == (M,)
    assert (np.asarray(stats.row_planes_used)
            <= np.asarray(budget.astype(jnp.float32))).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_row_budget_backends_identical(seed):
    """jnp in-scan masking and the Pallas SMEM budget vector are the same
    semantics: identical outputs, identical planes_used, identical
    row_planes_used for random per-row budgets."""
    rng = np.random.default_rng(seed)
    M, K, N = 32, 16, 32
    x = jnp.asarray(np.maximum(rng.normal(0.2, 0.5, (M, K)), 0), jnp.float32)
    w = jnp.asarray(rng.integers(-64, 65, (K, N)) / 128.0, jnp.float32)
    budget = jnp.asarray(rng.integers(1, 9, M), jnp.int32)
    outs = {}
    for backend in ("jnp", "pallas"):
        prep = dslot_prepare(w, block_m=16, block_n=16, block_k=16,
                             backend=backend)
        outs[backend] = dslot_execute(prep, x, n_planes=budget)
    oj, sj = outs["jnp"]
    op, sp = outs["pallas"]
    np.testing.assert_allclose(np.asarray(oj), np.asarray(op),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(sj.planes_used),
                                  np.asarray(sp.planes_used))
    np.testing.assert_array_equal(np.asarray(sj.row_planes_used),
                                  np.asarray(sp.row_planes_used))


def test_row_budget_rows_match_scalar_runs():
    """Each row under a vector budget equals that row under a scalar run at
    the same budget (the serving contract: per-request precision in a pooled
    batch is indistinguishable from solo execution)."""
    rng = np.random.default_rng(3)
    M, K, N = 32, 24, 16
    x = jnp.asarray(np.maximum(rng.normal(0.2, 0.5, (M, K)), 0), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.05, (K, N)), jnp.float32)
    prep = dslot_prepare(w, block_m=16, block_n=16, block_k=24,
                         backend="pallas")
    budget = jnp.asarray(rng.integers(2, 9, M), jnp.int32)
    ov, _ = dslot_execute(prep, x, n_planes=budget)
    for r in (0, 7, 31):
        orow, _ = dslot_execute(prep, x, n_planes=int(budget[r]))
        np.testing.assert_array_equal(np.asarray(ov[r]),
                                      np.asarray(orow[r]))
