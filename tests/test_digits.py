"""SD radix-2 digit codec: exactness + properties (paper §II-A)."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st  # hypothesis or skip-shim

from repro.core import (fixed_to_sd, first_negative_prefix, sd_from_value,
                        sd_prefix_values, sd_split_posneg, sd_to_value)


def test_fixed_to_sd_roundtrip_exact():
    rng = np.random.default_rng(0)
    q = rng.integers(-255, 256, size=(512,))
    d = fixed_to_sd(jnp.asarray(q), 9)
    assert set(np.unique(np.asarray(d))) <= {-1, 0, 1}
    v = np.asarray(sd_to_value(d)) * 2.0 ** 9
    np.testing.assert_array_equal(v, q)


def test_sd_from_value_exact_on_grid():
    rng = np.random.default_rng(1)
    q = rng.integers(-255, 256, size=(512,))
    d = sd_from_value(jnp.asarray(q / 256.0, jnp.float32), 8)
    np.testing.assert_allclose(np.asarray(sd_to_value(d)), q / 256.0,
                               rtol=0, atol=0)


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=-4095, max_value=4095))
def test_sd_from_value_property(q):
    d = sd_from_value(jnp.float32(q / 4096.0), 12)
    assert abs(float(sd_to_value(d)) - q / 4096.0) == 0.0
    assert set(np.unique(np.asarray(d))) <= {-1, 0, 1}


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=-1, max_value=1), min_size=1,
                max_size=20))
def test_posneg_bit_pair_identity(digits):
    """Paper eq. 2: d = x+ - x-."""
    d = jnp.asarray(np.array(digits, np.int8))
    pos, neg = sd_split_posneg(d)
    np.testing.assert_array_equal(np.asarray(pos) - np.asarray(neg),
                                  np.asarray(d))
    assert not np.any(np.asarray(pos) & np.asarray(neg))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(min_value=-1, max_value=1), min_size=1,
                max_size=18))
def test_first_negative_prefix_matches_bruteforce(digits):
    d = jnp.asarray(np.array(digits, np.int8))[:, None]
    idx = int(first_negative_prefix(d)[0])
    prefix = np.cumsum(np.array(digits) * 0.5 ** np.arange(1, len(digits) + 1))
    neg = np.nonzero(prefix < 0)[0]
    expected = (neg[0] + 1) if len(neg) else len(digits) + 1
    assert idx == expected


def test_prefix_values_shape_and_final():
    rng = np.random.default_rng(2)
    q = rng.integers(-200, 200, size=(64,))
    d = fixed_to_sd(jnp.asarray(q), 8)
    pv = sd_prefix_values(d)
    assert pv.shape == d.shape
    np.testing.assert_allclose(np.asarray(pv[-1]), q / 256.0, atol=1e-7)
