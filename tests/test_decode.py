"""Serving-path integration: prefill + incremental decode must reproduce the
full-sequence forward for every architecture family."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS
from repro.models.model_zoo import build_model


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_decode_matches_full(name):
    r = ARCHS[name].reduced()
    model = build_model(r)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S = 2, 24
    F = r.frontend_len if r.frontend else 0
    toks = jax.random.randint(key, (B, S - F), 0, r.vocab_size)
    batch = {"tokens": toks}
    if r.frontend:
        batch["frontend"] = jax.random.normal(key, (B, F, r.d_model)) * 0.02
    if r.family == "encdec":
        batch["src_embeds"] = jax.random.normal(key, (B, 8, r.d_model)) * 0.02

    logits_full, _, _ = model.forward(params, batch)
    npre = (S - F) - 5
    pre = dict(batch)
    pre["tokens"] = toks[:, :npre]
    last, state = model.prefill(params, pre, max_len=S + 4)
    errs = [float(jnp.abs(last - logits_full[:, npre - 1]).max())]
    for t in range(npre, S - F):
        lg, state = model.decode_step(params, state, toks[:, t:t + 1])
        errs.append(float(jnp.abs(lg - logits_full[:, t]).max()))
    scale = max(float(jnp.abs(logits_full).max()), 1.0)
    assert max(errs) / scale < 1e-3, errs


def test_swa_ring_cache_wraps_correctly():
    """Decode far past the window: ring slots recycle, old positions are
    masked out, and results stay finite and cache-consistent."""
    r = ARCHS["h2o-danube-3-4b"].reduced()   # window = 32 reduced
    model = build_model(r)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    toks = jax.random.randint(key, (1, 8), 0, r.vocab_size)
    _, state = model.prefill(params, {"tokens": toks}, max_len=128)
    for t in range(8, 80):                   # well past window 32
        lg, state = model.decode_step(
            params, state, jnp.zeros((1, 1), jnp.int32))
        assert bool(jnp.isfinite(lg).all()), t
    cache = jax.tree.leaves(state["caches"])
    assert all(bool(jnp.isfinite(c).all()) for c in cache
               if c.dtype.kind == "f")
