"""Algorithm 1: early negative detection — soundness, savings, and the
contrast with LSB-first SIP (whose partial sums cannot be used this way)."""

import numpy as np
import jax.numpy as jnp
from _hyp import given, settings, st  # hypothesis or skip-shim

from repro.core import (early_termination, fixed_to_sd, pe_schedule,
                        pe_sop_digits, sd_to_value, sip_sop_trace)


def _sop_digits(xq, wq, k=5):
    sch = pe_schedule(k=k, p_mult=16)
    xd = fixed_to_sd(jnp.asarray(xq), 8)
    wf = jnp.asarray(wq / 256.0, jnp.float32)[:, None]
    return pe_sop_digits(xd, wf, sch), sch


def test_soundness_batch():
    """Termination may fire ONLY on SOPs whose true value is negative."""
    rng = np.random.default_rng(0)
    xq = rng.integers(0, 128, size=(25, 512))
    wq = rng.integers(-127, 32, size=(25,))       # negative-leaning weights
    sop, sch = _sop_digits(xq, wq)
    rep = early_termination(sop, sch)
    true = (xq * wq[:, None]).sum(0)
    fired = np.asarray(rep.is_negative)
    assert fired.any(), "test should exercise termination"
    assert ((~fired) | (true < 0)).all(), "unsound termination"


def test_savings_range_on_negatives():
    """Paper §II-B.2: 45-50% of cycles saved on negative convolutions (the
    exact number depends on magnitudes; we check savings are substantial)."""
    rng = np.random.default_rng(1)
    xq = rng.integers(32, 128, size=(25, 256))
    wq = rng.integers(-127, -32, size=(25,))      # strongly negative SOPs
    sop, sch = _sop_digits(xq, wq)
    rep = early_termination(sop, sch)
    assert bool(np.all(np.asarray(rep.is_negative)))
    mean_saving = float(np.mean(np.asarray(rep.savings_frac)))
    assert 0.30 <= mean_saving <= 0.65, mean_saving


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 32 - 1))
def test_soundness_property(seed):
    rng = np.random.default_rng(seed)
    xq = rng.integers(0, 128, size=(9, 64))
    wq = rng.integers(-127, 128, size=(9,))
    sch = pe_schedule(k=3, p_mult=16)
    xd = fixed_to_sd(jnp.asarray(xq), 8)
    sop = pe_sop_digits(xd, jnp.asarray(wq / 256.0, jnp.float32)[:, None],
                        sch)
    rep = early_termination(sop, sch)
    true = (xq * wq[:, None]).sum(0)
    assert ((~np.asarray(rep.is_negative)) | (true < 0)).all()


def test_sip_partial_sign_is_unreliable():
    """LSB-first bit-serial accumulators change sign late — the structural
    reason SIP cannot terminate early (paper §II-B.2)."""
    rng = np.random.default_rng(2)
    found = False
    for _ in range(60):
        xq = rng.integers(0, 256, size=(25, 1))
        wq = rng.integers(-127, 128, size=(25, 1))
        trace = np.asarray(sip_sop_trace(jnp.asarray(xq), jnp.asarray(wq)))
        final = trace[-1, 0]
        # look for a case where some partial sum's sign != final sign
        if np.any(np.sign(trace[:-1, 0]) != np.sign(final)):
            found = True
            break
    assert found, "expected at least one sign flip in SIP partial sums"


def test_no_false_negative_rate_on_positive_sops():
    rng = np.random.default_rng(3)
    xq = rng.integers(0, 128, size=(25, 128))
    wq = rng.integers(16, 127, size=(25,))        # all-positive weights
    sop, sch = _sop_digits(xq, wq)
    rep = early_termination(sop, sch)
    assert not np.asarray(rep.is_negative).any()
    assert (np.asarray(rep.cycles_used) == sch.total_cycles).all()
