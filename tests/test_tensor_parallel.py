"""Tensor-parallel DSLOT equivalence suite.

The N-axis sharding contract (``kernels/ops.py`` module docs) is that a
mesh-prepared ``dslot_execute`` — and everything stacked on it, up to a
whole sharded ``ServeEngine`` — is BIT-identical to the single-device
path: outputs, ``planes_used``, ``planes_bounded``, ``skipped_frac``, and
the served token streams.  This file pins that contract two ways:

* an in-process derandomized hypothesis property on a 1-device mesh (the
  shard_map machinery with shards=1 — runs in every environment, no
  device-count override needed);
* spawned 8-host-device subprocesses (the ``test_distributed.py`` pattern,
  so the XLA override never leaks) sweeping shard counts {1, 2, 4} over
  scalar and per-row plane budgets with and without the MSR bound, plus a
  deterministic end-to-end pin that a sharded ``ServeEngine`` burst emits
  token-identical results vs the unsharded engine, and a 2-shard chaos
  mirror: fault injection + quarantine isolation (``serve/faults.py``)
  keeps survivors bit-identical on a sharded engine too.

Also holds the ``launch.mesh.make_test_mesh`` zero-extent regression test:
fewer devices than the model axis must raise, not build a (0, model) mesh.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hyp import given, settings, st
from repro.kernels.ops import dslot_execute, dslot_prepare
from repro.launch.mesh import make_test_mesh

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_dist(code: str, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    # src for the package, tests/ for the _hyp shim (subprocess properties
    # run derandomized through the same profile as the in-process ones)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_REPO, "src"), os.path.join(_REPO, "tests")])
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=_REPO)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


# ------------------------------------------------- make_test_mesh regression

def test_make_test_mesh_rejects_too_few_devices():
    # seed bug: n // model == 0 silently built a zero-extent (0, model)
    # mesh that every downstream shard_map call then tripped over.
    with pytest.raises(ValueError, match="at least model=2"):
        make_test_mesh(n_devices=1, model=2)
    with pytest.raises(ValueError, match="at least model=4"):
        make_test_mesh(n_devices=2, model=4)
    with pytest.raises(ValueError):
        make_test_mesh(n_devices=4, model=0)
    if len(jax.devices()) < 2:       # the default-arg path, same guard
        with pytest.raises(ValueError, match="host_platform_device_count"):
            make_test_mesh(model=2)
    # the valid shapes still build
    assert dict(make_test_mesh(n_devices=1, model=1).shape) == {
        "data": 1, "model": 1}


# ------------------------------------------- in-process property (1 device)

def _rand_case(seed, m, k, n, zero_cols):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(k, n)).astype(np.float32)
    if zero_cols:
        w[:, : n // 4] = 0.0                      # inert tiles for the bound
    x = rng.normal(size=(m, k)).astype(np.float32).clip(0)
    return w, x


@settings(deadline=None)
@given(seed=st.integers(0, 2**31 - 1), msr=st.booleans(),
       sort=st.booleans(), zero_cols=st.booleans(),
       npl=st.one_of(st.integers(1, 8), st.just("rows")))
def test_one_shard_mesh_bit_identical(seed, msr, sort, zero_cols, npl):
    m, k, n = 12, 32, 64
    w, x = _rand_case(seed, m, k, n, zero_cols)
    if npl == "rows":
        npl = np.random.default_rng(seed + 1).integers(1, 9, size=m)
        npl = jnp.asarray(npl, jnp.int32)
    kw = dict(n_bits=8, relu=True, sort_columns=sort, msr_bound=msr,
              block_m=8, block_n=16, block_k=16)
    ref, ref_st = dslot_execute(dslot_prepare(w, **kw), x, n_planes=npl)
    mesh = make_test_mesh(n_devices=1, model=1)
    out, st_ = dslot_execute(dslot_prepare(w, mesh=mesh, **kw), x,
                             n_planes=npl)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(st_.planes_used),
                                  np.asarray(ref_st.planes_used))
    np.testing.assert_array_equal(np.asarray(st_.planes_bounded),
                                  np.asarray(ref_st.planes_bounded))
    assert float(st_.skipped_frac) == float(ref_st.skipped_frac)


def test_prepare_rejects_missing_axis():
    mesh = make_test_mesh(n_devices=1, model=1)
    with pytest.raises(ValueError, match="tp_axis"):
        dslot_prepare(np.zeros((8, 8), np.float32), mesh=mesh,
                      tp_axis="nope")


# ------------------------------------------------- 8-device shard sweeps

@pytest.mark.slow
def test_sharded_execute_bit_identical_across_shards():
    # derandomized hypothesis property INSIDE the 8-device subprocess:
    # drawn weights/activations/budgets, shard counts {1, 2, 4}, with and
    # without the MSR bound, scalar and per-row budgets — all bit-identical
    # to the unsharded reference, including the stats tables.
    run_dist("""
        import numpy as np, jax, jax.numpy as jnp
        from _hyp import HAS_HYPOTHESIS, given, settings, st
        from repro.kernels.ops import dslot_execute, dslot_prepare
        from repro.launch.mesh import make_test_mesh
        assert len(jax.devices()) == 8

        M, K, N = 20, 48, 80
        KW = dict(n_bits=8, relu=True, sort_columns=True,
                  block_m=16, block_n=16, block_k=16)
        MESHES = {s: make_test_mesh(n_devices=s, model=s) for s in (1, 2, 4)}

        def check(seed, msr, vector):
            rng = np.random.default_rng(seed)
            w = rng.normal(size=(K, N)).astype(np.float32)
            w[:, :16] = 0.0                       # inert tiles
            x = rng.normal(size=(M, K)).astype(np.float32).clip(0)
            npl = (jnp.asarray(rng.integers(1, 9, size=M), jnp.int32)
                   if vector else int(rng.integers(1, 9)))
            ref, rst = dslot_execute(
                dslot_prepare(w, msr_bound=msr, **KW), x, n_planes=npl)
            for s, mesh in MESHES.items():
                out, st_ = dslot_execute(
                    dslot_prepare(w, msr_bound=msr, mesh=mesh, **KW),
                    x, n_planes=npl)
                assert np.array_equal(np.asarray(out), np.asarray(ref)), s
                assert np.array_equal(np.asarray(st_.planes_used),
                                      np.asarray(rst.planes_used)), s
                assert np.array_equal(np.asarray(st_.planes_bounded),
                                      np.asarray(rst.planes_bounded)), s
                assert float(st_.skipped_frac) == float(rst.skipped_frac)

        if HAS_HYPOTHESIS:
            @settings(deadline=None, max_examples=6)
            @given(seed=st.integers(0, 2**31 - 1), msr=st.booleans(),
                   vector=st.booleans())
            def prop(seed, msr, vector):
                check(seed, msr, vector)
            prop()
        else:                      # minimal env: deterministic corner sweep
            for seed in (0, 1):
                for msr in (False, True):
                    for vector in (False, True):
                        check(seed, msr, vector)
        print("shard sweep OK")
    """)


@pytest.mark.slow
def test_sharded_execute_pallas_backend():
    # the interpret-mode Pallas kernel under shard_map: one deterministic
    # case (it is ~10x slower than the jnp replay), still bit-identical.
    run_dist("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.kernels.ops import dslot_execute, dslot_prepare
        from repro.launch.mesh import make_test_mesh
        rng = np.random.default_rng(7)
        w = rng.normal(size=(32, 48)).astype(np.float32)
        x = rng.normal(size=(12, 32)).astype(np.float32).clip(0)
        kw = dict(n_bits=8, relu=True, sort_columns=True, backend="pallas",
                  block_m=8, block_n=16, block_k=16)
        ref, rst = dslot_execute(dslot_prepare(w, **kw), x, n_planes=5)
        mesh = make_test_mesh(n_devices=2, model=2)
        out, st = dslot_execute(dslot_prepare(w, mesh=mesh, **kw), x,
                                n_planes=5)
        assert np.array_equal(np.asarray(out), np.asarray(ref))
        assert np.array_equal(np.asarray(st.planes_used),
                              np.asarray(rst.planes_used))
        print("pallas shard OK")
    """)


@pytest.mark.slow
def test_sharded_serve_engine_token_identical():
    # end-to-end pin: a sharded ServeEngine burst (mixed per-request plane
    # budgets, chunked admission) emits byte-for-byte the token streams and
    # plane accounting of the unsharded engine, at 2 and 4 shards.
    run_dist("""
        import dataclasses
        import numpy as np, jax
        from repro.configs.base import DslotConfig
        from repro.configs.registry import ARCHS
        from repro.launch.mesh import make_test_mesh
        from repro.models import pspec
        from repro.models.model_zoo import build_model
        from repro.serve import Request, ServeConfig, ServeEngine

        cfg = dataclasses.replace(
            ARCHS["olmo-1b"].reduced(), act="relu", glu=False,
            dslot=DslotConfig(enabled=True, block_m=16, block_n=32,
                              block_k=16, act_scale=0.05))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompts = [np.asarray([3, 1, 4, 1, 5, 9, 2, 6], np.int32),
                   np.asarray([2, 7, 1, 8, 2, 8], np.int32),
                   np.asarray([1, 6, 1, 8, 0, 3, 3], np.int32)]

        def burst(mesh):
            pspec.set_mesh(None)            # engine installs its own mesh
            eng = ServeEngine(model, params, ServeConfig(
                n_slots=2, max_len=64, prefill_chunk=4, mesh=mesh))
            reqs = [Request(uid=i, prompt=p, max_new=6,
                            n_planes=[8, 5, 6][i])
                    for i, p in enumerate(prompts)]
            for r in reqs:
                assert eng.try_add(r)
            for _ in range(300):
                if all(r.done for r in reqs):
                    break
                eng.step()
            assert all(r.done for r in reqs)
            return [(list(map(int, r.out)), r.result.planes_used_mean)
                    for r in reqs]

        ref = burst(None)
        for shards in (2, 4):
            got = burst(make_test_mesh(n_devices=shards, model=shards))
            assert [t for t, _ in got] == [t for t, _ in ref], shards
            for (_, pg), (_, pr) in zip(got, ref):
                assert abs(pg - pr) < 1e-6, (shards, pg, pr)
        print("sharded serving token-identical OK")
    """)


@pytest.mark.slow
def test_sharded_chaos_quarantine_isolation():
    # PR 9 hardening composes with tensor parallelism: on a 2-shard mesh,
    # an injected NaN quarantines exactly the poisoned request, step()
    # never raises, invariants hold every tick, and the SURVIVOR's token
    # stream is bit-identical to a 2-shard run that never admitted the
    # victim (the fault hooks are host-side, outside the sharded jit, so
    # nothing recompiles and no shard sees a different program).
    run_dist("""
        import dataclasses
        import numpy as np, jax
        from repro.configs.base import DslotConfig
        from repro.configs.registry import ARCHS
        from repro.launch.mesh import make_test_mesh
        from repro.models import pspec
        from repro.models.model_zoo import build_model
        from repro.serve import (Fault, FaultPlan, QUARANTINED, Request,
                                 ServeConfig, ServeEngine, audit_engine)

        cfg = dataclasses.replace(
            ARCHS["olmo-1b"].reduced(), act="relu", glu=False,
            dslot=DslotConfig(enabled=True, block_m=16, block_n=32,
                              block_k=16, act_scale=0.05))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        surv_p = np.asarray([3, 1, 4, 1, 5, 9, 2, 6], np.int32)
        vict_p = np.asarray([2, 7, 1, 8, 2, 8], np.int32)
        mesh = make_test_mesh(n_devices=2, model=2)

        def run(with_victim, faults):
            pspec.set_mesh(None)
            eng = ServeEngine(model, params, ServeConfig(
                n_slots=2, max_len=64, prefill_chunk=4, mesh=mesh,
                faults=faults))
            surv = Request(uid=1, prompt=surv_p, max_new=8)
            assert eng.try_add(surv)
            vict = None
            if with_victim:
                vict = Request(uid=2, prompt=vict_p, max_new=8)
                assert eng.try_add(vict)
            for _ in range(100):
                eng.step()
                assert audit_engine(eng) == []
                if surv.done and (vict is None or vict.done):
                    break
            return eng, surv, vict

        plan = FaultPlan(faults=(Fault(kind="nan_logits", step=5, uid=2),))
        eng, surv, vict = run(True, plan)
        assert vict.phase == QUARANTINED and vict.done
        assert [u for _, u in eng.quarantined] == [2]
        assert surv.phase == "done" and len(surv.out) == 8
        _, ref, _ = run(False, None)       # victim never admitted
        assert list(surv.out) == list(ref.out), (surv.out, ref.out)
        print("sharded chaos quarantine OK")
    """)
