"""Pallas dslot_matmul vs pure-jnp oracle: shape/dtype sweeps, termination
soundness, runtime precision, column sorting (per-kernel requirement)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st  # hypothesis or skip-shim

from repro.kernels.dslot_matmul import dslot_matmul_pallas
from repro.kernels.ops import dslot_matmul, quantize_activations
from repro.kernels.ref import dslot_matmul_ref, make_planes, plane_value_ref


@pytest.mark.parametrize("M,K,N,bm,bn", [
    (32, 16, 32, 16, 16),
    (64, 48, 64, 32, 32),
    (128, 96, 128, 32, 64),
    (64, 128, 32, 64, 16),
])
@pytest.mark.parametrize("wdtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_oracle_sweep(M, K, N, bm, bn, wdtype):
    rng = np.random.default_rng(M + N)
    aq = jnp.asarray(rng.integers(0, 256, size=(M, K)), jnp.int32)
    w = jnp.asarray(rng.normal(0, 0.05, size=(K, N)), wdtype)
    ref = dslot_matmul_ref(make_planes(aq, 8), w.astype(jnp.float32), 8,
                           relu=True)
    out = dslot_matmul_pallas(aq, w.astype(jnp.float32), n_bits=8,
                              relu=True, block_m=bm, block_n=bn)
    np.testing.assert_allclose(np.asarray(out.out), np.asarray(ref),
                               atol=1e-2, rtol=1e-5)


@pytest.mark.parametrize("n_planes", [2, 4, 6, 8])
def test_runtime_precision_knob(n_planes):
    """Paper: 'precision of the online operators can be tuned at run-time'."""
    rng = np.random.default_rng(n_planes)
    aq = jnp.asarray(rng.integers(0, 256, size=(32, 32)), jnp.int32)
    w = jnp.asarray(rng.normal(0, 0.06, size=(32, 32)), jnp.float32)
    planes = make_planes(aq, 8, n_planes=n_planes)
    ref = dslot_matmul_ref(planes, w, 8, relu=True)
    out = dslot_matmul_pallas(aq, w, n_bits=8, n_planes=n_planes, relu=True,
                              block_m=16, block_n=16)
    np.testing.assert_allclose(np.asarray(out.out), np.asarray(ref),
                               atol=1e-2)
    # truncated value error is bounded by 2^(8-D) per element
    approx = np.asarray(plane_value_ref(planes, 8))
    assert np.abs(approx - np.asarray(aq)).max() < 2 ** (8 - n_planes)


def test_termination_soundness_and_savings():
    rng = np.random.default_rng(7)
    aq = jnp.asarray(rng.integers(0, 256, size=(64, 64)), jnp.int32)
    w = rng.normal(0, 0.04, size=(64, 64)).astype(np.float32)
    w[:, :32] -= 0.08                       # clustered dead columns
    ref = dslot_matmul_ref(make_planes(aq, 8), jnp.asarray(w), 8, relu=True)
    out = dslot_matmul_pallas(aq, jnp.asarray(w), n_bits=8, relu=True,
                              block_m=32, block_n=32)
    np.testing.assert_allclose(np.asarray(out.out), np.asarray(ref),
                               atol=1e-2)
    pu = np.asarray(out.planes_used)
    r = np.asarray(ref)
    assert pu.min() < 8, "termination should fire on dead tiles"
    for i in range(pu.shape[0]):
        for j in range(pu.shape[1]):
            if pu[i, j] < 8:
                assert (r[i * 32:(i + 1) * 32, j * 32:(j + 1) * 32]
                        == 0).all()


def test_no_termination_without_relu():
    rng = np.random.default_rng(8)
    aq = jnp.asarray(rng.integers(0, 256, size=(32, 32)), jnp.int32)
    w = jnp.asarray(rng.normal(0, 0.05, size=(32, 32)) - 0.1, jnp.float32)
    out = dslot_matmul_pallas(aq, w, n_bits=8, relu=False,
                              block_m=16, block_n=16)
    assert (np.asarray(out.planes_used) == 8).all()
    ref = dslot_matmul_ref(make_planes(aq, 8), w, 8, relu=False)
    np.testing.assert_allclose(np.asarray(out.out), np.asarray(ref),
                               atol=1e-2)


def test_ops_wrapper_padding_and_sorting():
    rng = np.random.default_rng(9)
    x = jnp.asarray(np.maximum(rng.normal(0.3, 0.4, size=(50, 40)), 0),
                    jnp.float32)
    w = rng.normal(0, 0.05, size=(40, 70)).astype(np.float32)
    w[:, rng.permutation(70)[:35]] -= 0.09
    ref = np.maximum(np.asarray(x) @ w, 0)
    for sort in (False, True):
        out, st_ = dslot_matmul(x, jnp.asarray(w), backend="jnp",
                                sort_columns=sort, block_m=32, block_n=32)
        err = np.abs(np.asarray(out) - ref).max()
        assert err < 0.02 * max(ref.max(), 1.0)
    # sorting must increase (or preserve) skipped fraction
    _, s0 = dslot_matmul(x, jnp.asarray(w), backend="jnp",
                         sort_columns=False, block_m=32, block_n=32)
    _, s1 = dslot_matmul(x, jnp.asarray(w), backend="jnp",
                         sort_columns=True, block_m=32, block_n=32)
    assert float(s1.skipped_frac) >= float(s0.skipped_frac)


def test_backends_agree():
    rng = np.random.default_rng(10)
    x = jnp.asarray(np.maximum(rng.normal(0.2, 0.5, size=(64, 48)), 0),
                    jnp.float32)
    w = jnp.asarray(rng.normal(-0.02, 0.05, size=(48, 64)), jnp.float32)
    o1, s1 = dslot_matmul(x, w, backend="jnp", block_m=32, block_n=32)
    o2, s2 = dslot_matmul(x, w, backend="pallas", block_m=32, block_n=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(s1.planes_used),
                                  np.asarray(s2.planes_used))


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6))
def test_kernel_oracle_property(seed):
    rng = np.random.default_rng(seed)
    M = int(rng.integers(1, 3)) * 16
    K = int(rng.integers(1, 5)) * 8
    N = int(rng.integers(1, 3)) * 16
    aq = jnp.asarray(rng.integers(-255, 256, size=(M, K)), jnp.int32)
    w = jnp.asarray(rng.normal(0, 0.1, size=(K, N)), jnp.float32)
    ref = dslot_matmul_ref(make_planes(aq, 8), w, 8, relu=True)
    out = dslot_matmul_pallas(aq, w, n_bits=8, relu=True,
                              block_m=16, block_n=16)
    np.testing.assert_allclose(np.asarray(out.out), np.asarray(ref),
                               atol=5e-2, rtol=1e-4)


def test_quantize_activations():
    x = jnp.asarray([0.0, 0.5, 1.0, 2.0], jnp.float32)
    q, step = quantize_activations(x, 8)
    np.testing.assert_allclose(np.asarray(q) * float(step),
                               np.asarray(x), atol=float(step) / 2)
