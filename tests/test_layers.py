"""Unified layer API: DslotDense / DslotConv2d lower through the digit-plane
kernel (both backends), match float references up to quantization, and
surface per-layer planes_used statistics; the model stack (MNIST CNN, MLP
dslot mode) routes through them."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.layers import DslotConv2d, DslotDense
from repro.models import stats


def test_dense_matches_float_reference_both_backends():
    key = jax.random.PRNGKey(0)
    layer = DslotDense(48, 64, name="d", block_m=32, block_n=32)
    p = layer.init(key)
    x = jnp.maximum(jax.random.normal(jax.random.PRNGKey(1), (3, 10, 48)), 0)
    ref = jnp.maximum(x.reshape(-1, 48) @ p["w"], 0).reshape(3, 10, 64)
    y_jnp, st_jnp = layer.apply(p, x)
    assert y_jnp.shape == (3, 10, 64)
    assert float(jnp.abs(y_jnp - ref).max()) < 0.02 * float(ref.max())

    pallas = dataclasses.replace(layer, use_pallas=True, block_k=16)
    y_pl, st_pl = pallas.apply(p, x)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_jnp),
                               atol=1e-4)
    np.testing.assert_array_equal(np.asarray(st_jnp.planes_used),
                                  np.asarray(st_pl.planes_used))


def test_dense_no_relu_head_runs_all_planes():
    layer = DslotDense(32, 16, name="head", relu=False,
                       block_m=16, block_n=16)
    p = layer.init(jax.random.PRNGKey(2))
    x = jnp.maximum(jax.random.normal(jax.random.PRNGKey(3), (16, 32)), 0)
    y, st = layer.apply(p, x)
    ref = x @ p["w"]
    assert float(jnp.abs(y - ref).max()) < 0.02 * float(jnp.abs(ref).max())
    assert (np.asarray(st.planes_used) == st.n_planes).all()


def test_conv2d_matches_lax_conv_multichannel_strided():
    key = jax.random.PRNGKey(4)
    layer = DslotConv2d(3, 4, 3, stride=2, name="c",
                        block_m=16, block_n=4)
    p = layer.init(key)
    x = jax.random.uniform(jax.random.PRNGKey(5), (2, 9, 9, 3))
    y, st = layer.apply(p, x)
    ref = jnp.maximum(jax.lax.conv_general_dilated(
        x, p["w"], (2, 2), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC")), 0)
    assert y.shape == ref.shape == (2, 4, 4, 4)
    assert float(jnp.abs(y - ref).max()) < 0.02 * float(ref.max())
    assert st.n_planes == 8


def test_layer_stats_side_channel():
    layer = DslotDense(32, 32, name="probe", block_m=16, block_n=16)
    p = layer.init(jax.random.PRNGKey(6))
    x = jnp.maximum(jax.random.normal(jax.random.PRNGKey(7), (16, 32)), 0)
    with stats.collect() as sink:
        layer.apply(p, x)
    assert "probe.skipped_frac" in sink
    assert "probe.planes_used_mean" in sink


def test_dense_early_termination_on_dead_columns():
    rng = np.random.default_rng(8)
    w = rng.normal(0, 0.04, (64, 64)).astype(np.float32)
    w[:, :32] -= 0.08                       # clustered dead columns
    layer = DslotDense(64, 64, name="dead", block_m=32, block_n=32,
                       block_k=16)
    x = jnp.asarray(np.maximum(rng.normal(0.3, 0.4, (64, 64)), 0),
                    jnp.float32)
    y, st = layer.apply({"w": jnp.asarray(w)}, x)
    assert float(st.skipped_frac) > 0.0
    ref = np.maximum(np.asarray(x) @ w, 0)
    assert np.abs(np.asarray(y) - ref).max() < 0.02 * max(ref.max(), 1.0)


def test_mnist_forward_dslot_routes_through_layers():
    from repro.configs.dslot_mnist import CONFIG
    from repro.core.mnist_cnn import forward, forward_dslot, init_cnn

    params = init_cnn(CONFIG, jax.random.PRNGKey(0))
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (4, 28, 28))
    ref = forward(params, imgs, CONFIG)
    res = forward_dslot(params, imgs, CONFIG, block_m=32, block_k=64)
    assert set(res.layer_stats) == {"conv1", "dense1"}
    for st_ in res.layer_stats.values():
        assert st_.planes_used.dtype == jnp.int32
        assert st_.n_planes == CONFIG.n_bits
    agree = float(jnp.mean(jnp.argmax(res.logits, -1)
                           == jnp.argmax(ref, -1)))
    assert agree == 1.0
    # logits head has no ReLU: every plane must run
    assert (np.asarray(res.layer_stats["dense1"].planes_used)
            == CONFIG.n_bits).all()


def test_mlp_dslot_mode_uses_layer_api():
    from repro.configs.base import DslotConfig
    from repro.configs.registry import ARCHS
    from repro.models.mlp import apply_mlp, init_mlp

    cfg = dataclasses.replace(
        ARCHS["olmo-1b"].reduced(), act="relu", glu=False,
        dslot=DslotConfig(enabled=True, block_m=32, block_n=32, block_k=16))
    p = init_mlp(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, cfg.d_model),
                          jnp.float32) * 0.5
    with stats.collect() as sink:
        y = apply_mlp(p, x, cfg)
    assert "mlp_up_dslot.skipped_frac" in sink
    assert "mlp_dslot_planes_used" in sink
    y_ref = apply_mlp(p, x, dataclasses.replace(cfg, dslot=DslotConfig()))
    rel = float(jnp.abs(y - y_ref).max() / jnp.abs(y_ref).max())
    assert rel < 0.1, rel
