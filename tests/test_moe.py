"""MoE: dispatch correctness vs a dense loop oracle, capacity semantics,
load-balance aux."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.granite_moe_1b_a400m import CONFIG as GRANITE
from repro.models.mlp import _ACTS
from repro.models.moe import apply_moe, init_moe, moe_capacity


def dense_oracle(p, x, cfg):
    """Evaluate every expert densely and combine with the same top-k gates
    (no capacity limits) — the dropless reference."""
    B, S, D = x.shape
    flat = x.reshape(-1, D)
    logits = flat.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    act = _ACTS[cfg.act]
    outs = []
    for e in range(cfg.n_experts):
        h = flat @ p["up"][e]
        if cfg.glu:
            h = act(flat @ p["gate"][e]) * h
        else:
            h = act(h)
        outs.append(h @ p["down"][e])
    stacked = jnp.stack(outs)                     # (E, T, D)
    y = jnp.zeros_like(flat)
    for k in range(cfg.top_k):
        y = y + gates[:, k:k + 1] * jnp.take_along_axis(
            stacked, idx[None, :, k:k + 1].transpose(2, 1, 0), axis=0)[0]
    return y.reshape(B, S, D)


def test_moe_matches_dense_oracle_when_dropless():
    cfg = GRANITE.reduced()                        # capacity_factor=4 -> dropless
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32) * 0.5
    y, aux = apply_moe(p, x, cfg)
    ref = dense_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4)


def test_aux_loss_near_one_for_uniform_routing():
    cfg = GRANITE.reduced()
    p = init_moe(cfg, jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 32, cfg.d_model)) * 0.1
    _, aux = apply_moe(p, x, cfg)
    assert 0.8 < float(aux) < 1.6       # balanced ~1.0 (Switch normalization)


def test_capacity_drops_are_graceful():
    import dataclasses
    cfg = dataclasses.replace(GRANITE.reduced(), capacity_factor=0.25)
    p = init_moe(cfg, jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, cfg.d_model)) * 0.5
    y, _ = apply_moe(p, x, cfg)
    assert bool(jnp.isfinite(y).all())
    # dropped tokens fall back to the residual path: output norm shrinks
    ref = dense_oracle(p, x, cfg)
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(ref)) * 1.05


def test_decode_capacity_is_dropless():
    cfg = GRANITE.reduced()
    assert moe_capacity(cfg, 4) >= cfg.top_k
    p = init_moe(cfg, jax.random.PRNGKey(6))
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 1, cfg.d_model)) * 0.5
    y, _ = apply_moe(p, x, cfg)                    # S==1 -> C = T*K
    ref = dense_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4)
