"""FPGA analytic model: eqs. 8-11 calibration against Table I."""

import pytest

from repro.core import TABLE1_PUBLISHED, table1_model
from repro.core.cycle_model import t_dslot, t_ola, t_olm, t_sip


def test_critical_paths_match_published():
    assert abs(t_sip(5) - 30.075) < 1e-6
    assert abs(t_dslot(5) - 15.436) < 1e-6


def test_dslot_cpd_is_half_of_sip():
    """Paper: 'approximately 48.6% shorter' critical path."""
    assert abs(1 - t_dslot(5) / t_sip(5) - 0.4867) < 0.01


def test_gops_per_watt_within_2pct():
    m = table1_model()
    for name, eng in m.items():
        pub = TABLE1_PUBLISHED[name]["gops_per_watt"]
        assert abs(eng.gops_per_watt - pub) / pub < 0.02, (name,
                                                           eng.gops_per_watt)


def test_dslot_perf_density_gain():
    """Paper abstract: ~49.7% higher OPS/W than SIP."""
    m = table1_model()
    gain = m["dslot"].gops_per_watt / m["stripes"].gops_per_watt - 1
    assert 0.40 <= gain <= 0.60, gain


def test_early_termination_improves_energy():
    m = table1_model()["dslot"]
    better = m.with_early_termination(0.06)   # ~12.5% negatives x ~50% saved
    assert better.gops_per_watt > m.gops_per_watt
    assert better.energy_per_window_nj() < m.energy_per_window_nj()
