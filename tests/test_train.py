"""Training substrate: convergence, grad-accumulation equivalence, optimizer."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS
from repro.data.pipeline import TokenPipeline
from repro.models.model_zoo import build_model
from repro.optim.adamw import (AdamWConfig, adamw_update, global_norm,
                               init_opt_state, schedule)
from repro.train.step import init_train_state, make_train_step


def test_loss_decreases():
    cfg = ARCHS["olmo-1b"].reduced()
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        model, AdamWConfig(peak_lr=3e-3, warmup_steps=5, decay_steps=200)))
    pipe = TokenPipeline(vocab=cfg.vocab_size, seq_len=32, global_batch=8,
                         microbatches=2)
    losses = []
    for _ in range(40):
        state, m = step(state, jax.tree.map(jnp.asarray,
                                            pipe.next_host_batch()))
        losses.append(float(m["loss"]))
    assert min(losses[-5:]) < losses[0] - 0.4, (losses[0], losses[-5:])


def test_grad_accumulation_equivalent():
    """M=1 vs M=4 microbatches: same data -> (near-)identical update."""
    cfg = ARCHS["olmo-1b"].reduced()
    model = build_model(cfg)
    opt = AdamWConfig(peak_lr=1e-3, warmup_steps=0, decay_steps=10)
    step = jax.jit(make_train_step(model, opt))
    pipe = TokenPipeline(vocab=cfg.vocab_size, seq_len=16, global_batch=8,
                         microbatches=1)
    raw = pipe.next_host_batch()
    b1 = jax.tree.map(jnp.asarray, raw)
    b4 = jax.tree.map(lambda a: jnp.asarray(a).reshape(4, 2, *a.shape[2:]),
                      raw)
    s0 = init_train_state(model, jax.random.PRNGKey(0))
    s1, m1 = step(s0, b1)
    s0b = init_train_state(model, jax.random.PRNGKey(0))
    s4, m4 = step(s0b, b4)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        s1.params, s4.params)
    assert max(jax.tree.leaves(diffs)) < 5e-3


def test_adamw_state_and_clipping():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    opt = init_opt_state(params)
    big = {"w": jnp.full((4, 4), 100.0), "b": jnp.full((4,), 100.0)}
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0, decay_steps=10,
                      peak_lr=0.1)
    newp, newopt, m = adamw_update(params, big, opt, cfg)
    assert float(m["grad_norm"]) > 1.0
    # clipped: effective step bounded by lr-ish magnitude
    assert float(jnp.abs(newp["w"] - params["w"]).max()) < 0.5
    assert int(newopt.count) == 1


def test_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100, 1000]]
    assert lrs[1] < lrs[2]                       # warmup rises
    assert abs(lrs[2] - 1.0) < 1e-6              # peak
    assert lrs[3] < lrs[2]                       # decays
    assert abs(lrs[-1] - 0.1) < 1e-6             # floor


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6
