"""Fused conv+ReLU+maxpool (paper Figs. 4-7): DSLOT == SIP == float conv."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import dslot_conv2d_stats, extract_windows, sip_conv2d
from repro.core.conv import im2col


def test_extract_windows():
    x = jnp.arange(2 * 8 * 8, dtype=jnp.int32).reshape(2, 8, 8)
    w = extract_windows(x, 3)
    assert w.shape == (2, 6, 6, 9)
    np.testing.assert_array_equal(
        np.asarray(w[0, 0, 0]), np.asarray(x[0, :3, :3]).reshape(-1))
    np.testing.assert_array_equal(
        np.asarray(w[1, 2, 3]), np.asarray(x[1, 2:5, 3:6]).reshape(-1))


def test_dslot_equals_sip_bit_exact():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(0, 1, size=(2, 14, 14)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.3, size=(4, 5, 5)), jnp.float32)
    res = dslot_conv2d_stats(x, w)
    ref = sip_conv2d(x, w)
    np.testing.assert_allclose(np.asarray(res.y_conv), np.asarray(ref),
                               atol=1e-5)


def test_dslot_matches_float_conv_to_quantization():
    rng = np.random.default_rng(1)
    x = rng.uniform(0, 1, size=(2, 12, 12)).astype(np.float32)
    w = rng.normal(0, 0.25, size=(3, 5, 5)).astype(np.float32)
    res = dslot_conv2d_stats(jnp.asarray(x), jnp.asarray(w))
    # float oracle
    from numpy.lib.stride_tricks import sliding_window_view
    win = sliding_window_view(x, (5, 5), axis=(1, 2))       # (B,8,8,5,5)
    ref = np.einsum("bijkl,mkl->bijm", win, w)
    err = np.abs(np.asarray(res.y_conv) - ref).max()
    assert err < 0.05 * max(np.abs(ref).max(), 1.0), err


def test_fused_relu_maxpool():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.uniform(0, 1, size=(1, 12, 12)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.3, size=(2, 5, 5)), jnp.float32)
    res = dslot_conv2d_stats(x, w, pool=2)
    relu = np.maximum(np.asarray(res.y_conv), 0.0)
    B, H, W, M = relu.shape
    pooled = relu[:, : H // 2 * 2, : W // 2 * 2].reshape(
        B, H // 2, 2, W // 2, 2, M).max(axis=(2, 4))
    np.testing.assert_allclose(np.asarray(res.y_pooled), pooled, atol=1e-6)


@pytest.mark.parametrize("shape,k,stride",
                         [((2, 9, 9, 3), 3, 1), ((2, 9, 9, 3), 3, 2),
                          ((1, 8, 10, 2), 5, 2), ((1, 7, 7, 1), 4, 3),
                          ((2, 6, 6, 3), 2, 2)])
def test_im2col_same_padding_matches_lax_conv(shape, k, stride):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    C, M = shape[-1], 4
    w = jnp.asarray(rng.normal(size=(k, k, C, M)), jnp.float32)
    cols = im2col(x, k, stride, padding="same")
    y = cols @ w.reshape(-1, M)
    ref = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    assert y.shape == ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)


def test_im2col_bad_padding_raises():
    with pytest.raises(ValueError, match="padding"):
        im2col(jnp.zeros((1, 8, 8, 1)), 3, padding="reflect")


def test_dslot_conv2d_same_padding_matches_lax():
    from repro.layers import DslotConv2d

    layer = DslotConv2d(3, 4, 3, stride=2, padding="same", name="cs",
                        block_m=16, block_n=4)
    params = layer.init(jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 9, 9, 3))
    y, st = layer.apply(params, x)
    ref = jnp.maximum(jax.lax.conv_general_dilated(
        x, params["w"], (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")), 0)
    assert y.shape == ref.shape == (2, 5, 5, 4)
    assert float(jnp.abs(y - ref).max()) < 0.02 * float(ref.max())


def test_termination_stats_are_consistent():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.uniform(0, 1, size=(1, 12, 12)), jnp.float32)
    w = jnp.asarray(rng.normal(-0.15, 0.2, size=(2, 5, 5)), jnp.float32)
    res = dslot_conv2d_stats(x, w)
    neg = np.asarray(res.y_conv) < 0
    fired = np.asarray(res.report.is_negative)
    assert (fired <= neg).all()                   # soundness
    assert fired.mean() > 0.2                     # actually fires here
    saved = np.asarray(res.report.cycles_saved)
    assert (saved[fired] > 0).all()
    assert (saved[~fired] == 0).all()
