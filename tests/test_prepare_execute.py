"""Prepare/execute split: bit-exactness vs the fused path, prepare-once
amortization, runtime precision semantics, and calibration."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops
from repro.kernels.ops import (calibrate_scale, dslot_execute, dslot_matmul,
                               dslot_prepare)

from _hyp import given, settings, st  # hypothesis or skip-shim


def _workload(seed=0, M=48, K=40, N=56, dead=True):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(np.maximum(rng.normal(0.2, 0.5, (M, K)), 0), jnp.float32)
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    if dead:
        w[:, :N // 2] -= 0.10            # clustered ReLU-dead columns
    return x, jnp.asarray(w)


# ------------------------------------------------------- fused == split

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("sort_columns", [False, True])
def test_split_bitexact_vs_fused_dense(backend, sort_columns):
    x, w = _workload()
    kw = dict(n_bits=8, relu=True, sort_columns=sort_columns,
              block_m=16, block_n=16, block_k=16, backend=backend)
    prep = dslot_prepare(w, **kw)
    for D in (8, 5, 2):
        of, sf = dslot_matmul(x, w, n_planes=D, **kw)
        oe, se = dslot_execute(prep, x, n_planes=D)
        np.testing.assert_array_equal(np.asarray(of), np.asarray(oe)), D
        np.testing.assert_array_equal(np.asarray(sf.planes_used),
                                      np.asarray(se.planes_used))


def test_split_bitexact_vs_fused_conv_shapes():
    """Conv lowering through the layer API: prepared layer == fused matmul
    on the same im2col workload."""
    from repro.core.conv import im2col
    from repro.layers import DslotConv2d

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.uniform(0, 1, (2, 12, 12, 3)), jnp.float32)
    layer = DslotConv2d(3, 4, 3, stride=2, name="c",
                        block_m=16, block_n=4, block_k=16)
    params = layer.init(jax.random.PRNGKey(0))
    y, st_ = layer.apply(params, x)
    cols = im2col(x, 3, 2)
    B, Ho, Wo, kkc = cols.shape
    of, sf = dslot_matmul(cols.reshape(-1, kkc),
                          params["w"].astype(jnp.float32).reshape(kkc, 4),
                          n_bits=8, relu=True, block_m=16, block_n=4,
                          block_k=16, backend="jnp")
    np.testing.assert_array_equal(
        np.asarray(y.reshape(-1, 4)), np.asarray(of))
    np.testing.assert_array_equal(np.asarray(st_.planes_used),
                                  np.asarray(sf.planes_used))


def test_backends_agree_runtime_precision():
    x, w = _workload(seed=5)
    pj = dslot_prepare(w, sort_columns=True, block_m=16, block_n=16,
                       block_k=16, backend="jnp")
    pp = dslot_prepare(w, sort_columns=True, block_m=16, block_n=16,
                       block_k=16, backend="pallas")
    for D in (8, 6, 3):
        oj, sj = dslot_execute(pj, x, n_planes=D)
        op, sp = dslot_execute(pp, x, n_planes=D)
        np.testing.assert_allclose(np.asarray(oj), np.asarray(op), atol=1e-4)
        np.testing.assert_array_equal(np.asarray(sj.planes_used),
                                      np.asarray(sp.planes_used))


# ------------------------------------------------------- prepare-once

def test_prepare_called_once_per_layer_lifetime():
    """The acceptance criterion: one prepare per layer, then any number of
    executions at any precision without re-preparing."""
    from repro.layers import DslotDense

    layer = DslotDense(32, 32, name="once", block_m=16, block_n=16)
    n0 = ops.prepare_call_count()
    params = layer.init(jax.random.PRNGKey(0))
    assert ops.prepare_call_count() - n0 == 1
    x = jnp.maximum(jax.random.normal(jax.random.PRNGKey(1), (16, 32)), 0)
    outs = []
    for D in (8, 6, 4, 2, 8, 3):
        y, _ = layer.apply(params, x, n_planes=D)
        outs.append(np.asarray(y))
    assert ops.prepare_call_count() - n0 == 1, \
        "runtime precision must not re-prepare"
    # and precision actually changes results
    assert np.abs(outs[0] - outs[3]).max() > 0


def test_prepare_once_whole_cnn():
    from repro.configs.dslot_mnist import CONFIG
    from repro.core.mnist_cnn import forward_dslot, init_cnn, prepare_cnn

    params = init_cnn(CONFIG, jax.random.PRNGKey(0))
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (4, 28, 28))
    n0 = ops.prepare_call_count()
    prep = prepare_cnn(params, CONFIG, block_m=32, block_k=64)
    assert ops.prepare_call_count() - n0 == 2          # conv + head
    r8 = forward_dslot(prep, imgs, CONFIG, n_planes=8)
    r2 = forward_dslot(prep, imgs, CONFIG, n_planes=2)
    assert ops.prepare_call_count() - n0 == 2
    assert float(jnp.abs(r8.logits - r2.logits).max()) > 0


# ------------------------------------------------------- runtime precision

def test_runtime_vector_precision_matches_scalar_rows():
    x, w = _workload(seed=7, M=32)
    prep = dslot_prepare(w, block_m=16, block_n=16, block_k=16,
                         backend="jnp")
    budget = jnp.asarray(np.random.default_rng(1).integers(2, 9, 32),
                         jnp.int32)
    ov, sv = dslot_execute(prep, x, n_planes=budget)
    assert sv.row_planes_used.shape == (32,)
    for r in (0, 9, 31):
        orow, _ = dslot_execute(prep, x, n_planes=int(budget[r]))
        np.testing.assert_array_equal(np.asarray(ov[r]), np.asarray(orow[r]))


def test_calibrated_scale_removes_data_dependence():
    x, w = _workload(seed=9)
    prep = dslot_prepare(w, block_m=16, block_n=16, block_k=16,
                         backend="jnp")
    cal = prep.with_scale(calibrate_scale(x, n_bits=8))
    o_dyn, _ = dslot_execute(prep, x)
    o_fix, _ = dslot_execute(cal, x)
    # calibrating on the same batch reproduces the dynamic scale exactly
    np.testing.assert_allclose(np.asarray(o_dyn), np.asarray(o_fix),
                               atol=1e-6)
    # a fixed scale is stable under input scaling; outliers clip instead of
    # stretching the grid
    o_big, _ = dslot_execute(cal, x.at[0, 0].set(100.0))
    assert np.isfinite(np.asarray(o_big)).all()


# ------------------------------------------------------- truncation property

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 16), n_planes=st.integers(1, 8))
def test_truncation_only_truncates(seed, n_planes):
    """Decreasing ``n_planes`` at execute time is a bounded truncation of
    the full-precision output: the error never exceeds the SD-digit tail
    bound, ReLU outputs stay nonnegative, and any output the full-precision
    run produces above the tail bound keeps its sign (nonzero stays
    nonzero under ReLU)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(np.maximum(rng.normal(0.3, 0.4, (16, 24)), 0),
                    jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.1, (24, 16)), jnp.float32)
    prep = dslot_prepare(w, block_m=16, block_n=16, block_k=24,
                         backend="jnp")
    full, stf = dslot_execute(prep, x, n_planes=8)
    trunc, stt = dslot_execute(prep, x, n_planes=n_planes)
    full, trunc = np.asarray(full), np.asarray(trunc)
    assert (trunc >= 0).all() and (full >= 0).all()
    # SD tail: |q - q_D| < 2^(8 - D); error per output < tail * colsum * step
    q, step = ops.quantize_activations(x, 8)
    tail = 2.0 ** (8 - n_planes)
    bound = tail * np.abs(np.asarray(w)).sum(axis=0) * float(step) + 1e-5
    assert (np.abs(full - trunc) <= bound[None, :]).all()
    # sign preservation for confidently-positive outputs
    confident = full > bound[None, :]
    assert (trunc[confident] > 0).all()
