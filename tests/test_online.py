"""Online multiplier / adder: bit-exactness, digit validity, online-delay
invariants (paper §II-A, DESIGN.md §4.1)."""

import numpy as np
import jax.numpy as jnp
from _hyp import given, settings, st  # hypothesis or skip-shim

from repro.core import (DELTA_ADD, DELTA_MULT, fixed_to_sd, online_add,
                        online_add_tree, online_mult_sp, sd_to_value)


def test_olm_bit_exact_batch():
    rng = np.random.default_rng(0)
    xq = rng.integers(0, 256, size=(256,))
    wq = rng.integers(-255, 256, size=(256,))
    xd = fixed_to_sd(jnp.asarray(xq), 9)            # value xq/512 < 1/2
    z = online_mult_sp(xd, jnp.asarray(wq / 512.0, jnp.float32), n_out=18)
    got = np.asarray(sd_to_value(z)) * 2.0 ** 18
    np.testing.assert_allclose(got, xq * wq, rtol=0, atol=1e-3)


def test_olm_digit_validity():
    rng = np.random.default_rng(1)
    xq = rng.integers(0, 128, size=(64,))
    xd = fixed_to_sd(jnp.asarray(xq), 8)
    z = online_mult_sp(xd, jnp.float32(0.49), n_out=16)
    assert set(np.unique(np.asarray(z))) <= {-1, 0, 1}


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=127),
       st.integers(min_value=-127, max_value=127))
def test_olm_property(xq, wq):
    xd = fixed_to_sd(jnp.asarray([xq]), 8)
    z = online_mult_sp(xd, jnp.float32(wq / 256.0), n_out=16)
    assert float(sd_to_value(z)[0]) * 2 ** 16 == xq * wq


def test_olm_msdf_prefix_convergence():
    """MSDF property: prefix after j digits is within 2^-j of the result —
    the basis of early sign detection (paper §I)."""
    xq, wq = 97, -113
    xd = fixed_to_sd(jnp.asarray([xq]), 8)
    z = online_mult_sp(xd, jnp.float32(wq / 256.0), n_out=16)
    true = xq * wq / 2.0 ** 16
    prefix = 0.0
    for j in range(16):
        prefix += float(z[j, 0]) * 2.0 ** -(j + 1)
        assert abs(prefix - true) <= 2.0 ** -(j + 1) + 1e-9


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=-16000, max_value=16000),
       st.integers(min_value=-16000, max_value=16000))
def test_ola_property(aq, bq):
    a = fixed_to_sd(jnp.asarray([aq]), 16)
    b = fixed_to_sd(jnp.asarray([bq]), 16)
    s = online_add(a, b, n_out=17)
    assert float(sd_to_value(s)[0]) * 2 ** 17 == aq + bq


def test_adder_tree_scaling_and_exactness():
    rng = np.random.default_rng(3)
    terms = rng.integers(-12000, 12000, size=(25, 32))
    streams = jnp.stack([fixed_to_sd(jnp.asarray(terms[i]), 16)
                         for i in range(25)])
    out, stages = online_add_tree(streams, n_out=21)
    assert stages == 5                               # ceil(log2 25)
    got = np.asarray(sd_to_value(out)) * 2.0 ** (16 + 5)
    np.testing.assert_allclose(got, terms.sum(0), rtol=0, atol=1e-2)


def test_online_delays_are_papers():
    assert DELTA_MULT == 2 and DELTA_ADD == 2
