"""Pin the public API surface and the deprecation shims.

The serving redesign froze the construction/result contract:
``ServeEngine(model, params, cfg: ServeConfig)`` and ``GenerateResult``
from both generation paths.  These tests pin the exported names and the
load-bearing signatures so an accidental rename or a dropped shim fails
tier-1 instead of breaking downstream callers silently.
"""

import inspect
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.kernels
import repro.runtime
import repro.serve
from repro.serve import GenerateResult, Request, ServeConfig, ServeEngine
from repro.serve import engine as engine_mod
from repro.serve import generate

SERVE_ALL = {
    "ServeConfig", "Request", "ServeEngine", "generate", "GenerateResult",
    "PrefillPipeline", "PrefillTask",
    "PENDING", "PREFILLING", "DECODING", "DONE", "CANCELLED",
    "TIMEOUT", "QUARANTINED", "FAILED",
    "Fault", "FaultPlan", "FaultInjector", "TransientFault", "FAULT_KINDS",
    "InvariantViolation", "audit_engine", "check_invariants",
    "SloConfig", "SloController", "SloSignals", "TierSpec", "default_tiers",
    "RESERVED", "STANDARD", "DEGRADABLE", "TIERS",
}

RUNTIME_ALL = {
    "AdaptiveBudget", "Fixed", "PerLayerSchedule", "PolicyFeedback",
    "PrecisionPolicy", "current_precision", "precision_scope",
}

KERNELS_ALL = {
    "DslotMatmulOut", "DslotStats", "DslotWeights", "dslot_matmul",
    "dslot_prepare", "dslot_execute", "calibrate_scale",
    "prepare_call_count", "dslot_matmul_pallas",
    "dslot_matmul_pallas_batched", "colsum_tables", "select_block_k",
    "q_storage_dtype", "quantize_activations", "dslot_matmul_ref",
    "csd_matmul_ref", "make_planes", "sd_digit_plane",
}


def test_exported_surface_pinned():
    assert set(repro.serve.__all__) == SERVE_ALL
    assert set(repro.runtime.__all__) == RUNTIME_ALL
    assert set(repro.kernels.__all__) == KERNELS_ALL
    for mod in (repro.serve, repro.runtime, repro.kernels):
        for name in mod.__all__:
            assert hasattr(mod, name), f"{mod.__name__}.{name} missing"


def test_serve_engine_signature_pinned():
    sig = inspect.signature(ServeEngine.__init__)
    names = list(sig.parameters)
    # the blessed surface: (model, params, cfg) — everything after is the
    # keyword-only deprecation shim
    assert names[:4] == ["self", "model", "params", "cfg"]
    assert sig.parameters["cfg"].default is None
    legacy = {n for n, p in sig.parameters.items()
              if p.kind is inspect.Parameter.KEYWORD_ONLY}
    assert legacy == {"n_slots", "max_len", "sample", "precision_policy",
                      "serve_config"}


def test_generate_signature_pinned():
    sig = inspect.signature(generate)
    names = list(sig.parameters)
    assert names == ["model", "params", "batch", "max_new_tokens",
                     "max_len", "sample", "key", "n_planes", "return_stats"]
    # precision is named n_planes on every public surface
    assert "n_planes" in inspect.signature(
        repro.runtime.precision_scope).parameters
    assert "n_planes" in {f.name for f in Request.__dataclass_fields__.values()}
    assert "n_planes" in {
        f.name for f in GenerateResult.__dataclass_fields__.values()}


def test_serve_config_fields_pinned():
    assert {f.name for f in ServeConfig.__dataclass_fields__.values()} == {
        "n_slots", "max_len", "prefill_chunk", "chunks_per_step",
        "max_queue", "jit_prefill", "sample", "precision_policy", "slo",
        "mesh", "tp_axis",
        "default_deadline_steps", "max_step_retries",
        "quarantine_nonfinite", "faults"}
    assert ServeConfig().mesh is None and ServeConfig().tp_axis == "model"
    # hardening defaults: no deadline, quarantine ON, no fault plan
    cfg = ServeConfig()
    assert cfg.default_deadline_steps is None
    assert cfg.max_step_retries == 2
    assert cfg.quarantine_nonfinite is True
    assert cfg.faults is None


def test_hardening_surface_pinned():
    """The PR 9 failure surface: deadlines, fault plane, shutdown, and the
    terminal phase strings downstream dashboards key on."""
    from repro.serve import (FAILED, FAULT_KINDS, Fault, FaultPlan,
                             QUARANTINED, TIMEOUT)

    assert "deadline_steps" in {
        f.name for f in Request.__dataclass_fields__.values()}
    assert Request.__dataclass_fields__["deadline_steps"].default is None

    # phase strings are wire format — pin the values, not just the names
    assert TIMEOUT == "timeout"
    assert QUARANTINED == "quarantined"
    assert FAILED == "failed"
    assert set(FAULT_KINDS) == {
        "nan_logits", "inf_logits", "kv_corrupt", "lane_exception",
        "admission_exception", "decode_exception", "cancel", "slow_step"}

    # Fault/FaultPlan are declarative data
    assert {f.name for f in Fault.__dataclass_fields__.values()} == {
        "kind", "step", "slot", "uid", "count", "value"}
    assert {f.name for f in FaultPlan.__dataclass_fields__.values()} == {
        "faults", "seed"}
    rnd = inspect.signature(FaultPlan.random).parameters
    assert {"n_faults", "max_step", "n_slots", "uids", "kinds"} <= set(rnd)

    # shutdown + audit surface
    drain = inspect.signature(ServeEngine.drain).parameters
    assert list(drain) == ["self", "max_steps"]
    assert drain["max_steps"].default is None
    assert list(inspect.signature(ServeEngine.close).parameters) == ["self"]
    assert isinstance(ServeEngine.closed, property)
    assert callable(ServeEngine.check_invariants)


def test_sharding_surface_pinned():
    # the tensor-parallel surface: mesh/tp_axis keywords on the prepare
    # entry points, the shard-count property, and the EP budget keywords —
    # all keyword-only / defaulted so single-device callers never change.
    from repro.distributed.expert_parallel import apply_moe_ep
    from repro.kernels.ops import DslotWeights, dslot_prepare
    from repro.models.model_zoo import Model

    prep = inspect.signature(dslot_prepare).parameters
    assert {"mesh", "tp_axis"} <= set(prep)
    assert prep["mesh"].default is None
    assert prep["tp_axis"].default == "model"
    assert prep["mesh"].kind is inspect.Parameter.KEYWORD_ONLY

    pd = inspect.signature(Model.prepare_dslot).parameters
    assert list(pd) == ["self", "params", "mesh", "tp_axis"]
    assert pd["mesh"].default is None

    ep = inspect.signature(apply_moe_ep).parameters
    assert {"expert_planes", "n_bits"} <= set(ep)
    assert ep["expert_planes"].default is None and ep["n_bits"].default == 8

    assert {"mesh", "tp_axis"} <= set(
        f.name for f in DslotWeights.__dataclass_fields__.values())
    assert DslotWeights.tp_shards.fget is not None      # property exists


def test_generate_result_fields_pinned():
    assert {f.name for f in GenerateResult.__dataclass_fields__.values()} == {
        "tokens", "n_planes", "planes_used_mean", "skipped_frac",
        "planes_bounded_mean", "ttft_steps", "steps", "phase", "uid", "tier"}


# ------------------------------------------------------- deprecation shims

@pytest.fixture(scope="module")
def lm():
    from repro.configs.registry import ARCHS
    from repro.models.model_zoo import build_model

    cfg = ARCHS["olmo-1b"].reduced()
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def test_legacy_engine_kwargs_shim_warns_once(lm):
    model, params = lm
    engine_mod._LEGACY_WARNED.discard("ServeEngine.kwargs")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        eng = ServeEngine(model, params, n_slots=2, max_len=32)
        ServeEngine(model, params, serve_config=ServeConfig(
            n_slots=1, max_len=32))
    assert len([w for w in rec
                if issubclass(w.category, DeprecationWarning)]) == 1
    # the shim maps onto a real config — behaviour, not just acceptance
    assert eng.cfg.n_slots == 2 and eng.cfg.max_len == 32
    assert eng.serve_config is eng.cfg        # back-compat alias
    r = Request(uid=1, prompt=np.asarray([1, 2, 3], np.int32), max_new=2)
    assert eng.try_add(r)
    while not r.done:
        eng.step()
    assert len(r.out) == 2 and r.result.phase == "done"


def test_mixing_cfg_and_legacy_kwargs_rejected(lm):
    model, params = lm
    with pytest.raises(TypeError, match="not both"):
        ServeEngine(model, params, ServeConfig(), n_slots=2)


def test_generate_return_stats_shim(lm):
    model, params = lm
    batch = {"tokens": jnp.asarray([[1, 2, 3]], jnp.int32)}
    engine_mod._LEGACY_WARNED.discard("generate.return_stats")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        toks, stats = generate(model, params, batch, 2, return_stats=True)
        bare = generate(model, params, batch, 2, return_stats=False)
    assert len([w for w in rec
                if issubclass(w.category, DeprecationWarning)]) == 1
    assert toks.shape == (1, 2) and stats == {}       # non-DSLOT: empty
    assert bare.shape == (1, 2)
    res = generate(model, params, batch, 2)
    assert isinstance(res, GenerateResult)
    np.testing.assert_array_equal(np.asarray(res.tokens), np.asarray(toks))
