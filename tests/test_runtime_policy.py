"""Runtime precision-policy subsystem: policies, context threading, and the
adaptive feedback loop."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.runtime import (AdaptiveBudget, Fixed, PerLayerSchedule,
                           PolicyFeedback, current_precision,
                           precision_scope)


def test_precision_scope_nesting_and_default():
    assert current_precision("x", 8) == 8
    with precision_scope(4):
        assert current_precision("x", 8) == 4
        with precision_scope(2):
            assert current_precision("x", 8) == 2
        assert current_precision("x", 8) == 4
    assert current_precision("x", 8) == 8


def test_precision_scope_dict_and_wildcard():
    with precision_scope({"conv1": 6, "*": 3}):
        assert current_precision("conv1", 8) == 6
        assert current_precision("dense1", 8) == 3
    with precision_scope({"conv1": 6}):
        assert current_precision("dense1", 8) == 8   # falls through
    with precision_scope(None):
        assert current_precision("anything", 7) == 7


def test_fixed_and_per_layer_schedule():
    assert Fixed(5).next_precision() == 5
    sched = PerLayerSchedule({"conv1": 8, "dense1": 4}, default=6)
    got = sched.next_precision()
    assert got["conv1"] == 8 and got["dense1"] == 4 and got["*"] == 6
    sched.observe(PolicyFeedback(8, 8.0, 0.0))       # no-op


def test_adaptive_budget_closes_the_loop():
    pol = AdaptiveBudget(plane_budget=4.0, min_planes=2, max_planes=8,
                         ema=1.0)   # ema=1: react fully to each observation
    # dense workload: every granted plane is executed -> throttle to budget
    pol.observe(PolicyFeedback(n_planes=8, planes_used_mean=8.0,
                               skipped_frac=0.0))
    assert pol.next_precision() == 4
    # sparse workload: early termination skips half -> earn more precision
    pol.observe(PolicyFeedback(n_planes=4, planes_used_mean=2.0,
                               skipped_frac=0.5))
    assert pol.next_precision() == 8
    # bounds respected
    pol.observe(PolicyFeedback(n_planes=8, planes_used_mean=8.0,
                               skipped_frac=0.0))
    pol.plane_budget = 0.5
    assert pol.next_precision() == 2


def test_layers_read_precision_scope():
    from repro.layers import DslotDense

    layer = DslotDense(32, 32, name="scoped", block_m=16, block_n=16)
    params = layer.init(jax.random.PRNGKey(0))
    x = jnp.maximum(jax.random.normal(jax.random.PRNGKey(1), (16, 32)), 0)
    y8, _ = layer.apply(params, x)
    with precision_scope(2):
        y2, st2 = layer.apply(params, x)
    with precision_scope({"scoped": 2}):
        y2d, _ = layer.apply(params, x)
    with precision_scope({"other": 2}):
        y_other, _ = layer.apply(params, x)
    assert float(jnp.abs(y8 - y2).max()) > 0
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(y2d))
    np.testing.assert_array_equal(np.asarray(y8), np.asarray(y_other))
    # explicit argument beats the scope
    with precision_scope(2):
        y8e, _ = layer.apply(params, x, n_planes=8)
    np.testing.assert_array_equal(np.asarray(y8), np.asarray(y8e))
