"""Processing engine: eq.(6) cycle schedule + SOP bit-exactness."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import fixed_to_sd, pe_schedule, pe_sop_digits, sd_to_value


def test_eq6_paper_example():
    """Paper §II-B.2: k=5, N=1, p_out=21  ->  33 cycles."""
    s = pe_schedule(k=5, n_fmaps=1, p_mult=16)
    assert s.p_out == 21
    assert s.tree_stages == 5
    assert s.total_cycles == 33
    assert s.pipeline_fill == 2 + 2 * 5


@pytest.mark.parametrize("k,n_fmaps,p_mult,expected", [
    (3, 1, 16, 2 + 2 * 4 + (16 + 4)),          # ceil(log2 9) = 4
    (5, 4, 16, 2 + 2 * 5 + 2 * 2 + (16 + 5)),  # fmap stages = 2
    (7, 1, 16, 2 + 2 * 6 + (16 + 6)),
])
def test_eq6_general(k, n_fmaps, p_mult, expected):
    assert pe_schedule(k=k, n_fmaps=n_fmaps, p_mult=p_mult).total_cycles \
        == expected


@pytest.mark.parametrize("k", [3, 5])
def test_pe_sop_bit_exact(k):
    rng = np.random.default_rng(k)
    sch = pe_schedule(k=k, p_mult=16)
    taps = k * k
    xq = rng.integers(0, 128, size=(taps, 24))
    wq = rng.integers(-127, 128, size=(taps,))
    xd = fixed_to_sd(jnp.asarray(xq), 8)
    wf = jnp.asarray(wq / 256.0, jnp.float32)[:, None]
    sop = pe_sop_digits(xd, wf, sch)
    assert sop.shape[0] == sch.p_out
    S = sch.tree_stages + sch.fmap_stages
    got = np.asarray(sd_to_value(sop)) * 2.0 ** (16 + S)
    np.testing.assert_allclose(got, (xq * wq[:, None]).sum(0), atol=1e-3)


def test_cycle_of_digit():
    s = pe_schedule(k=5, p_mult=16)
    assert s.cycle_of_digit(1) == s.pipeline_fill + 1
    assert s.cycle_of_digit(s.p_out) == s.total_cycles
