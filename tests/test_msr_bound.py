"""Weight-side MSR static plane bound: exactness, trimming, backend mirror.

The bound (``DslotWeights.msr_bound``, from ``core.msr.tile_plane_bound``)
is a pure work-saving: ``dslot_prepare`` only emits output-exact per-tile
caps (exactly-zero tiles in every mode; all-non-positive tiles under
unsigned+ReLU), so execution with the bound must be bit-identical to
execution without it at every precision — the property test sweeps
``(n_bits, n_planes, signed, relu)``.  The deterministic tests pin the
pallas kernel (SMEM per-j bound scalar) against the jnp replay, assert the
bound actually trims ``planes_used`` on near-zero weight tiles, and pin
the mechanism itself with an injected partial bound table (any (Nt,)
values — the exact-only policy lives in prepare, not in the kernels).
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.msr import (msr_depths, msr_histogram, quantize_weights,
                            tile_plane_bound)
from repro.kernels.ops import dslot_execute, dslot_matmul, dslot_prepare

from _hyp import given, settings, st


def _weights_with_inert_tiles(rng, K, N):
    """Weights with exactly-zero and all-non-positive column runs."""
    w = rng.normal(size=(K, N)).astype(np.float32)
    w[:, N // 4: N // 2] = 0.0
    w[:, 3 * N // 4:] = -np.abs(w[:, 3 * N // 4:])
    return w


@settings(max_examples=24, deadline=None)
@given(n_bits=st.integers(2, 8), rel_planes=st.integers(1, 8),
       signed=st.booleans(), relu=st.booleans(), seed=st.integers(0, 2**16))
def test_bound_bit_exact_every_mode(n_bits, rel_planes, signed, relu, seed):
    """Outputs with the static bound == without, at every (n_bits,
    n_planes, signed/unsigned, relu) combination — the exactness contract
    of ``dslot_prepare(msr_bound=True)``."""
    n_planes = min(rel_planes, n_bits)
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(6, 16)).astype(np.float32)
    if not signed:
        x = np.abs(x)
    w = jnp.asarray(_weights_with_inert_tiles(rng, 16, 8))
    kw = dict(n_bits=n_bits, relu=relu, signed=signed, block_m=2,
              block_n=2, backend="jnp")
    yb, sb = dslot_execute(dslot_prepare(w, **kw), jnp.asarray(x),
                           n_planes=n_planes)
    yu, su = dslot_execute(dslot_prepare(w, msr_bound=False, **kw),
                           jnp.asarray(x), n_planes=n_planes)
    np.testing.assert_array_equal(np.asarray(yb), np.asarray(yu))
    # the bound can only reduce issued planes, never add
    assert int(jnp.sum(sb.planes_used)) <= int(jnp.sum(su.planes_used))
    assert int(jnp.sum(su.planes_bounded)) == 0


def test_bound_bit_exact_exhaustive_combos():
    """Deterministic exhaustive sweep of the same contract (runs even where
    hypothesis is unavailable): every (n_bits, n_planes, signed, relu)."""
    rng = np.random.default_rng(0)
    x_base = rng.normal(size=(6, 16)).astype(np.float32)
    w = jnp.asarray(_weights_with_inert_tiles(rng, 16, 8))
    for n_bits in (2, 4, 8):
        for n_planes in sorted({1, n_bits // 2, n_bits} - {0}):
            for signed in (False, True):
                for relu in (False, True):
                    x = jnp.asarray(x_base if signed else np.abs(x_base))
                    kw = dict(n_bits=n_bits, relu=relu, signed=signed,
                              block_m=2, block_n=2, backend="jnp")
                    yb, sb = dslot_execute(dslot_prepare(w, **kw), x,
                                           n_planes=n_planes)
                    yu, _ = dslot_execute(
                        dslot_prepare(w, msr_bound=False, **kw), x,
                        n_planes=n_planes)
                    np.testing.assert_array_equal(
                        np.asarray(yb), np.asarray(yu),
                        err_msg=f"{n_bits=} {n_planes=} {signed=} {relu=}")
                    assert int(jnp.sum(sb.planes_bounded)) > 0


def test_pallas_jnp_mirror_with_bound():
    """Same inputs, both backends, bound active: identical outputs AND
    identical per-tile planes_used / planes_bounded accounting."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(np.abs(rng.normal(size=(8, 16))).astype(np.float32))
    w = jnp.asarray(_weights_with_inert_tiles(rng, 16, 8))
    kw = dict(n_bits=8, relu=True, signed=False, block_m=4, block_n=2)
    pj = dslot_prepare(w, backend="jnp", **kw)
    pp = dslot_prepare(w, backend="pallas", **kw)
    np.testing.assert_array_equal(np.asarray(pj.msr_bound),
                                  np.asarray(pp.msr_bound))
    for npl in (8, 5, jnp.asarray([1, 8, 2, 8, 3, 8, 4, 6], jnp.int32)):
        yj, sj = dslot_execute(pj, x, n_planes=npl)
        yp, sp = dslot_execute(pp, x, n_planes=npl)
        np.testing.assert_array_equal(np.asarray(yj), np.asarray(yp))
        np.testing.assert_array_equal(np.asarray(sj.planes_used),
                                      np.asarray(sp.planes_used))
        np.testing.assert_array_equal(np.asarray(sj.planes_bounded),
                                      np.asarray(sp.planes_bounded))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_bound_trims_planes_on_near_zero_tiles(backend):
    """Near-zero weight tiles: without the bound the non-relu path runs all
    planes; with it, exactly-zero tiles are never issued at all."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(np.abs(rng.normal(size=(4, 16))).astype(np.float32))
    w = rng.normal(size=(16, 8)).astype(np.float32)
    w[:, 2:6] = 0.0                               # tiles 1 and 2 at bn=2
    kw = dict(n_bits=8, relu=False, signed=False, block_m=4, block_n=2,
              backend=backend)
    pb = dslot_prepare(jnp.asarray(w), **kw)
    pu = dslot_prepare(jnp.asarray(w), msr_bound=False, **kw)
    assert list(np.asarray(pb.msr_bound)) == [8, 0, 0, 8]
    yb, sb = dslot_execute(pb, x)
    yu, su = dslot_execute(pu, x)
    np.testing.assert_array_equal(np.asarray(yb), np.asarray(yu))
    assert np.asarray(sb.planes_used)[:, 1:3].max() == 0
    assert np.asarray(su.planes_used).min() == 8   # relu off: all planes run
    assert np.asarray(sb.planes_bounded)[:, 1:3].min() == 8
    # skipped_frac accounts the weight-side savings (compounding contract)
    assert float(sb.skipped_frac) > float(su.skipped_frac)


def test_injected_partial_bound_mechanism():
    """The kernels honour ANY (Nt,) bound table (mechanism), even partial
    caps prepare's exact-only policy would never emit: per-tile planes_used
    == min(bound, granted) on a non-relu run, pallas == jnp."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(np.abs(rng.normal(size=(4, 16))).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    table = jnp.asarray([0, 3, 5, 8], jnp.int32)
    outs = []
    for backend in ("jnp", "pallas"):
        p = dslot_prepare(w, n_bits=8, relu=False, block_m=4, block_n=2,
                          backend=backend)
        p = dataclasses.replace(p, msr_bound=table)
        y, st_ = dslot_execute(p, x)
        assert np.asarray(st_.planes_used).tolist() == [[0, 3, 5, 8]]
        assert np.asarray(st_.planes_bounded).tolist() == [[8, 5, 3, 0]]
        outs.append(np.asarray(y))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_fused_path_grid_trim_on_global_bound():
    """The fused one-shot path trims its STATIC plane axis when every
    column is weight-side inert (clamped to one plane), and stays at full
    depth otherwise."""
    x = jnp.asarray(np.abs(np.random.default_rng(0).normal(
        size=(4, 16))).astype(np.float32))
    y0, st0 = dslot_matmul(x, jnp.zeros((16, 8)), block_m=4, block_n=2,
                           backend="jnp")
    assert st0.n_planes == 1
    assert float(jnp.abs(y0).max()) == 0.0
    w = jnp.asarray(np.random.default_rng(1).normal(
        size=(16, 8)).astype(np.float32))
    _, st1 = dslot_matmul(x, w, block_m=4, block_n=2, backend="jnp")
    assert st1.n_planes == 8


def test_tile_plane_bound_rules():
    """Exact-only policy: zero tiles bound 0 always; non-positive tiles
    bound 0 only under unsigned+ReLU; everything else full depth."""
    rng = np.random.default_rng(5)
    w = np.zeros((8, 8), np.float32)
    w[:, 0:2] = rng.normal(size=(8, 2))
    w[:, 4:6] = -np.abs(rng.normal(size=(8, 2)))
    w = jnp.asarray(w)                             # tiles: mixed, 0, -, 0
    b = tile_plane_bound(w, 2, n_bits=8, relu=True, signed=False)
    assert list(np.asarray(b)) == [8, 0, 0, 0]
    for relu, signed in ((True, True), (False, False), (False, True)):
        b = tile_plane_bound(w, 2, n_bits=8, relu=relu, signed=signed)
        assert list(np.asarray(b)) == [8, 0, 8, 0], (relu, signed)


def test_msr_depths_and_histogram():
    """MSR depth = n_bits - bitlength(|w_q|) (SNIPPETS definition) and the
    MSR-N fractions are a valid cumulative distribution."""
    d = msr_depths(jnp.asarray([0, 1, -1, 7, 8, 127, -127], jnp.int32), 8)
    assert list(np.asarray(d)) == [8, 7, 7, 5, 4, 1, 1]
    w = jnp.asarray(np.random.default_rng(2).normal(
        size=(32, 32)).astype(np.float32) * 0.05)
    h = msr_histogram(w, 8)
    assert sum(h["depth_counts"]) == 32 * 32
    ge = [h["msr_ge"][k] for k in ("3", "4", "5", "6")]
    assert all(0.0 <= f <= 1.0 for f in ge)
    assert ge == sorted(ge, reverse=True)          # cumulative: MSR-3 >= MSR-4
    # quantize_weights maps max|w| to the qmax bucket (depth 1)
    q = quantize_weights(w, 8)
    assert int(jnp.max(jnp.abs(q))) == 127
