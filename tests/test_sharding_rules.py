"""Sharding-rule unit tests (no multi-device needed: rules are pure)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.train.sharding import param_pspec, sanitize_spec


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def _spec(path_str, shape):
    class L:
        pass
    leaf = L()
    leaf.ndim = len(shape)
    leaf.shape = shape
    path = tuple(type("K", (), {"key": k})() for k in path_str.split("/"))
    return param_pspec(path, leaf)(("data",), "model")


def test_attention_rules():
    assert _spec("decoder/rest/0/attn/wq/w", (512, 512)) == P(("data",),
                                                              "model")
    assert _spec("decoder/rest/0/attn/wo/w", (512, 512)) == P("model",
                                                              ("data",))
    assert _spec("decoder/rest/0/attn/wq/b", (512,)) == P("model")


def test_stacked_group_rules_shift():
    assert _spec("decoder/groups/0/attn/wq/w", (8, 512, 512)) == \
        P(None, ("data",), "model")
    assert _spec("decoder/groups/0/mlp/down/w", (8, 2048, 512)) == \
        P(None, "model", ("data",))


def test_moe_and_mixer_rules():
    assert _spec("decoder/groups/0/moe/up", (8, 4, 64, 128)) == \
        P(None, None, ("data",), "model")
    assert _spec("decoder/rest/0/mixer/w_in", (512, 1024)) == \
        P(("data",), "model")
    assert _spec("decoder/rest/0/mixer/A_log", (16,)) == P(None)


def test_norm_replicated():
    assert _spec("decoder/rest/0/norm1/scale", (512,)) == P(None)
    assert _spec("final_norm/scale", (512,)) == P(None)


def test_sanitize_drops_nondivisible():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # granite's odd vocab: model axis cannot shard 49155
    assert sanitize_spec(mesh, P("model", ("data",)), (49155, 1024)) == \
        P(None, ("data",))
    assert sanitize_spec(mesh, P("model", ("data",)), (49152, 1024)) == \
        P("model", ("data",))
    assert sanitize_spec(mesh, P(("data",), "model"), (8, 512)) == \
        P(None, "model")
