"""Docs stay honest: links resolve, code fences at least parse.

The CI ``docs`` job additionally EXECUTES the import-bearing fences
(``tools/check_docs.py`` without ``--no-exec``); here we keep the fast
invariants in tier-1 so a broken docs change fails locally too.
"""

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_docs  # noqa: E402


def test_docs_exist():
    for name in ("architecture.md", "serving.md", "kernel.md"):
        assert (ROOT / "docs" / name).is_file(), name


def test_internal_links_resolve():
    assert check_docs.check_links() == []


def test_fences_parse():
    assert check_docs.check_fences(run=False) == []


def test_docs_have_runnable_fences():
    """Each doc must carry at least one fence the CI job will execute —
    otherwise the 'docs code runs' guarantee is vacuous."""
    for name in ("architecture.md", "serving.md", "kernel.md"):
        fences = check_docs.extract_fences(ROOT / "docs" / name)
        runnable = [1 for _, info, code in fences
                    if check_docs._is_python(info)
                    and check_docs._should_exec(info, code)]
        assert runnable, f"{name} has no executable python fence"
