"""Multi-device correctness (8 host devices, spawned subprocesses so the
XLA device-count override never leaks into other tests) + single-process
fault-tolerance / compression / straggler logic."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.distributed.compression import (compressed_ratio, init_ef_state,
                                           int8_compress, int8_decompress,
                                           topk_compress, topk_decompress)
from repro.distributed.fault_tolerance import StragglerMonitor

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_dist(code: str, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=_REPO)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


# ----------------------------------------------------------- compression

def test_int8_compression_error_feedback():
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(0, 1, (64, 64)), jnp.float32)}
    ef = init_ef_state(grads)
    acc = jnp.zeros((64, 64))
    true = jnp.zeros((64, 64))
    for _ in range(20):
        payload, ef = int8_compress(grads, ef)
        acc = acc + int8_decompress(payload)["w"]
        true = true + grads["w"]
    # error feedback keeps the long-run average unbiased
    rel = float(jnp.abs(acc - true).max() / jnp.abs(true).max())
    assert rel < 0.01, rel
    assert compressed_ratio(grads, payload[0]) < 0.3


def test_topk_compression():
    rng = np.random.default_rng(1)
    grads = {"w": jnp.asarray(rng.normal(0, 1, (128,)), jnp.float32)}
    ef = init_ef_state(grads)
    payload, ef = topk_compress(grads, ef, frac=0.1)
    dec = topk_decompress(payload, grads)
    # kept entries are the largest; dropped mass lives in the residual
    assert int(jnp.sum(dec["w"] != 0)) <= 13
    np.testing.assert_allclose(
        np.asarray(dec["w"] + ef.residual["w"]), np.asarray(grads["w"]),
        atol=1e-6)


def test_straggler_monitor():
    mon = StragglerMonitor(n_ranks=16, factor=1.5, patience=3)
    t = np.full(16, 1.0)
    for _ in range(2):
        assert mon.observe(t) == []
    t[5] = 4.0                                   # rank 5 goes slow
    flagged = []
    for _ in range(10):
        flagged = mon.observe(t)
    assert flagged == [5]


# ----------------------------------------------------------- 8-device

@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    run_dist("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.registry import ARCHS
        from repro.models.model_zoo import build_model
        from repro.models import pspec
        from repro.optim.adamw import AdamWConfig
        from repro.train.step import init_train_state, make_train_step
        from repro.train.sharding import make_param_shardings, make_batch_shardings
        from repro.data.pipeline import TokenPipeline

        cfg = ARCHS["olmo-1b"].reduced()
        model = build_model(cfg)
        state = init_train_state(model, jax.random.PRNGKey(0))
        opt = AdamWConfig(peak_lr=1e-3, warmup_steps=0, decay_steps=10)
        pipe = TokenPipeline(vocab=cfg.vocab_size, seq_len=16,
                             global_batch=8, microbatches=2)
        batch = jax.tree.map(jnp.asarray, pipe.next_host_batch())

        # single-device reference
        s1, m1 = jax.jit(make_train_step(model, opt))(state, batch)

        # 8-device (4 data x 2 model)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        pspec.set_mesh(mesh)
        psh = make_param_shardings(mesh, state.params)
        ssh = type(state)(params=psh,
                          opt=type(state.opt)(
                              m=make_param_shardings(mesh, state.opt.m),
                              v=make_param_shardings(mesh, state.opt.v),
                              count=NamedSharding(mesh, P())),
                          step=NamedSharding(mesh, P()))
        bsh = make_batch_shardings(mesh, batch, 8, batch_axis=1)
        with mesh:
            step = jax.jit(make_train_step(model, opt),
                           in_shardings=(ssh, bsh))
            s8, m8 = step(state, batch)
        assert abs(float(m1["loss"]) - float(m8["loss"])) < 2e-3, \\
            (float(m1["loss"]), float(m8["loss"]))
        diffs = [float(jnp.abs(a.astype(jnp.float32) -
                               b.astype(jnp.float32)).max())
                 for a, b in zip(jax.tree.leaves(s1.params),
                                 jax.tree.leaves(s8.params))]
        assert max(diffs) < 5e-2, max(diffs)
        print("sharded==single OK", float(m1["loss"]))
    """)


@pytest.mark.slow
def test_collective_matmul_equivalence():
    run_dist("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.distributed.overlap import collective_matmul_ag, plain_matmul_ag
        mesh = jax.make_mesh((8,), ("model",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(0, 1, (64, 32)), jnp.float32)
        w = jnp.asarray(rng.normal(0, 1, (32, 48)), jnp.float32)
        y1 = collective_matmul_ag(x, w, mesh)
        y2 = plain_matmul_ag(x, w, mesh)
        y3 = x @ w
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y3), atol=1e-3)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(y3), atol=1e-3)
        print("collective matmul OK")
    """)


@pytest.mark.slow
def test_expert_parallel_equivalence():
    run_dist("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro.configs.granite_moe_1b_a400m import CONFIG
        from repro.models.moe import apply_moe, init_moe
        from repro.distributed.expert_parallel import apply_moe_ep
        cfg = dataclasses.replace(CONFIG.reduced(), n_experts=8, top_k=2)
        p = init_moe(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                              jnp.float32) * 0.5
        y_ref, aux_ref = apply_moe(p, x, cfg)
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        y_ep, aux_ep = apply_moe_ep(p, x, cfg, mesh)
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                                   atol=2e-3)
        assert abs(float(aux_ep) - float(aux_ref)) < 1e-3
        print("EP MoE OK")
    """)


@pytest.mark.slow
def test_expert_parallel_2way_model_mesh_and_plane_budgets():
    # EP under a small 2-way model mesh (built through make_test_mesh, the
    # same helper the TP serving path uses), plus the per-expert digit-
    # plane budget surface: full budgets are an exact no-op (bitwise equal
    # to the budget-less call), truncated budgets change the output but
    # stay finite and within quantization distance of the dense forward.
    run_dist("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro.configs.granite_moe_1b_a400m import CONFIG
        from repro.models.moe import apply_moe, init_moe
        from repro.distributed.expert_parallel import apply_moe_ep
        from repro.launch.mesh import make_test_mesh

        cfg = dataclasses.replace(CONFIG.reduced(), n_experts=8, top_k=2)
        p = init_moe(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                              jnp.float32) * 0.5
        mesh = make_test_mesh(n_devices=2, model=2)

        y_ref, aux_ref = apply_moe(p, x, cfg)
        y_ep, aux_ep = apply_moe_ep(p, x, cfg, mesh)
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                                   atol=2e-3)
        assert abs(float(aux_ep) - float(aux_ref)) < 1e-3

        # full per-expert budgets: exact no-op vs the budget-less call
        full = jnp.full((cfg.n_experts,), 8, jnp.int32)
        y_full, _ = apply_moe_ep(p, x, cfg, mesh, expert_planes=full)
        np.testing.assert_array_equal(np.asarray(y_full), np.asarray(y_ep))

        # truncated budgets: deterministic, finite, near the dense forward,
        # and actually different from the full-precision output
        lo = jnp.asarray([3, 8, 4, 8, 3, 8, 4, 8], jnp.int32)
        y_lo, _ = apply_moe_ep(p, x, cfg, mesh, expert_planes=lo)
        y_lo2, _ = apply_moe_ep(p, x, cfg, mesh, expert_planes=lo)
        assert np.isfinite(np.asarray(y_lo)).all()
        np.testing.assert_array_equal(np.asarray(y_lo), np.asarray(y_lo2))
        assert not np.array_equal(np.asarray(y_lo), np.asarray(y_ep))
        np.testing.assert_allclose(np.asarray(y_lo), np.asarray(y_ref),
                                   atol=0.25)
        print("EP 2-way + budgets OK")
    """)


@pytest.mark.slow
def test_resilient_training_with_elastic_restart():
    run_dist("""
        import numpy as np, jax, jax.numpy as jnp, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.registry import ARCHS
        from repro.models.model_zoo import build_model
        from repro.models import pspec
        from repro.optim.adamw import AdamWConfig
        from repro.train.step import init_train_state, make_train_step
        from repro.train.sharding import make_param_shardings, make_batch_shardings
        from repro.data.pipeline import TokenPipeline
        from repro.checkpoint.checkpointer import Checkpointer
        from repro.distributed.fault_tolerance import (NodeFailure,
                                                       ResilientTrainer)

        cfg = ARCHS["olmo-1b"].reduced()
        model = build_model(cfg)
        opt = AdamWConfig(peak_lr=1e-3, warmup_steps=0, decay_steps=100)
        pipe = TokenPipeline(vocab=cfg.vocab_size, seq_len=16,
                             global_batch=8, microbatches=1)
        batches = [jax.tree.map(jnp.asarray, pipe.next_host_batch())
                   for _ in range(30)]

        def make(n_lost):
            # elastic: lose a node -> drop from 8 devices to 4
            ndev = 8 if n_lost == 0 else 4
            mesh = jax.make_mesh((ndev // 2, 2), ("data", "model"))
            pspec.set_mesh(mesh)
            state0 = jax.eval_shape(lambda: init_train_state(
                model, jax.random.PRNGKey(0)))
            psh = make_param_shardings(mesh, state0.params)
            ssh = type(state0)(params=psh,
                               opt=type(state0.opt)(
                                   m=make_param_shardings(mesh, state0.opt.m),
                                   v=make_param_shardings(mesh, state0.opt.v),
                                   count=NamedSharding(mesh, P())),
                               step=NamedSharding(mesh, P()))
            with mesh:
                step = jax.jit(make_train_step(model, opt),
                               in_shardings=(ssh, None))
            def place(b):
                return b
            return mesh, ssh, step, place

        state = init_train_state(model, jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            tr = ResilientTrainer(checkpointer=Checkpointer(d),
                                  make_mesh_and_step=make, ckpt_every=5)
            state, rep = tr.run(state, lambda s: batches[s], 25,
                                inject={12: NodeFailure("host 3 died",
                                                        lost_nodes=1)})
        assert rep.steps_done == 25
        assert rep.restarts == 1 and rep.reshards == 1
        assert np.isfinite(rep.losses).all()
        print("resilient training OK:", rep.restarts, "restart,",
              len(rep.losses), "step-losses")
    """)
