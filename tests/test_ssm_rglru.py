"""Recurrent mixers: SSD chunked == sequential oracle; RG-LRU scan == step."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st  # hypothesis or skip-shim

from repro.configs.mamba2_780m import CONFIG as MAMBA
from repro.configs.recurrentgemma_2b import CONFIG as RG
from repro.models.rglru import apply_rglru, init_rglru
from repro.models.ssm import (SSMState, apply_ssm, init_ssm, ssd_chunked,
                              ssd_sequential)


@pytest.mark.parametrize("s,chunk", [(16, 4), (33, 8), (64, 16), (7, 16)])
def test_ssd_chunked_equals_sequential(s, chunk):
    key = jax.random.PRNGKey(s)
    ks = jax.random.split(key, 5)
    b, h, p, n = 2, 3, 8, 16
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, n)) * 0.5
    y1, h1 = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    y2, h2 = ssd_sequential(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4)


def test_ssd_initial_state_propagation():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    b, s, h, p, n = 1, 24, 2, 4, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    B = jax.random.normal(ks[3], (b, s, n)) * 0.4
    C = jax.random.normal(ks[4], (b, s, n)) * 0.4
    yf, hf = ssd_chunked(x, dt, A, B, C, chunk=8)
    # split at 12: run first half, feed state into second half
    y1, h1 = ssd_chunked(x[:, :12], dt[:, :12], A, B[:, :12], C[:, :12],
                         chunk=8)
    y2, h2 = ssd_chunked(x[:, 12:], dt[:, 12:], A, B[:, 12:], C[:, 12:],
                         chunk=8, init_state=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(yf), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(hf), atol=2e-4)


def test_mamba_block_decode_consistency():
    cfg = MAMBA.reduced()
    model_p = init_ssm(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model)) * 0.2
    yf, _ = apply_ssm(model_p, x, cfg, return_state=True)
    y0, st = apply_ssm(model_p, x[:, :6], cfg, return_state=True)
    ys = [y0]
    for t in range(6, 12):
        yt, st = apply_ssm(model_p, x[:, t:t + 1], cfg, state=st,
                           return_state=True)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(yf), atol=3e-3)


def test_rglru_decode_consistency():
    cfg = RG.reduced()
    p = init_rglru(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, cfg.d_model)) * 0.3
    yf, _ = apply_rglru(p, x, cfg, return_state=True)
    y0, st = apply_rglru(p, x[:, :5], cfg, return_state=True)
    ys = [y0]
    for t in range(5, 10):
        yt, st = apply_rglru(p, x[:, t:t + 1], cfg, state=st,
                             return_state=True)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(yf), atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6))
def test_rglru_stability_property(seed):
    """|a_t| < 1 by construction -> bounded states for bounded inputs."""
    cfg = RG.reduced()
    p = init_rglru(cfg, jax.random.PRNGKey(seed % 2 ** 31))
    x = jax.random.normal(jax.random.PRNGKey(seed % 97), (1, 64, cfg.d_model))
    y, st = apply_rglru(p, x, cfg, return_state=True)
    assert bool(jnp.isfinite(y).all())
    assert float(jnp.abs(st.h).max()) < 1e3


# ----------------------------------------------- pad-masked ragged batches

def _q_valid(lens, S):
    return jnp.arange(S, dtype=jnp.int32)[None] \
        < jnp.asarray(lens, jnp.int32)[:, None]


def _trim_state(state, b, L):
    """The reference: the same row run alone, unpadded."""
    return jax.tree.map(lambda leaf: leaf[b:b + 1], state)


@pytest.mark.parametrize("sequential", [True, False])
def test_ssm_padded_stack_equals_trimmed_rows(sequential):
    """Pad positions are identity steps of the SSD recurrence (dt = 0 ->
    decay 1, zero update) and the conv tail gathers each row's last VALID
    inputs: a padded stacked forward must equal each row's solo trimmed
    forward — valid outputs AND carried state.  The sequential scan is
    bit-exact; the chunked path re-partitions when widths differ, so it
    gets a tight allclose."""
    cfg = MAMBA.reduced()
    p = init_ssm(cfg, jax.random.PRNGKey(0))
    S, lens = 12, (12, 7, 1)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (len(lens), S, cfg.d_model)) * 0.2
    y, st = apply_ssm(p, x, cfg, return_state=True, sequential=sequential,
                      q_valid=_q_valid(lens, S))
    for b, L in enumerate(lens):
        yr, str_ = apply_ssm(p, x[b:b + 1, :L], cfg, return_state=True,
                             sequential=sequential)
        got_y = np.asarray(y[b:b + 1, :L])
        got_st = [np.asarray(l) for l in jax.tree.leaves(
            jax.tree.map(lambda leaf: leaf[b:b + 1], st))]
        ref_st = [np.asarray(l) for l in jax.tree.leaves(str_)]
        if sequential:
            assert np.array_equal(got_y, np.asarray(yr)), (b, L)
            for g, r in zip(got_st, ref_st):
                assert np.array_equal(g, r), (b, L)
        else:
            np.testing.assert_allclose(got_y, np.asarray(yr),
                                       atol=1e-5, rtol=1e-5)
            for g, r in zip(got_st, ref_st):
                np.testing.assert_allclose(g, r, atol=1e-5, rtol=1e-5)


def test_ssm_zero_length_row_carries_state_unchanged():
    """A zero-length (idle lane) row's carried state must pass through a
    padded forward bitwise untouched."""
    cfg = MAMBA.reduced()
    p = init_ssm(cfg, jax.random.PRNGKey(0))
    x0 = jax.random.normal(jax.random.PRNGKey(2), (2, 6, cfg.d_model)) * 0.2
    _, st0 = apply_ssm(p, x0, cfg, return_state=True)
    x1 = jax.random.normal(jax.random.PRNGKey(3), (2, 5, cfg.d_model)) * 0.2
    _, st1 = apply_ssm(p, x1, cfg, state=st0, return_state=True,
                       q_valid=_q_valid((5, 0), 5))
    for got, ref in zip(jax.tree.leaves(st1), jax.tree.leaves(st0)):
        assert np.array_equal(np.asarray(got)[1:], np.asarray(ref)[1:])


def test_rglru_padded_stack_equals_trimmed_rows():
    """Pads are (a, b) = (1, 0) identity elements of the RG-LRU linear
    recurrence — masking the GATES, not just r (b would keep its 1e-6
    floor) — so a padded stacked forward matches each row's solo trimmed
    forward, valid outputs and carried (conv, h) state."""
    cfg = RG.reduced()
    p = init_rglru(cfg, jax.random.PRNGKey(0))
    S, lens = 11, (11, 4, 1)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (len(lens), S, cfg.d_model)) * 0.3
    y, st = apply_rglru(p, x, cfg, return_state=True,
                        q_valid=_q_valid(lens, S))
    for b, L in enumerate(lens):
        yr, str_ = apply_rglru(p, x[b:b + 1, :L], cfg, return_state=True)
        np.testing.assert_allclose(np.asarray(y[b:b + 1, :L]),
                                   np.asarray(yr), atol=1e-5, rtol=1e-5)
        for got, ref in zip(jax.tree.leaves(
                jax.tree.map(lambda leaf: leaf[b:b + 1], st)),
                jax.tree.leaves(str_)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       atol=1e-5, rtol=1e-5)


def test_rglru_zero_length_row_carries_state_unchanged():
    cfg = RG.reduced()
    p = init_rglru(cfg, jax.random.PRNGKey(0))
    x0 = jax.random.normal(jax.random.PRNGKey(2), (2, 6, cfg.d_model)) * 0.3
    _, st0 = apply_rglru(p, x0, cfg, return_state=True)
    x1 = jax.random.normal(jax.random.PRNGKey(3), (2, 4, cfg.d_model)) * 0.3
    _, st1 = apply_rglru(p, x1, cfg, state=st0, return_state=True,
                         q_valid=_q_valid((4, 0), 4))
    for got, ref in zip(jax.tree.leaves(st1), jax.tree.leaves(st0)):
        assert np.array_equal(np.asarray(got)[1:], np.asarray(ref)[1:])


@settings(max_examples=8, deadline=None)
@given(st.data())
def test_hyp_recurrent_padded_equals_trimmed(data):
    """Property (derandomized profile): padded stacked forward == per-row
    trimmed forward for both recurrent mixers across lengths and widths."""
    kind = data.draw(st.sampled_from(["ssm", "rglru"]), label="kind")
    S = data.draw(st.integers(2, 16), label="S")
    n_rows = data.draw(st.integers(1, 3), label="rows")
    lens = tuple(data.draw(st.integers(0, S), label=f"len{i}")
                 for i in range(n_rows))
    cfg = (MAMBA if kind == "ssm" else RG).reduced()
    init = init_ssm if kind == "ssm" else init_rglru
    apply = apply_ssm if kind == "ssm" else apply_rglru
    p = init(cfg, jax.random.PRNGKey(7))
    x = jax.random.normal(jax.random.PRNGKey(S), (n_rows, S, cfg.d_model)) \
        * 0.2
    y, st = apply(p, x, cfg, return_state=True, q_valid=_q_valid(lens, S))
    fresh = jax.tree.map(lambda leaf: jnp.zeros_like(leaf[:1]),
                         st)  # zero init state reference for L == 0 rows
    for b, L in enumerate(lens):
        got_st = jax.tree.map(lambda leaf: leaf[b:b + 1], st)
        if L == 0:
            for g, r in zip(jax.tree.leaves(got_st), jax.tree.leaves(fresh)):
                assert np.array_equal(np.asarray(g), np.asarray(r)), (b, lens)
            continue
        yr, str_ = apply(p, x[b:b + 1, :L], cfg, return_state=True)
        np.testing.assert_allclose(np.asarray(y[b:b + 1, :L]),
                                   np.asarray(yr), atol=2e-5, rtol=2e-5)
        for g, r in zip(jax.tree.leaves(got_st), jax.tree.leaves(str_)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                       atol=2e-5, rtol=2e-5)
