"""Recurrent mixers: SSD chunked == sequential oracle; RG-LRU scan == step."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st  # hypothesis or skip-shim

from repro.configs.mamba2_780m import CONFIG as MAMBA
from repro.configs.recurrentgemma_2b import CONFIG as RG
from repro.models.rglru import apply_rglru, init_rglru
from repro.models.ssm import (SSMState, apply_ssm, init_ssm, ssd_chunked,
                              ssd_sequential)


@pytest.mark.parametrize("s,chunk", [(16, 4), (33, 8), (64, 16), (7, 16)])
def test_ssd_chunked_equals_sequential(s, chunk):
    key = jax.random.PRNGKey(s)
    ks = jax.random.split(key, 5)
    b, h, p, n = 2, 3, 8, 16
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, n)) * 0.5
    y1, h1 = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    y2, h2 = ssd_sequential(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4)


def test_ssd_initial_state_propagation():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    b, s, h, p, n = 1, 24, 2, 4, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    B = jax.random.normal(ks[3], (b, s, n)) * 0.4
    C = jax.random.normal(ks[4], (b, s, n)) * 0.4
    yf, hf = ssd_chunked(x, dt, A, B, C, chunk=8)
    # split at 12: run first half, feed state into second half
    y1, h1 = ssd_chunked(x[:, :12], dt[:, :12], A, B[:, :12], C[:, :12],
                         chunk=8)
    y2, h2 = ssd_chunked(x[:, 12:], dt[:, 12:], A, B[:, 12:], C[:, 12:],
                         chunk=8, init_state=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(yf), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(hf), atol=2e-4)


def test_mamba_block_decode_consistency():
    cfg = MAMBA.reduced()
    model_p = init_ssm(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model)) * 0.2
    yf, _ = apply_ssm(model_p, x, cfg, return_state=True)
    y0, st = apply_ssm(model_p, x[:, :6], cfg, return_state=True)
    ys = [y0]
    for t in range(6, 12):
        yt, st = apply_ssm(model_p, x[:, t:t + 1], cfg, state=st,
                           return_state=True)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(yf), atol=3e-3)


def test_rglru_decode_consistency():
    cfg = RG.reduced()
    p = init_rglru(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, cfg.d_model)) * 0.3
    yf, _ = apply_rglru(p, x, cfg, return_state=True)
    y0, st = apply_rglru(p, x[:, :5], cfg, return_state=True)
    ys = [y0]
    for t in range(5, 10):
        yt, st = apply_rglru(p, x[:, t:t + 1], cfg, state=st,
                             return_state=True)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(yf), atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6))
def test_rglru_stability_property(seed):
    """|a_t| < 1 by construction -> bounded states for bounded inputs."""
    cfg = RG.reduced()
    p = init_rglru(cfg, jax.random.PRNGKey(seed % 2 ** 31))
    x = jax.random.normal(jax.random.PRNGKey(seed % 97), (1, 64, cfg.d_model))
    y, st = apply_rglru(p, x, cfg, return_state=True)
    assert bool(jnp.isfinite(y).all())
    assert float(jnp.abs(st.h).max()) < 1e3
