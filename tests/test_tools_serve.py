"""Dry-run tooling (HLO cost model, collective parser, specs) + serving."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo
from repro.configs.registry import ARCHS
from repro.models.model_zoo import build_model
from repro.serve.engine import Request, ServeEngine, generate


def test_hlo_cost_counts_scan_trip_counts():
    """cost_analysis() counts while bodies once (the bug this module fixes);
    analyze_hlo must multiply by known_trip_count."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(x, x).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] == pytest.approx(2 * 128 ** 3, rel=0.01)  # body once
    t = analyze_hlo(compiled.as_text())
    assert t["dot_flops"] == 2 * 128 ** 3 * 10                   # corrected


def test_hlo_cost_nested_scans():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    t = analyze_hlo(jax.jit(g).lower(x, x).compile().as_text())
    assert t["dot_flops"] == 2 * 64 ** 3 * 15


def test_hlo_cost_counts_vector_and_bytes():
    def f(a, b):
        return jnp.tanh(a) + b

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    t = analyze_hlo(jax.jit(f).lower(a, a).compile().as_text())
    assert t["dot_flops"] == 0
    assert t["vector_flops"] >= 256 * 256              # at least the add
    # pure elementwise work has no compulsory (dot-side) traffic, but the
    # upper-bound model must see the 2 reads + 1 write
    assert t["hbm_bytes_upper"] >= 3 * 256 * 256 * 4
    assert t["hbm_bytes"] <= t["hbm_bytes_upper"]


def test_input_specs_cover_all_cells():
    from repro.configs.registry import SHAPES, live_cells
    from repro.launch import dryrun

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    for arch_name, shape_name in live_cells():
        arch = ARCHS[arch_name]
        shape = SHAPES[shape_name]
        specs = dryrun.input_specs(arch, shape, FakeMesh())
        assert "tokens" in specs
        for v in jax.tree.leaves(specs):
            assert all(d > 0 for d in v.shape)


# ------------------------------------------------------------- serving

def test_generate_shapes_and_determinism():
    cfg = ARCHS["qwen2.5-3b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray([[1, 2, 3, 4], [4, 3, 2, 1]], jnp.int32)}
    out1 = generate(model, params, batch, 6)
    out2 = generate(model, params, batch, 6)
    assert out1.tokens.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1.tokens),
                                  np.asarray(out2.tokens))
    assert out1.stats == {}                      # non-DSLOT: no plane stats


def test_generate_matches_stepwise_decode():
    cfg = ARCHS["olmo-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    toks = jnp.asarray([[5, 6, 7, 8]], jnp.int32)
    out = generate(model, params, {"tokens": toks}, 4).tokens
    # manual loop
    logits, st = model.prefill(params, {"tokens": toks}, max_len=8)
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    manual = []
    for _ in range(4):
        manual.append(int(cur[0]))
        lg, st = model.decode_step(params, st, cur[:, None])
        cur = jnp.argmax(lg, -1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(out[0]), manual)


def test_serve_engine_slots():
    cfg = ARCHS["olmo-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    eng = ServeEngine(model, params, n_slots=2, max_len=32)
    r1 = Request(uid=1, prompt=np.asarray([1, 2, 3], np.int32), max_new=4)
    r2 = Request(uid=2, prompt=np.asarray([4, 5, 6], np.int32), max_new=2)
    assert eng.try_add(r1) and eng.try_add(r2)
    done = []
    for _ in range(8):
        done += eng.step()
    assert {r.uid for r in done} == {1, 2}
    assert len(r1.out) == 4 and len(r2.out) == 2
    # finished slots are reusable
    r3 = Request(uid=3, prompt=np.asarray([7, 8, 9], np.int32), max_new=1)
    assert eng.try_add(r3)


def test_serve_engine_staggered_admissions_match_solo():
    """Regression for the pool-shared position counter: a request admitted
    into a NON-empty pool must not disturb other slots' decode positions —
    every request's tokens must exactly match a solo ``generate`` run."""
    cfg = ARCHS["olmo-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    eng = ServeEngine(model, params, n_slots=3, max_len=32)
    prompts = [np.asarray([1, 2, 3], np.int32),
               np.asarray([7, 8, 9, 10], np.int32),
               np.asarray([5, 5], np.int32)]
    reqs = [Request(uid=i, prompt=p, max_new=5)
            for i, p in enumerate(prompts)]
    assert eng.try_add(reqs[0])
    eng.step()                                # pool mid-decode...
    assert eng.try_add(reqs[1])               # ...staggered admission
    eng.step()
    eng.step()
    assert eng.try_add(reqs[2])               # deeper stagger
    done = []
    for _ in range(12):
        done += eng.step()
    assert {r.uid for r in done} == {0, 1, 2}
    for req, prompt in zip(reqs, prompts):
        solo = generate(model, params, {"tokens": jnp.asarray(prompt[None])},
                        5)
        assert req.out == list(np.asarray(solo.tokens[0])), req.uid


def _dslot_model(key=4):
    import dataclasses
    from repro.configs.base import DslotConfig

    cfg = dataclasses.replace(
        ARCHS["olmo-1b"].reduced(), act="relu", glu=False,
        dslot=DslotConfig(enabled=True, block_m=16, block_n=32, block_k=16))
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(key))


def test_serve_engine_dslot_per_request_precision():
    """DSLOT serving mode: per-request digit-plane budgets execute in one
    pooled step, and every finished request carries its planes-executed
    account."""
    model, params = _dslot_model()
    eng = ServeEngine(model, params, n_slots=2, max_len=32)
    assert eng.dslot
    ra = Request(uid=1, prompt=np.asarray([1, 2, 3], np.int32), max_new=3,
                 n_planes=8)
    rb = Request(uid=2, prompt=np.asarray([4, 5, 6], np.int32), max_new=3,
                 n_planes=3)
    assert eng.try_add(ra) and eng.try_add(rb)
    done = []
    for _ in range(4):
        done += eng.step()
    assert {r.uid for r in done} == {1, 2}
    for r in (ra, rb):
        assert r.dslot_stats is not None
        assert r.dslot_stats["n_planes"] == r.n_planes
        assert 0 < r.dslot_stats["planes_used_mean"] <= r.n_planes
        assert 0.0 <= r.dslot_stats["skipped_frac"] < 1.0
    # low-precision request executed strictly fewer planes
    assert rb.dslot_stats["planes_used_mean"] < \
        ra.dslot_stats["planes_used_mean"] + 1e-6 and \
        rb.dslot_stats["planes_used_mean"] <= 3.0


def test_serve_engine_dslot_policy_assignment_and_feedback():
    from repro.runtime import AdaptiveBudget

    model, params = _dslot_model(key=5)
    pol = AdaptiveBudget(plane_budget=4.0, min_planes=2, max_planes=8,
                         ema=1.0)
    eng = ServeEngine(model, params, n_slots=1, max_len=32,
                      precision_policy=pol)
    r = Request(uid=1, prompt=np.asarray([1, 2], np.int32), max_new=2)
    assert eng.try_add(r)
    assert r.n_planes == pol.max_planes or r.n_planes >= pol.min_planes
    while not r.done:
        eng.step()
    assert pol.last_feedback is not None          # loop closed
    assert pol.last_feedback.n_planes == r.n_planes


def test_serve_engine_accepts_per_layer_schedule_policy():
    """PerLayerSchedule.next_precision() returns a dict — the engine must
    flatten it to the MLP budget, not crash on int(dict)."""
    from repro.runtime import PerLayerSchedule

    model, params = _dslot_model(key=7)
    pol = PerLayerSchedule({"mlp_up_dslot": 3}, default=6)
    eng = ServeEngine(model, params, n_slots=1, max_len=32,
                      precision_policy=pol)
    r = Request(uid=1, prompt=np.asarray([1, 2], np.int32), max_new=2)
    assert eng.try_add(r)
    assert r.n_planes == 3
    while not r.done:
        eng.step()
    assert r.dslot_stats["planes_used_mean"] <= 3.0


def test_generate_default_precision_stats_budget():
    """With no explicit n_planes, skipped_frac must be measured against the
    precision the layers actually ran at (cfg.dslot.n_planes), not n_bits."""
    import dataclasses
    from repro.configs.base import DslotConfig

    cfg = dataclasses.replace(
        ARCHS["olmo-1b"].reduced(), act="relu", glu=False,
        dslot=DslotConfig(enabled=True, n_planes=4, block_m=16, block_n=32,
                          block_k=16))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(8))
    batch = {"tokens": jnp.asarray([[1, 2, 3]], jnp.int32)}
    toks, stats = generate(model, params, batch, 2, return_stats=True)
    used = float(stats["planes_used_mean"][0])
    skipped = float(stats["skipped_frac"][0])
    assert used <= 4.0 + 1e-6
    # no early termination at this scale -> used == 4 and skipped ~ 0, not
    # the 0.5 that dividing by n_bits=8 would report
    assert abs(skipped - (1.0 - used / 4.0)) < 1e-6


def test_generate_dslot_stats_per_request():
    model, params = _dslot_model(key=6)
    batch = {"tokens": jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)}
    toks, stats = generate(model, params, batch, 3,
                           n_planes=jnp.asarray([8, 2], jnp.int32),
                           return_stats=True)
    assert toks.shape == (2, 3)
    used = np.asarray(stats["planes_used_mean"])
    assert used.shape == (2,)
    assert used[1] <= 2.0 + 1e-6 < used[0]
    # default call returns the unified result with the same account
    res = generate(model, params, batch, 3,
                   n_planes=jnp.asarray([8, 2], jnp.int32))
    assert res.tokens.shape == (2, 3)
    np.testing.assert_array_equal(np.asarray(res.tokens), np.asarray(toks))
    np.testing.assert_allclose(np.asarray(res.planes_used_mean), used,
                               rtol=1e-6)
