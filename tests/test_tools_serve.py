"""Dry-run tooling (HLO cost model, collective parser, specs) + serving."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo
from repro.configs.registry import ARCHS
from repro.models.model_zoo import build_model
from repro.serve.engine import Request, ServeEngine, generate


def test_hlo_cost_counts_scan_trip_counts():
    """cost_analysis() counts while bodies once (the bug this module fixes);
    analyze_hlo must multiply by known_trip_count."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(x, x).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] == pytest.approx(2 * 128 ** 3, rel=0.01)  # body once
    t = analyze_hlo(compiled.as_text())
    assert t["dot_flops"] == 2 * 128 ** 3 * 10                   # corrected


def test_hlo_cost_nested_scans():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    t = analyze_hlo(jax.jit(g).lower(x, x).compile().as_text())
    assert t["dot_flops"] == 2 * 64 ** 3 * 15


def test_hlo_cost_counts_vector_and_bytes():
    def f(a, b):
        return jnp.tanh(a) + b

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    t = analyze_hlo(jax.jit(f).lower(a, a).compile().as_text())
    assert t["dot_flops"] == 0
    assert t["vector_flops"] >= 256 * 256              # at least the add
    # pure elementwise work has no compulsory (dot-side) traffic, but the
    # upper-bound model must see the 2 reads + 1 write
    assert t["hbm_bytes_upper"] >= 3 * 256 * 256 * 4
    assert t["hbm_bytes"] <= t["hbm_bytes_upper"]


def test_input_specs_cover_all_cells():
    from repro.configs.registry import SHAPES, live_cells
    from repro.launch import dryrun

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    for arch_name, shape_name in live_cells():
        arch = ARCHS[arch_name]
        shape = SHAPES[shape_name]
        specs = dryrun.input_specs(arch, shape, FakeMesh())
        assert "tokens" in specs
        for v in jax.tree.leaves(specs):
            assert all(d > 0 for d in v.shape)


# ------------------------------------------------------------- serving

def test_generate_shapes_and_determinism():
    cfg = ARCHS["qwen2.5-3b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray([[1, 2, 3, 4], [4, 3, 2, 1]], jnp.int32)}
    out1 = generate(model, params, batch, 6)
    out2 = generate(model, params, batch, 6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_generate_matches_stepwise_decode():
    cfg = ARCHS["olmo-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    toks = jnp.asarray([[5, 6, 7, 8]], jnp.int32)
    out = generate(model, params, {"tokens": toks}, 4)
    # manual loop
    logits, st = model.prefill(params, {"tokens": toks}, max_len=8)
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    manual = []
    for _ in range(4):
        manual.append(int(cur[0]))
        lg, st = model.decode_step(params, st, cur[:, None])
        cur = jnp.argmax(lg, -1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(out[0]), manual)


def test_serve_engine_slots():
    cfg = ARCHS["olmo-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    eng = ServeEngine(model, params, n_slots=2, max_len=32)
    r1 = Request(uid=1, prompt=np.asarray([1, 2, 3], np.int32), max_new=4)
    r2 = Request(uid=2, prompt=np.asarray([4, 5, 6], np.int32), max_new=2)
    assert eng.try_add(r1) and eng.try_add(r2)
    done = []
    for _ in range(8):
        done += eng.step()
    assert {r.uid for r in done} == {1, 2}
    assert len(r1.out) == 4 and len(r2.out) == 2
    # finished slots are reusable
    r3 = Request(uid=3, prompt=np.asarray([7, 8, 9], np.int32), max_new=1)
    assert eng.try_add(r3)
