"""Chunked-prefill admission pipeline: lifecycle, edge cases, exactness.

The bar for everything here is the PR 2 regression contract: whatever the
admission pipeline does, every request's emitted tokens must exactly match
a solo ``generate`` run of the same prompt.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hyp import given, st  # hypothesis or skip-shim
from repro.configs.registry import ARCHS
from repro.models.model_zoo import build_model
from repro.serve import (DECODING, PENDING, PREFILLING, Request, ServeConfig,
                         ServeEngine, generate)


@pytest.fixture(scope="module")
def lm():
    cfg = ARCHS["olmo-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    return cfg, model, params


# swa / ssm / rglru — the three stacks the old pipeline kept out of the
# batched lanes.  One reduced model each, shared across the module.
ZOO_ARCHS = ("h2o-danube-3-4b", "mamba2-780m", "recurrentgemma-2b")


@pytest.fixture(scope="module")
def zoo():
    out = {}
    for name in ZOO_ARCHS:
        cfg = ARCHS[name].reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(5))
        out[name] = (cfg, model, params)
    return out


_ZOO_SOLO: dict = {}     # keyed (arch, len, seed, max_new); zoo fixture only


def _zoo_solo(arch, model, params, n, seed, max_new):
    key = (arch, n, seed, max_new)
    if key not in _ZOO_SOLO:
        _ZOO_SOLO[key] = _solo(model, params, _prompt(n, seed=seed), max_new)
    return _ZOO_SOLO[key]


def _prompt(n, seed=0):
    return np.random.RandomState(seed).randint(0, 256, size=n).astype(np.int32)


def _solo(model, params, prompt, n):
    return list(np.asarray(generate(
        model, params, {"tokens": jnp.asarray(prompt[None])}, n).tokens[0]))


_SOLO_CACHE: dict = {}     # keyed (len, seed, max_new); lm fixture only


def _solo_cached(model, params, n, seed, max_new):
    key = (n, seed, max_new)
    if key not in _SOLO_CACHE:
        _SOLO_CACHE[key] = _solo(model, params, _prompt(n, seed=seed),
                                 max_new)
    return _SOLO_CACHE[key]


def _drive(eng, reqs, arrivals, max_steps=200):
    """Step the engine, admitting each request at its arrival step, until
    every request finishes."""
    for step in range(max_steps):
        for r, a in zip(reqs, arrivals):
            if a == step:
                assert eng.try_add(r)
        eng.step()
        if all(r.done for r in reqs):
            return
    raise AssertionError(f"requests not drained in {max_steps} steps")


# ------------------------------------------------------------- edge cases

def test_prompt_shorter_than_one_chunk(lm):
    """A prompt that fits one chunk admits in a single tick and matches the
    one-shot prefill path exactly (it IS the one-shot path)."""
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=1, max_len=32,
                      serve_config=ServeConfig(prefill_chunk=16))
    p = _prompt(3)
    r = Request(uid=1, prompt=p, max_new=4)
    assert eng.try_add(r)
    assert r.phase == PENDING
    while not r.done:
        eng.step()
    assert r.out == _solo(model, params, p, 4)
    assert r.ttft_steps == 1                       # admitted + decoded step 1


def test_prompt_not_multiple_of_chunk(lm):
    """13 tokens at chunk 5 -> chunks of 5/5/3; the ragged tail must land at
    the right offsets and stay token-exact."""
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=1, max_len=64,
                      serve_config=ServeConfig(prefill_chunk=5))
    p = _prompt(13, seed=1)
    r = Request(uid=1, prompt=p, max_new=5)
    assert eng.try_add(r)
    eng.step()
    assert r.phase == PREFILLING and r.out == []   # chunk 1 of 3 in flight
    eng.step()
    assert r.phase == PREFILLING and r.out == []
    eng.step()                                     # last chunk lands ...
    assert r.phase == DECODING and len(r.out) == 1  # ... decodable SAME step
    while not r.done:
        eng.step()
    assert r.out == _solo(model, params, p, 5)
    assert r.ttft_steps == 3                       # ceil(13 / 5) chunks


def test_chunk_exact_multiple_boundary(lm):
    """Prompt length an exact multiple of the chunk (no ragged tail)."""
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=1, max_len=64,
                      serve_config=ServeConfig(prefill_chunk=4))
    p = _prompt(8, seed=2)
    r = Request(uid=1, prompt=p, max_new=4)
    assert eng.try_add(r)
    while not r.done:
        eng.step()
    assert r.out == _solo(model, params, p, 4)
    assert r.ttft_steps == 2


def test_slot_freed_mid_prefill(lm):
    """Cancelling an in-flight prefill frees its reserved slot without ever
    having touched the pool; the next admission into that slot is exact."""
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=1, max_len=64,
                      serve_config=ServeConfig(prefill_chunk=4))
    victim = Request(uid=1, prompt=_prompt(12, seed=3), max_new=3)
    assert eng.try_add(victim)
    eng.step()                                     # chunk 1 of 3 in flight
    assert eng.slot_phases() == [PREFILLING]
    assert eng.cancel(1)
    assert victim.phase == "cancelled" and eng.slot_phases() == ["free"]
    p = _prompt(9, seed=4)
    r = Request(uid=2, prompt=p, max_new=4)
    assert eng.try_add(r)
    while not r.done:
        eng.step()
    assert r.out == _solo(model, params, p, 4)


def test_mid_prefill_cancel_does_not_disturb_live_slot(lm):
    """A decode-live slot must be unaffected by a neighbouring prefill that
    is started and then abandoned mid-flight."""
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=2, max_len=64,
                      serve_config=ServeConfig(prefill_chunk=4))
    p = _prompt(6, seed=5)
    live = Request(uid=1, prompt=p, max_new=8)
    assert eng.try_add(live)
    eng.step(); eng.step()                         # live and decoding
    assert eng.try_add(Request(uid=2, prompt=_prompt(12, seed=6), max_new=3))
    eng.step()                                     # uid 2 mid-prefill
    assert eng.slot_phases()[1] == PREFILLING
    assert eng.cancel(2)
    while not live.done:
        eng.step()
    assert live.out == _solo(model, params, p, 8)


def test_full_pool_burst_drains_fifo(lm):
    """More requests than slots, enqueued at once: the queue must drain in
    FIFO order as slots free, every request exact."""
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=2, max_len=64,
                      serve_config=ServeConfig(prefill_chunk=8))
    prompts = [_prompt(4 + i, seed=10 + i) for i in range(6)]
    reqs = [Request(uid=i, prompt=p, max_new=3) for i, p in enumerate(prompts)]
    for r in reqs:
        assert eng.try_add(r)
    assert eng.queue_depth == 6
    order = []
    for _ in range(40):
        for r in eng.step():
            order.append(r.uid)
        if len(order) == 6:
            break
    assert order == [0, 1, 2, 3, 4, 5]             # FIFO admission = FIFO done
    assert eng.queue_depth == 0
    for r, p in zip(reqs, prompts):
        assert r.out == _solo(model, params, p, 3), r.uid


def test_staggered_chunked_admissions_match_solo(lm):
    """The PR 2 staggered-admission bar, now with multi-chunk prompts: a
    long prompt trickling in chunk-by-chunk must not disturb slots that are
    decoding, and must itself come out token-exact."""
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=3, max_len=64,
                      serve_config=ServeConfig(prefill_chunk=4))
    prompts = [_prompt(3, seed=20), _prompt(11, seed=21), _prompt(6, seed=22)]
    reqs = [Request(uid=i, prompt=p, max_new=5)
            for i, p in enumerate(prompts)]
    assert eng.try_add(reqs[0])
    eng.step()                                     # slot 0 decoding
    assert eng.try_add(reqs[1])                    # 3-chunk prompt
    eng.step(); eng.step()
    assert eng.try_add(reqs[2])                    # stagger deeper
    done = []
    for _ in range(15):
        done += eng.step()
    assert {r.uid for r in done} == {0, 1, 2}
    for r, p in zip(reqs, prompts):
        assert r.out == _solo(model, params, p, 5), r.uid


def test_admission_budget_is_one_chunk_per_step(lm):
    """With two queued requests, admission work is serialized: one chunk per
    step, FIFO — the second prompt does not start until the first lands."""
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=2, max_len=64,
                      serve_config=ServeConfig(prefill_chunk=4))
    a = Request(uid=1, prompt=_prompt(8, seed=30), max_new=2)
    b = Request(uid=2, prompt=_prompt(4, seed=31), max_new=2)
    assert eng.try_add(a) and eng.try_add(b)
    eng.step()                                     # a: chunk 1/2
    assert a.phase == PREFILLING and b.phase == PENDING
    eng.step()                                     # a: chunk 2/2 -> decoding
    assert a.phase == DECODING and b.phase == PENDING
    eng.step()                                     # b admits
    assert b.phase == DECODING
    while not (a.done and b.done):
        eng.step()
    assert a.out == _solo(model, params, a.prompt, 2)
    assert b.out == _solo(model, params, b.prompt, 2)


def test_chunks_per_step_two_does_not_double_book_a_slot(lm):
    """Regression: with chunks_per_step >= 2, a task completing mid-tick
    must not have its slot handed to the next queued request before the
    engine merges it (the second merge would orphan the first request)."""
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=1, max_len=64,
                      serve_config=ServeConfig(prefill_chunk=4,
                                               chunks_per_step=2))
    a = Request(uid=1, prompt=_prompt(4, seed=60), max_new=2)
    b = Request(uid=2, prompt=_prompt(4, seed=61), max_new=2)
    assert eng.try_add(a) and eng.try_add(b)
    done = []
    for _ in range(10):
        done += eng.step()
        if a.done and b.done:
            break
    assert a.done and b.done
    assert {r.uid for r in done} == {1, 2}
    assert a.out == _solo(model, params, a.prompt, 2)
    assert b.out == _solo(model, params, b.prompt, 2)


def test_cancel_decoding_request_is_terminal(lm):
    """cancel() of a DECODING request must set done (phase 'cancelled') so
    ``while not req.done`` driving loops exit."""
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=1, max_len=32,
                      serve_config=ServeConfig(prefill_chunk=8))
    r = Request(uid=1, prompt=_prompt(3, seed=62), max_new=8)
    assert eng.try_add(r)
    eng.step(); eng.step()
    assert r.phase == DECODING
    assert eng.cancel(1)
    assert r.done and r.phase == "cancelled"
    assert eng.slot_phases() == ["free"]


def test_max_queue_bound(lm):
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=1, max_len=32,
                      serve_config=ServeConfig(prefill_chunk=8, max_queue=2))
    assert eng.try_add(Request(uid=1, prompt=_prompt(3), max_new=2))
    assert eng.try_add(Request(uid=2, prompt=_prompt(3), max_new=2))
    assert not eng.try_add(Request(uid=3, prompt=_prompt(3), max_new=2))
    eng.step()                                     # uid 1 admits + decodes
    assert eng.try_add(Request(uid=3, prompt=_prompt(3), max_new=2))


def test_swa_chunked_admission_token_exact(zoo):
    """Regression for the retired SWA whole-prompt fallback: sliding-window
    rings now extend chunk-by-chunk (each chunk attends against the carried
    pre-write ring, so recycling can never evict a live in-window key) and
    the chunked admission stays token-exact."""
    cfg, model, params = zoo["h2o-danube-3-4b"]       # window = 32 reduced
    eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_len=48,
                                                 prefill_chunk=4))
    assert eng.pipeline.chunk == 4                    # no fallback to 0
    p = _prompt(10, seed=50)
    r = Request(uid=1, prompt=p, max_new=4)
    assert eng.try_add(r)
    eng.step()
    assert r.phase == PREFILLING                      # chunk 1 of 3 in flight
    while not r.done:
        eng.step()
    assert r.ttft_steps == 3                          # ceil(10 / 4) chunks
    assert r.out == _solo(model, params, p, 4)


# ------------------------------------------------------------- validation

def test_try_add_rejects_overlong_request(lm):
    """Regression: prompt + max_new > max_len used to report success and
    corrupt the KV ring later; it must be rejected at enqueue."""
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=1, max_len=32)
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.try_add(Request(uid=1, prompt=_prompt(30), max_new=10))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.try_add(Request(uid=2, prompt=np.zeros(0, np.int32), max_new=4))
    with pytest.raises(ValueError, match="max_new"):
        eng.try_add(Request(uid=3, prompt=_prompt(4), max_new=0))
    # a valid request still admits after the rejections
    r = Request(uid=4, prompt=_prompt(4), max_new=2)
    assert eng.try_add(r)
    while not r.done:
        eng.step()
    assert r.out == _solo(model, params, r.prompt, 2)


# ------------------------------------------------------------- DSLOT mode

def test_chunked_admission_keeps_per_request_precision():
    """Per-request DSLOT plane budgets must apply to prefill chunks and
    pooled decode alike through chunked admission.

    ``act_scale`` is pinned: with the per-call ``jnp.max`` fallback the
    quantization step would depend on the token window each chunk sees, and
    chunked prefill could not be bit-equal to a one-shot prefill.  A fixed
    calibrated scale is the serving configuration anyway (no data-dependent
    max in the hot path)."""
    import dataclasses
    from repro.configs.base import DslotConfig

    cfg = dataclasses.replace(
        ARCHS["olmo-1b"].reduced(), act="relu", glu=False,
        dslot=DslotConfig(enabled=True, block_m=16, block_n=32, block_k=16,
                          act_scale=0.05))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    eng = ServeEngine(model, params, n_slots=2, max_len=64,
                      serve_config=ServeConfig(prefill_chunk=4))
    hi = Request(uid=1, prompt=_prompt(10, seed=40), max_new=3, n_planes=8)
    lo = Request(uid=2, prompt=_prompt(10, seed=41), max_new=3, n_planes=2)
    assert eng.try_add(hi) and eng.try_add(lo)
    done = []
    while len(done) < 2:
        done += eng.step()
    for r in (hi, lo):
        assert r.dslot_stats is not None
        assert r.dslot_stats["n_planes"] == r.n_planes
    assert lo.dslot_stats["planes_used_mean"] <= 2.0 + 1e-6
    # chunked admission at a runtime budget matches solo generate at the
    # same budget
    pp = model.prepare_dslot(params)
    solo = generate(model, pp, {"tokens": jnp.asarray(lo.prompt[None])}, 3,
                    n_planes=2)
    assert lo.out == list(np.asarray(solo.tokens[0]))
    # precision is a TRACED argument to the jitted batched chunk forward,
    # tokens are always padded to the fixed (lanes, chunk) shape and the
    # ragged tails ride in a traced lengths vector: every admission at every
    # precision and every tail length shares ONE compile, total
    assert eng.pipeline._extend_lanes._cache_size() == 1


def test_jitted_prefill_chunks_match_eager(lm):
    """ServeConfig.jit_prefill only changes how chunk forwards execute —
    token streams must be identical to the eager admission path."""
    _, model, params = lm
    outs = {}
    for jit in (True, False):
        eng = ServeEngine(model, params, n_slots=1, max_len=64,
                          serve_config=ServeConfig(prefill_chunk=5,
                                                   jit_prefill=jit))
        r = Request(uid=1, prompt=_prompt(13, seed=7), max_new=4)
        assert eng.try_add(r)
        while not r.done:
            eng.step()
        outs[jit] = r.out
    assert outs[True] == outs[False]


# ------------------------------------------------------- batched admission

def test_batched_admission_advances_two_requests_in_one_forward(lm):
    """The lifted batch-1 restriction, end to end: with chunks_per_step=2,
    two queued prompts PREFILL simultaneously — co-batched lanes, ONE model
    forward per tick for both — and still come out token-exact."""
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=2, max_len=64,
                      serve_config=ServeConfig(prefill_chunk=4,
                                               chunks_per_step=2))
    assert eng.pipeline.lanes == 2
    a = Request(uid=1, prompt=_prompt(12, seed=70), max_new=2)
    b = Request(uid=2, prompt=_prompt(10, seed=71), max_new=2)
    assert eng.try_add(a) and eng.try_add(b)
    f0 = eng.pipeline.forwards
    eng.step()
    # both in flight at once (the old pipeline held b PENDING until a
    # landed), and the tick spent exactly one forward on the pair
    assert a.phase == PREFILLING and b.phase == PREFILLING
    assert eng.pipeline.forwards == f0 + 1
    assert eng.slot_phases() == [PREFILLING, PREFILLING]
    while not (a.done and b.done):
        eng.step()
    assert a.out == _solo(model, params, a.prompt, 2)
    assert b.out == _solo(model, params, b.prompt, 2)


@pytest.mark.parametrize("lens,chunk,cps,arrivals", [
    ((9, 5, 13), 4, 3, (0, 0, 2)),     # ragged mix, one late arrival
    ((4, 4), 8, 2, (0, 1)),            # single-chunk prompts, staggered
    ((12, 3, 7, 5), 5, 4, (0, 0, 0, 0)),   # 4-wide burst, ragged tails
    ((6, 11), 3, 2, (0, 3)),           # second joins mid-prefill of first
    ((13, 13, 13), 4, 2, (0, 0, 0)),   # 3 requests through 2 lanes
])
def test_batched_ragged_admissions_match_solo(lm, lens, chunk, cps, arrivals):
    """Deterministic pin of the ragged-batch equivalence property: stacked
    prompts at ragged lengths/offsets, co-batched through the lane pool at
    staggered arrival steps, each token-exact vs a solo ``generate``."""
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=len(lens), max_len=32,
                      serve_config=ServeConfig(prefill_chunk=chunk,
                                               chunks_per_step=cps))
    reqs = [Request(uid=i, prompt=_prompt(n, seed=80 + i), max_new=3)
            for i, n in enumerate(lens)]
    _drive(eng, reqs, arrivals)
    for i, (r, n) in enumerate(zip(reqs, lens)):
        assert r.out == _solo_cached(model, params, n, 80 + i, 3), r.uid


@given(data=st.data())
def test_hyp_batched_chunked_admission_token_exact(lm, data):
    """Property: batched chunked admission is token-exact vs solo
    ``generate`` across ragged prompt lengths, chunk sizes,
    chunks_per_step in 1..4, and staggered arrival steps.  Example count
    and derandomization come from the loaded profile (tests/_hyp.py) so
    HYPOTHESIS_PROFILE=dev really deepens the search."""
    _, model, params = lm
    n_req = data.draw(st.integers(1, 4), label="n_req")
    chunk = data.draw(st.integers(1, 8), label="chunk")
    cps = data.draw(st.integers(1, 4), label="chunks_per_step")
    lens = [data.draw(st.integers(1, 13), label=f"len{i}")
            for i in range(n_req)]
    arrivals = sorted(data.draw(st.integers(0, 5), label=f"arrive{i}")
                      for i in range(n_req))
    eng = ServeEngine(model, params, n_slots=n_req, max_len=32,
                      serve_config=ServeConfig(prefill_chunk=chunk,
                                               chunks_per_step=cps))
    reqs = [Request(uid=i, prompt=_prompt(n, seed=90 + i), max_new=3)
            for i, n in enumerate(lens)]
    _drive(eng, reqs, arrivals)
    for i, (r, n) in enumerate(zip(reqs, lens)):
        assert r.out == _solo_cached(model, params, n, 90 + i, 3), \
            (r.uid, lens, chunk, cps, arrivals)


def test_cancel_cobatched_prefill_frees_lane_and_keeps_survivors_exact(lm):
    """Cancelling ONE co-batched PREFILLING request mid-batch: the freed
    lane (and pool slot) is claimable the very next tick, and the surviving
    requests' outputs are bit-identical to an unbatched (chunks_per_step=1)
    run of the same prompts."""
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=3, max_len=64,
                      serve_config=ServeConfig(prefill_chunk=4,
                                               chunks_per_step=3))
    a = Request(uid=1, prompt=_prompt(12, seed=100), max_new=3)
    b = Request(uid=2, prompt=_prompt(12, seed=101), max_new=3)
    c = Request(uid=3, prompt=_prompt(12, seed=102), max_new=3)
    assert eng.try_add(a) and eng.try_add(b) and eng.try_add(c)
    eng.step()
    assert [r.phase for r in (a, b, c)] == [PREFILLING] * 3   # co-batched
    victim_lane = next(t.lane for t in eng.pipeline.active if t.req is b)
    assert eng.cancel(2)
    assert b.done and b.phase == "cancelled"
    assert {t.req.uid for t in eng.pipeline.active} == {1, 3}
    # freed lane is reusable next tick by a fresh admission
    d = Request(uid=4, prompt=_prompt(9, seed=103), max_new=3)
    assert eng.try_add(d)
    eng.step()
    assert d.phase == PREFILLING
    assert next(t.lane for t in eng.pipeline.active
                if t.req is d) == victim_lane
    while not (a.done and c.done and d.done):
        eng.step()
    # bit-identical to an engine that admits one request at a time
    for r in (a, c, d):
        ref = ServeEngine(model, params, n_slots=1, max_len=64,
                          serve_config=ServeConfig(prefill_chunk=4,
                                                   chunks_per_step=1))
        rr = Request(uid=9, prompt=r.prompt, max_new=3)
        assert ref.try_add(rr)
        while not rr.done:
            ref.step()
        assert r.out == rr.out, r.uid
        assert r.out == _solo(model, params, r.prompt, 3), r.uid


def test_prefill_chunk_wider_than_ring_is_clamped(lm):
    """Regression: batched chunks are padded to the FULL chunk width, so a
    prefill_chunk wider than max_len would alias ring slots (pad phantoms
    overwriting real keys).  The pipeline must clamp the chunk to the ring
    capacity and stay token-exact."""
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=1, max_len=12,
                      serve_config=ServeConfig(prefill_chunk=40,
                                               chunks_per_step=2))
    assert eng.pipeline.chunk == 12
    p = _prompt(7, seed=130)
    r = Request(uid=1, prompt=p, max_new=4)
    assert eng.try_add(r)
    while not r.done:
        eng.step()
    assert r.out == _solo(model, params, p, 4)


def test_batched_more_requests_than_lanes_queue_fifo(lm):
    """5 requests through 2 lanes and 3 slots: lane reuse after completion
    keeps FIFO admission order and exactness."""
    _, model, params = lm
    eng = ServeEngine(model, params, n_slots=3, max_len=32,
                      serve_config=ServeConfig(prefill_chunk=4,
                                               chunks_per_step=2))
    reqs = [Request(uid=i, prompt=_prompt(5 + i, seed=110 + i), max_new=2)
            for i in range(5)]
    for r in reqs:
        assert eng.try_add(r)
    done = []
    for _ in range(40):
        done += eng.step()
        if len(done) == 5:
            break
    assert [r.uid for r in done] == [0, 1, 2, 3, 4]
    for i, r in enumerate(reqs):
        assert r.out == _solo(model, params, r.prompt, 2), r.uid


# ----------------------------------------------------------- hybrid tick

def test_hybrid_tick_spends_leftover_budget_on_head_task(lm):
    """A LONE admission must drain ``chunks_per_step`` sequential chunks
    per tick (the leftover lane budget goes to the head task), not one —
    and a full lane pool still gets one batched forward per chunk row."""
    _, model, params = lm
    eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_len=64,
                                                 prefill_chunk=4,
                                                 chunks_per_step=3))
    p = _prompt(12, seed=120)
    r = Request(uid=1, prompt=p, max_new=2)
    assert eng.try_add(r)
    f0 = eng.pipeline.forwards
    eng.step()
    # all ceil(12/4) = 3 chunks landed in ONE tick: 1 batched + 2 head
    assert r.phase == DECODING and r.ttft_steps == 1
    assert eng.pipeline.forwards == f0 + 3
    while not r.done:
        eng.step()
    assert r.out == _solo(model, params, p, 2)


def test_hybrid_tick_partial_pool_splits_budget(lm):
    """Two actives under chunks_per_step=3: the tick spends one batched
    forward on both, then one extra head chunk — FIFO head drains first,
    schedules never change the computed tokens."""
    _, model, params = lm
    eng = ServeEngine(model, params, ServeConfig(n_slots=2, max_len=64,
                                                 prefill_chunk=4,
                                                 chunks_per_step=3))
    a = Request(uid=1, prompt=_prompt(12, seed=121), max_new=2)
    b = Request(uid=2, prompt=_prompt(12, seed=122), max_new=2)
    assert eng.try_add(a) and eng.try_add(b)
    f0 = eng.pipeline.forwards
    eng.step()
    # batched forward (a+b, one chunk each) + 1 head chunk of a
    assert eng.pipeline.forwards == f0 + 2
    assert a.phase == PREFILLING and b.phase == PREFILLING
    offs = {t.req.uid: t.offset for t in eng.pipeline.active}
    assert offs == {1: 8, 2: 4}                    # head got the leftover
    while not (a.done and b.done):
        eng.step()
    assert a.out == _solo(model, params, a.prompt, 2)
    assert b.out == _solo(model, params, b.prompt, 2)


# ------------------------------------------------- swa / ssm / rglru lanes

@pytest.mark.parametrize("arch", ZOO_ARCHS)
def test_zoo_stack_batched_ragged_admission_token_exact(zoo, arch):
    """The tentpole, end to end per stack: ragged co-batched chunked
    admission on swa / ssm / rglru engines is token-exact vs solo
    ``generate`` — the lanes these stacks were locked out of."""
    cfg, model, params = zoo[arch]
    eng = ServeEngine(model, params, ServeConfig(n_slots=2, max_len=32,
                                                 prefill_chunk=4,
                                                 chunks_per_step=2))
    assert eng.pipeline.chunk == 4 and eng.pipeline.lanes == 2
    lens = (13, 7)
    reqs = [Request(uid=i, prompt=_prompt(n, seed=140 + i), max_new=3)
            for i, n in enumerate(lens)]
    assert all(eng.try_add(r) for r in reqs)
    eng.step()
    assert [r.phase for r in reqs] == [PREFILLING] * 2   # co-batched
    _drive(eng, reqs, (None, None))
    for i, (r, n) in enumerate(zip(reqs, lens)):
        assert r.out == _zoo_solo(arch, model, params, n, 140 + i, 3), r.uid


def test_swa_prefill_chunk_clamped_to_window(zoo):
    """SWA rings are only ``window`` wide: a wider chunk's pad phantoms
    would alias ring slots, so the pipeline clamps the chunk to the window
    (not max_len) and stays token-exact on prompts longer than the
    window."""
    cfg, model, params = zoo["h2o-danube-3-4b"]       # window = 32 reduced
    eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_len=48,
                                                 prefill_chunk=40))
    assert eng.pipeline.chunk == 32
    p = _prompt(40, seed=150)                         # prompt > window
    r = Request(uid=1, prompt=p, max_new=4)
    assert eng.try_add(r)
    while not r.done:
        eng.step()
    assert r.out == _solo(model, params, p, 4)


def test_swa_whole_prompt_longer_than_ring_rejected(zoo):
    """chunk == 0 runs the whole prompt as ONE chunk; under SWA the ring is
    only ``window`` wide, so an over-window prompt must be rejected at
    ``try_add`` with a clear error instead of silently wrapping — and an
    in-capacity prompt still admits exactly."""
    cfg, model, params = zoo["h2o-danube-3-4b"]       # window = 32 reduced
    eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_len=48,
                                                 prefill_chunk=0))
    with pytest.raises(ValueError, match="ring would wrap"):
        eng.try_add(Request(uid=1, prompt=_prompt(40, seed=151), max_new=4))
    p = _prompt(20, seed=152)
    r = Request(uid=2, prompt=p, max_new=4)
    assert eng.try_add(r)
    eng.step()
    assert r.phase == DECODING and r.ttft_steps == 1  # one-shot admission
    while not r.done:
        eng.step()
    assert r.out == _solo(model, params, p, 4)


def test_cancel_cobatched_recurrent_stack_survivors_exact(zoo):
    """Cancel-mid-batch on a RECURRENT stack: dropping one co-batched
    PREFILLING request must leave the survivors' carried ssm state — and
    therefore their token streams — bit-identical to an unbatched run."""
    arch = "mamba2-780m"
    cfg, model, params = zoo[arch]
    eng = ServeEngine(model, params, ServeConfig(n_slots=3, max_len=32,
                                                 prefill_chunk=4,
                                                 chunks_per_step=3))
    reqs = [Request(uid=i, prompt=_prompt(12, seed=160 + i), max_new=3)
            for i in range(3)]
    assert all(eng.try_add(r) for r in reqs)
    eng.step()
    assert [r.phase for r in reqs] == [PREFILLING] * 3   # co-batched
    assert eng.cancel(1)
    assert reqs[1].done and reqs[1].phase == "cancelled"
    survivors = [reqs[0], reqs[2]]
    while not all(r.done for r in survivors):
        eng.step()
    for i, r in zip((0, 2), survivors):
        # bit-identical to solo generate AND to a batch-1 engine run
        assert r.out == _zoo_solo(arch, model, params, 12, 160 + i, 3), r.uid
        ref = ServeEngine(model, params, ServeConfig(n_slots=1, max_len=32,
                                                     prefill_chunk=4))
        rr = Request(uid=9, prompt=r.prompt, max_new=3)
        assert ref.try_add(rr)
        while not rr.done:
            ref.step()
        assert r.out == rr.out, r.uid


@given(data=st.data())
def test_hyp_zoo_stacks_batched_admission_token_exact(zoo, data):
    """Property (derandomized profile): on every previously-gated stack
    (swa / ssm / rglru), batched ragged chunked admission is token-exact vs
    solo ``generate`` across prompt lengths × chunk × lanes × arrivals."""
    arch = data.draw(st.sampled_from(ZOO_ARCHS), label="arch")
    cfg, model, params = zoo[arch]
    n_req = data.draw(st.integers(1, 3), label="n_req")
    chunk = data.draw(st.integers(1, 8), label="chunk")
    cps = data.draw(st.integers(1, 3), label="chunks_per_step")
    lens = [data.draw(st.integers(1, 13), label=f"len{i}")
            for i in range(n_req)]
    arrivals = sorted(data.draw(st.integers(0, 4), label=f"arrive{i}")
                      for i in range(n_req))
    eng = ServeEngine(model, params, ServeConfig(n_slots=n_req, max_len=32,
                                                 prefill_chunk=chunk,
                                                 chunks_per_step=cps))
    reqs = [Request(uid=i, prompt=_prompt(n, seed=170 + i), max_new=3)
            for i, n in enumerate(lens)]
    _drive(eng, reqs, arrivals)
    for i, (r, n) in enumerate(zip(reqs, lens)):
        assert r.out == _zoo_solo(arch, model, params, n, 170 + i, 3), \
            (arch, r.uid, lens, chunk, cps, arrivals)
