"""Batched multi-token cache extension: the (B, S)-positions flash path.

PR 5 lifted the batch-1 restriction on multi-token cache extension
(``attention_forward`` S > 1 with a cache).  These tests pin the new
surface directly:

* the generic flash path with per-sequence 2-D positions against a dense
  per-sequence reference mask (causal, windowed, ring holes);
* 2-D positions broadcast from shared 1-D positions are bit-identical to
  the 1-D path (the serving pools rely on this);
* ragged extension's masked ring writes — a padded row's phantom positions
  can NEVER clobber live slots, even when they wrap the ring;
* the SWA carry-window extension: sliding-window stacks extend their rings
  chunk-by-chunk by attending against the carried pre-write ring alongside
  the chunk's own keys, so ring recycling can never evict a live in-window
  key — chunked extension matches whole-prompt prefill, and the ragged
  stacked SWA prefill builds each row's ring from its own last in-window
  keys (the per-row gather), not the padded batch's last columns.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS
from repro.models.attention import flash_attention
from repro.models.model_zoo import build_model


def _dense_ref(q, k, v, q_pos, k_pos, *, causal=True, window=0):
    """Unchunked softmax attention with an explicit per-sequence mask."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = (np.asarray(q, np.float32) * D ** -0.5).reshape(B, Sq, Hkv, G, D)
    s = np.einsum("bqhgd,bkhd->bqhgk", qg, np.asarray(k, np.float32))
    mask = (k_pos >= 0)[:, None, :]
    if causal:
        mask = mask & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        mask = mask & (k_pos[:, None, :] > q_pos[:, :, None] - window)
    s = np.where(mask[:, :, None, None, :], s, -1e30)
    m = s.max(axis=-1, keepdims=True)
    p = np.exp(s - m)
    out = np.einsum("bqhgk,bkhd->bqhgd", p, np.asarray(v, np.float32)) \
        / p.sum(axis=-1)[..., None]
    return out.reshape(B, Sq, Hq, D)


def _rand_qkv(rng, B, Sq, Sk, Hq=4, Hkv=2, D=8):
    q = rng.standard_normal((B, Sq, Hq, D)).astype(np.float32)
    k = rng.standard_normal((B, Sk, Hkv, D)).astype(np.float32)
    v = rng.standard_normal((B, Sk, Hkv, D)).astype(np.float32)
    return q, k, v


def test_flash_2d_positions_matches_dense_reference():
    """Per-sequence (B, Sq) query positions at ragged offsets against a
    ring-ordered KV set with holes (-1 slots), multiple scan chunks."""
    rng = np.random.default_rng(0)
    B, Sq, Sk = 3, 5, 16
    q, k, v = _rand_qkv(rng, B, Sq, Sk)
    offsets = np.asarray([0, 4, 9], np.int32)
    q_pos = offsets[:, None] + np.arange(Sq, dtype=np.int32)[None]
    # each row's ring: positions scattered mod Sk, with holes beyond the
    # row's own frontier (never-written slots = -1)
    k_pos = np.full((B, Sk), -1, np.int32)
    for b in range(B):
        frontier = int(offsets[b]) + Sq          # keys written so far
        for p in range(frontier):
            k_pos[b, p % Sk] = p
    out = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          jnp.asarray(q_pos), jnp.asarray(k_pos),
                          causal=True, window=0, chunk=4)
    ref = _dense_ref(q, k, v, q_pos, k_pos, causal=True, window=0)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-5, rtol=2e-5)


def test_flash_2d_positions_windowed_matches_dense_reference():
    """Sliding-window masking composes with per-sequence positions."""
    rng = np.random.default_rng(1)
    B, Sq, Sk, W = 2, 4, 12, 5
    q, k, v = _rand_qkv(rng, B, Sq, Sk)
    offsets = np.asarray([3, 7], np.int32)
    q_pos = offsets[:, None] + np.arange(Sq, dtype=np.int32)[None]
    k_pos = np.full((B, Sk), -1, np.int32)
    for b in range(B):
        for p in range(int(offsets[b]) + Sq):
            k_pos[b, p % Sk] = p
    out = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          jnp.asarray(q_pos), jnp.asarray(k_pos),
                          causal=True, window=W, chunk=4)
    ref = _dense_ref(q, k, v, q_pos, k_pos, causal=True, window=W)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-5, rtol=2e-5)


def test_flash_2d_broadcast_equals_shared_1d_bitwise():
    """Broadcasting shared positions to (B, S) must not change a single
    bit — serving mixes both forms and exactness tests compare across."""
    rng = np.random.default_rng(2)
    B, Sq, Sk = 2, 6, 10
    q, k, v = _rand_qkv(rng, B, Sq, Sk)
    q_pos = np.arange(Sq, dtype=np.int32)
    k_pos = np.arange(Sk, dtype=np.int32)
    o1 = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         jnp.asarray(q_pos), jnp.asarray(k_pos),
                         causal=True, window=0, chunk=4)
    o2 = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         jnp.broadcast_to(jnp.asarray(q_pos)[None], (B, Sq)),
                         jnp.broadcast_to(jnp.asarray(k_pos)[None], (B, Sk)),
                         causal=True, window=0, chunk=4)
    assert np.array_equal(np.asarray(o1), np.asarray(o2))


# --------------------------------------------------- ragged ring writes

@pytest.fixture(scope="module")
def lm():
    cfg = ARCHS["olmo-1b"].reduced()
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(7))


def _kv_positions(caches):
    """All KVCache.positions leaves of a decode state (i32, -1 sentinel)."""
    return [leaf for leaf in jax.tree.leaves(caches)
            if leaf.dtype == jnp.int32]


def test_ragged_extension_pad_rows_never_clobber_the_ring(lm):
    """A padded tail chunk near the ring's end: the pad's phantom positions
    wrap capacity and land on slots holding LIVE keys — the masked scatter
    must write the old contents back, bit for bit."""
    model, params = lm
    max_len = 16
    rng = np.random.RandomState(3)
    head = rng.randint(0, 256, size=(1, 14)).astype(np.int32)
    st = model.init_decode_state(1, max_len)
    _, st = model.extend(params, st, jnp.asarray(head))    # positions 0..13

    # 1 real token at offset 14, padded to 8: phantom positions 15..21 wrap
    # onto slots 15, 0..5 — six of those slots hold live keys
    toks = np.zeros((1, 8), np.int32)
    toks[0, 0] = 7
    lg_r, st_r = model.extend(params, st, jnp.asarray(toks),
                              lengths=jnp.asarray([1], np.int32))
    # reference: the same single token, unpadded
    lg_1, st_1 = model.extend(params, st, jnp.asarray([[7]], np.int32))
    assert np.array_equal(np.asarray(lg_r), np.asarray(lg_1))
    assert np.asarray(st_r["pos"]).tolist() == [15]
    for got, ref in zip(jax.tree.leaves(st_r["caches"]),
                        jax.tree.leaves(st_1["caches"])):
        assert np.array_equal(np.asarray(got), np.asarray(ref))
    # the wrapped slots really were at stake: positions 0..5 survive (an
    # unmasked scatter would have stamped them 16..21), slot 14 took the
    # real token, slot 15 (phantom 15) stayed empty
    for leaf in _kv_positions(st_r["caches"]):
        for row in np.asarray(leaf).reshape(-1, max_len):
            assert (row[:6] == np.arange(6)).all()
            assert row[14] == 14 and row[15] == -1


def test_ragged_extension_zero_length_row_is_untouched(lm):
    """Length-0 rows (idle admission lanes) neither write KV nor advance
    their position."""
    model, params = lm
    st = model.init_decode_state(2, 16)
    toks = np.zeros((2, 4), np.int32)
    toks[0] = [5, 6, 7, 8]
    _, st2 = model.extend(params, st, jnp.asarray(toks),
                          lengths=jnp.asarray([4, 0], np.int32))
    assert np.asarray(st2["pos"]).tolist() == [4, 0]
    for leaf in _kv_positions(st2["caches"]):
        row1 = np.asarray(leaf)[..., 1, :] if leaf.ndim == 3 \
            else np.asarray(leaf)[1]
        assert (row1 == -1).all()


def test_extension_chunk_wider_than_ring_raises(lm):
    """Regression: a chunk wider than the KV ring would make in-chunk
    positions alias slots (nondeterministic scatter) — it must be rejected,
    ragged or not."""
    model, params = lm
    st = model.init_decode_state(1, 8)
    toks = jnp.zeros((1, 12), jnp.int32)
    with pytest.raises(ValueError, match="exceeds the KV ring capacity"):
        model.extend(params, st, toks)
    with pytest.raises(ValueError, match="exceeds the KV ring capacity"):
        model.extend(params, st, toks, lengths=jnp.asarray([5], jnp.int32))


# ------------------------------------------------ SWA chunked extension

@pytest.fixture(scope="module")
def swa():
    cfg = ARCHS["h2o-danube-3-4b"].reduced()          # window = 32 reduced
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(5))


def test_swa_chunked_extension_matches_whole_prompt_prefill(swa):
    """The retired NotImplementedError, pinned the other way: chunked SWA
    extension (each chunk attends against the carried pre-write ring, so
    recycling never evicts a live in-window key) must reproduce the
    one-shot whole-prompt prefill — logits and ring contents — even when
    the prompt wraps the window-capacity ring."""
    cfg, model, params = swa
    rng = np.random.RandomState(11)
    p = rng.randint(0, 256, size=40).astype(np.int32)  # > window = 32
    lg_ref, st_ref = model.prefill(params, {"tokens": jnp.asarray(p[None])},
                                   max_len=48)
    st = model.init_decode_state(1, 48)
    lg = None
    for o in range(0, 40, 8):
        lg, st = model.extend(params, st, jnp.asarray(p[None, o:o + 8]))
    assert np.argmax(np.asarray(lg)) == np.argmax(np.asarray(lg_ref))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref),
                               atol=1e-4, rtol=1e-4)
    assert np.asarray(st["pos"]).tolist() == [40]
    for got, ref in zip(jax.tree.leaves(st["caches"]),
                        jax.tree.leaves(st_ref["caches"])):
        got, ref = np.asarray(got), np.asarray(ref)
        if got.dtype == np.int32:                      # ring positions
            assert np.array_equal(got, ref)
        else:                                          # ring k/v contents
            np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4)


def test_swa_ragged_extension_rows_are_independent(swa):
    """A short row co-batched with a longer one must get bit-identical ring
    state and logits to the same row extended alone — pad columns are dead
    weight, not evictions."""
    cfg, model, params = swa
    rng = np.random.RandomState(12)
    toks = rng.randint(0, 256, size=(2, 8)).astype(np.int32)
    lens = jnp.asarray([8, 3], np.int32)
    st = model.init_decode_state(2, 48)
    lg, st2 = model.extend(params, st, jnp.asarray(toks), lengths=lens)
    st1 = model.init_decode_state(1, 48)
    lg1, st1 = model.extend(params, st1, jnp.asarray(toks[1:, :3]))
    assert np.asarray(st2["pos"]).tolist() == [8, 3]
    assert np.array_equal(np.asarray(lg[1]), np.asarray(lg1[0]))
    for got, ref in zip(jax.tree.leaves(st2["caches"]),
                        jax.tree.leaves(st1["caches"])):
        assert np.array_equal(np.asarray(got)[1:], np.asarray(ref))


def test_swa_ragged_stacked_prefill_builds_per_row_rings(swa):
    """The ragged SWA prefill ring build (per-row gather of each row's own
    last in-window keys): a short row stacked with a longer one must come
    out with the same ring a solo trimmed prefill builds — the old
    last-columns slice would have filled it with pads."""
    cfg, model, params = swa
    rng = np.random.RandomState(13)
    toks = rng.randint(0, 256, size=(2, 40)).astype(np.int32)
    toks[1, 9:] = 0                                    # row 1: 9 real + pads
    lens = jnp.asarray([40, 9], np.int32)
    lg, st = model.prefill(params, {"tokens": jnp.asarray(toks)},
                           max_len=48, lengths=lens)
    assert np.asarray(st["pos"]).tolist() == [40, 9]
    for b, L in ((0, 40), (1, 9)):
        lg1, st1 = model.prefill(
            params, {"tokens": jnp.asarray(toks[b:b + 1, :L])}, max_len=48)
        np.testing.assert_allclose(np.asarray(lg[b]), np.asarray(lg1[0]),
                                   atol=1e-4, rtol=1e-4)
        for got, ref in zip(jax.tree.leaves(st["caches"]),
                            jax.tree.leaves(st1["caches"])):
            got, ref = np.asarray(got)[b:b + 1], np.asarray(ref)
            if got.dtype == np.int32:
                assert np.array_equal(got, ref), (b, L)
            else:
                np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4)


def test_swa_extension_chunk_wider_than_window_still_raises(swa):
    """A chunk wider than the window-capacity ring still aliases slots
    within itself — it must stay rejected (serving clamps its chunk to the
    window, so this is unreachable through the engine)."""
    cfg, model, params = swa
    st = model.init_decode_state(1, 48)
    toks = jnp.zeros((1, 40), jnp.int32)               # 40 > window = 32
    with pytest.raises(ValueError, match="exceeds the KV ring capacity"):
        model.extend(params, st, toks)
    # single-token pooled decode steps keep working
    st = model.init_decode_state(2, 48)
    lg, _ = model.decode_step(params, st, jnp.zeros((2, 1), jnp.int32))
    assert np.isfinite(np.asarray(lg)).all()
