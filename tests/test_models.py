"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness (assignment deliverable f)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS, SHAPES, cell_is_live, live_cells
from repro.models.model_zoo import build_model, loss_fn


def _batch(r, key, B=2, S=32):
    F = r.frontend_len if r.frontend else 0
    batch = {"tokens": jax.random.randint(key, (B, S - F), 0, r.vocab_size),
             "labels": jax.random.randint(key, (B, S - F), 0, r.vocab_size)}
    if r.frontend:
        batch["frontend"] = jax.random.normal(key, (B, F, r.d_model)) * 0.02
    if r.family == "encdec":
        batch["src_embeds"] = jax.random.normal(key, (B, 8, r.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_forward_smoke(name):
    r = ARCHS[name].reduced()
    model = build_model(r)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(r, key)
    logits, aux, _ = model.forward(params, batch)
    B, St = batch["tokens"].shape
    assert logits.shape == (B, St, r.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_one_train_step(name):
    r = ARCHS[name].reduced()
    model = build_model(r)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = _batch(r, key)
    (loss, (ce, aux)), grads = jax.value_and_grad(
        lambda p: loss_fn(model, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


def test_registry_complete():
    assert len(ARCHS) == 10
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}


def test_cell_rules():
    """long_500k only for sub-quadratic archs (DESIGN.md §6)."""
    live = set(live_cells())
    expect_long = {"mamba2-780m", "recurrentgemma-2b", "h2o-danube-3-4b",
                   "mixtral-8x22b"}
    for a in ARCHS:
        assert ((a, "long_500k") in live) == (a in expect_long), a
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert (a, s) in live
    assert len(live) == 34


def test_param_counts_full_configs():
    """Full (non-reduced) configs have the right parameter scale."""
    expected = {  # rough totals, billions
        "deepseek-67b": (60, 75), "mixtral-8x22b": (120, 160),
        "olmo-1b": (0.9, 1.6), "qwen2.5-3b": (2.5, 4.0),
        "mamba2-780m": (0.6, 1.0), "recurrentgemma-2b": (2.0, 3.5),
        "granite-moe-1b-a400m": (0.8, 1.8), "internvl2-26b": (18, 28),
        "h2o-danube-3-4b": (3.0, 5.0), "seamless-m4t-medium": (0.7, 1.6),
    }
    for name, (lo, hi) in expected.items():
        model = build_model(ARCHS[name])
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes)) / 1e9
        assert lo <= n <= hi, (name, n)
