"""SLO-aware precision elasticity: controller law, QoS tiers, streaming,
and the calibration guard.

Controller tests are pure python (nothing traced); engine tests drive a
reduced calibrated DSLOT model through overload and verify the properties
the overload benchmark gates on: reserved requests never drop below their
plane floor, shedding happens under burst, and budgets are restored after
the queue drains.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import DslotConfig
from repro.configs.registry import ARCHS
from repro.models.model_zoo import build_model
from repro.serve import (CANCELLED, DEGRADABLE, DONE, RESERVED, STANDARD,
                         Request, ServeConfig, ServeEngine, SloConfig,
                         SloController, SloSignals, TierSpec, default_tiers)

N_BITS = 8


def _press(depth=10):
    return SloSignals(queue_depth=depth)


def _slack():
    return SloSignals(queue_depth=0)


# ------------------------------------------------------------- controller

def test_default_tiers_shape():
    tiers = default_tiers(N_BITS)
    assert tiers[RESERVED].floor == tiers[RESERVED].ceiling == N_BITS
    assert tiers[DEGRADABLE].floor == 1
    assert tiers[DEGRADABLE].shed_order < tiers[STANDARD].shed_order \
        < tiers[RESERVED].shed_order


def test_shed_requires_consecutive_pressure():
    c = SloController(N_BITS, SloConfig(shed_patience=3, queue_high_water=4))
    c.update(_press())
    c.update(_press())
    assert c.shed_events == 0                 # patience not yet reached
    c.update(SloSignals(queue_depth=2))       # neutral: resets the counter
    c.update(_press())
    c.update(_press())
    assert c.shed_events == 0                 # counter restarted
    c.update(_press())
    assert c.shed_events == 1                 # third consecutive -> shed
    assert c.levels[DEGRADABLE] == N_BITS - 1


def test_shed_order_degradable_first_reserved_never():
    c = SloController(N_BITS, SloConfig(shed_patience=1))
    for _ in range(100):                      # way past every floor
        c.update(_press())
    assert c.levels[DEGRADABLE] == c.tiers[DEGRADABLE].floor == 1
    assert c.levels[STANDARD] == c.tiers[STANDARD].floor == 2
    assert c.levels[RESERVED] == N_BITS       # reserved never moved
    assert c.min_levels[RESERVED] == N_BITS
    # degradable must bottom out before standard loses a single plane:
    c2 = SloController(N_BITS, SloConfig(shed_patience=1))
    for _ in range(N_BITS - 1):               # exactly drain degradable
        c2.update(_press())
    assert c2.levels[DEGRADABLE] == 1 and c2.levels[STANDARD] == N_BITS


def test_restore_reverse_order_after_slack():
    c = SloController(N_BITS, SloConfig(shed_patience=1, restore_patience=2))
    for _ in range(N_BITS):                   # degradable floored, standard
        c.update(_press())                    # down one
    assert c.levels[STANDARD] == N_BITS - 1
    c.update(_slack())
    assert c.restore_events == 0              # patience not reached
    c.update(_slack())
    assert c.restore_events == 1
    assert c.levels[STANDARD] == N_BITS       # most important tier first
    assert c.levels[DEGRADABLE] == 1
    for _ in range(2 * (N_BITS - 1)):
        c.update(_slack())
    assert c.levels == {n: t.ceiling for n, t in c.tiers.items()}


def test_budget_for_applies_floor_level_and_ceiling():
    c = SloController(N_BITS, SloConfig(shed_patience=1))
    # reserved floor RAISES a lower explicit budget
    assert c.budget_for(RESERVED, 2) == N_BITS
    assert c.budget_for(STANDARD, 5) == 5     # fully restored: granted wins
    for _ in range(N_BITS + 2):
        c.update(_press())
    lvl = c.levels[STANDARD]
    assert c.budget_for(STANDARD, N_BITS) == lvl   # level caps the grant
    assert c.budget_for(STANDARD, 1) == c.tiers[STANDARD].floor


def test_ttft_pressure_and_p95_window():
    c = SloController(N_BITS, SloConfig(target_ttft_steps=4, ttft_window=4,
                                        shed_patience=1, queue_high_water=99))
    c.update(SloSignals(queue_depth=0, ttft_steps=[10, 10, 10, 10]))
    assert c.ttft_p95() == 10.0
    assert c.shed_events == 1                 # TTFT alone trips pressure
    c.update(SloSignals(queue_depth=0, ttft_steps=[1, 1, 1, 1]))
    assert c.ttft_p95() == 1.0                # old samples rolled out


def test_stale_ttft_window_expires_when_idle():
    """A drained burst's TTFT samples must not hold the controller in
    pressure forever: after ``ttft_idle_expiry`` idle updates the window
    clears and restores can proceed."""
    c = SloController(N_BITS, SloConfig(
        target_ttft_steps=4, shed_patience=1, restore_patience=1,
        queue_high_water=99, ttft_idle_expiry=3))
    c.update(SloSignals(queue_depth=0, ttft_steps=[50]))   # hot -> shed
    assert c.shed_events == 1
    for _ in range(2):
        c.update(_slack())
    assert c.restore_events == 0          # window still hot, not yet idle
    c.update(_slack())                    # third idle update: window expires
    c.update(_slack())                    # p95 is None -> slack -> restore
    assert c.ttft_p95() is None
    assert c.restore_events >= 1


def test_timed_out_feeds_pressure():
    """Deadline evictions are direct overload evidence: ``timed_out > 0``
    trips pressure on its own (empty queue, cool TTFT) and vetoes slack."""
    c = SloController(N_BITS, SloConfig(shed_patience=1, restore_patience=1,
                                        queue_high_water=99))
    c.update(SloSignals(queue_depth=0, timed_out=1))
    assert c.shed_events == 1                 # timeout alone sheds
    # a timeout step is never slack, even with everything else quiet: with
    # restore_patience=2, two timeout steps after a shed restore NOTHING
    # (each resets the cool counter), while two clean steps do
    c2 = SloController(N_BITS, SloConfig(shed_patience=1, restore_patience=2,
                                         queue_high_water=99))
    c2.update(SloSignals(queue_depth=0, timed_out=1))      # shed once
    assert c2.shed_events == 1
    c2.update(SloSignals(queue_depth=0, timed_out=1))
    c2.update(SloSignals(queue_depth=0, timed_out=1))
    assert c2.restore_events == 0             # timeouts veto the slack streak
    c2.update(SloSignals(queue_depth=0))
    c2.update(SloSignals(queue_depth=0))
    assert c2.restore_events == 1             # genuine slack restores


def test_custom_tiers_clamped_to_n_bits():
    cfg = SloConfig(tiers={"gold": TierSpec(floor=99, ceiling=99,
                                            shed_order=0)})
    c = SloController(N_BITS, cfg)
    assert c.tiers["gold"].floor == N_BITS
    assert c.budget_for("gold", 3) == N_BITS


# ------------------------------------------------------------- engine

def _dslot_cfg(act_scale=0.05):
    return dataclasses.replace(
        ARCHS["olmo-1b"].reduced(), act="relu", glu=False,
        dslot=DslotConfig(enabled=True, block_m=16, block_n=32, block_k=16,
                          act_scale=act_scale))


@pytest.fixture(scope="module")
def dslot_lm():
    cfg = _dslot_cfg()
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(11))


def _prompt(n, seed=0):
    return np.random.RandomState(seed).randint(
        0, 256, size=n).astype(np.int32)


def test_engine_overload_sheds_holds_reserved_floor_and_restores(dslot_lm):
    model, params = dslot_lm
    slo = SloConfig(queue_high_water=1, shed_patience=1, restore_patience=2,
                    target_ttft_steps=100)
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=2, max_len=64, prefill_chunk=4, slo=slo))
    n_bits = model.cfg.dslot.n_bits
    reqs = [Request(uid=i, prompt=_prompt(6, seed=i), max_new=4,
                    tier=(RESERVED if i == 0 else DEGRADABLE))
            for i in range(6)]
    for r in reqs:
        assert eng.try_add(r)
    done = []
    while len(done) < len(reqs):
        done += eng.step()
        if eng.last_budget is not None:
            for slot, req in enumerate(eng.slot_req):
                if req is not None and req.tier == RESERVED:
                    assert eng.last_budget[slot] == n_bits
    assert eng.slo.shed_events > 0            # burst forced shedding
    assert eng.slo.min_levels[DEGRADABLE] < n_bits
    assert eng.slo.min_levels[RESERVED] == n_bits
    shed_reqs = [r for r in reqs if r.tier == DEGRADABLE
                 and r.result.planes_used_mean is not None]
    res_req = reqs[0]
    assert res_req.result.n_planes == n_bits
    # degradable ran cheaper than reserved on average
    assert (np.mean([r.result.planes_used_mean for r in shed_reqs])
            <= res_req.result.planes_used_mean + 1e-6)
    # queue drained: slack steps restore every tier to its ceiling
    for _ in range(4 * n_bits):
        eng.step()
    assert eng.slo.levels == {n: t.ceiling for n, t in eng.slo.tiers.items()}
    assert eng.slo.restore_events > 0
    # per-tier planes-used EMA flowed through observe()
    assert DEGRADABLE in eng.slo.planes_used_ema


def test_engine_rejects_unknown_tier(dslot_lm):
    model, params = dslot_lm
    eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_len=32))
    with pytest.raises(ValueError, match="unknown QoS tier"):
        eng.try_add(Request(uid=1, prompt=_prompt(3), max_new=2,
                            tier="platinum"))


def test_streaming_on_token_and_generator(dslot_lm):
    model, params = dslot_lm
    eng = ServeEngine(model, params, ServeConfig(n_slots=2, max_len=64,
                                                 prefill_chunk=4))
    pushed = []
    r1 = Request(uid=1, prompt=_prompt(6, seed=1), max_new=4,
                 on_token=lambda req, tok, step: pushed.append((tok, step)))
    r2 = Request(uid=2, prompt=_prompt(6, seed=2), max_new=3)
    assert eng.try_add(r1)
    streamed = list(eng.stream(r2))           # drives the engine; r1 rides
    assert streamed == r2.out and len(streamed) == 3
    while not r1.done:
        eng.step()
    assert [t for t, _ in pushed] == r1.out   # push path saw every token
    assert [s for _, s in pushed] == r1.token_steps
    assert r1.token_steps == sorted(r1.token_steps)
    assert r1.token_steps[0] == r1.first_token_step
    for r in (r1, r2):
        assert r.result is not None and r.result.phase == DONE
        assert r.result.tokens == r.out
        assert r.result.ttft_steps == r.ttft_steps >= 1
        assert r.result.steps >= r.result.ttft_steps


def test_cancel_attaches_terminal_result(dslot_lm):
    model, params = dslot_lm
    eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_len=64,
                                                 prefill_chunk=4))
    active = Request(uid=1, prompt=_prompt(4, seed=3), max_new=8)
    queued = Request(uid=2, prompt=_prompt(4, seed=4), max_new=8)
    assert eng.try_add(active) and eng.try_add(queued)
    for _ in range(3):
        eng.step()
    assert eng.cancel(1) and eng.cancel(2)
    for r in (active, queued):
        assert r.done and r.phase == CANCELLED
        assert r.result is not None and r.result.phase == CANCELLED
    assert active.result.tokens == active.out and len(active.out) > 0
    assert queued.result.tokens == []


def test_uncalibrated_chunked_budget_rejected():
    """Per-request budgets + multi-chunk prompts need a calibrated
    act_scale (per-call max quantization is not chunk-invariant)."""
    cfg = _dslot_cfg(act_scale=None)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(12))
    eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_len=64,
                                                 prefill_chunk=4))
    assert not eng.calibrated
    with pytest.raises(ValueError, match="calibrated activation scale"):
        eng.try_add(Request(uid=1, prompt=_prompt(10), max_new=2,
                            n_planes=4))
    # single-chunk prompts and unbudgeted requests are unaffected
    ok = Request(uid=2, prompt=_prompt(3), max_new=2, n_planes=4)
    ok2 = Request(uid=3, prompt=_prompt(10), max_new=2)
    assert eng.try_add(ok) and eng.try_add(ok2)
