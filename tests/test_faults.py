"""Fault-injection plane + engine hardening contracts.

The bar throughout is the PR 9 hardening contract (``docs/serving.md``,
"Failure modes and recovery"):

* ``step()`` never raises — injected exceptions are absorbed with bounded
  retry and the engine's accounting (``check_invariants``) holds after
  EVERY step, including the faulted ones;
* isolation is exact — a poisoned request's quarantine leaves surviving
  co-batched requests' token streams **bit-identical** to a run where the
  victim was never admitted (the same bar the cancel-mid-batch tests set);
* recovery is exact — a transient failure that heals within the retry
  budget leaves every token stream identical to a fault-free run.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hyp import given, settings, st  # hypothesis or skip-shim
from repro.configs.registry import ARCHS
from repro.models.model_zoo import build_model
from repro.serve import (CANCELLED, DONE, FAILED, QUARANTINED, TIMEOUT,
                         Fault, FaultInjector, FaultPlan, Request,
                         ServeConfig, ServeEngine, TransientFault,
                         audit_engine, check_invariants, generate)


@pytest.fixture(scope="module")
def lm():
    cfg = ARCHS["olmo-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    return cfg, model, params


def _prompt(n, seed=0):
    return np.random.RandomState(seed).randint(0, 256, size=n).astype(np.int32)


_SOLO_CACHE: dict = {}


def _solo(model, params, n, seed, max_new):
    """Token stream of a solo ``generate`` run (cached per module)."""
    key = (n, seed, max_new)
    if key not in _SOLO_CACHE:
        p = _prompt(n, seed=seed)
        _SOLO_CACHE[key] = list(np.asarray(generate(
            model, params, {"tokens": jnp.asarray(p[None])}, max_new
        ).tokens[0]))
    return _SOLO_CACHE[key]


def _drive(eng, reqs, max_steps=200, invariants=True):
    """Step until every request is terminal, auditing after every step."""
    for _ in range(max_steps):
        eng.step()
        if invariants:
            check_invariants(eng)
        if all(r.done for r in reqs):
            return
    raise AssertionError(f"requests not terminal in {max_steps} steps: "
                         f"{[(r.uid, r.phase) for r in reqs]}")


# ------------------------------------------------------------- the plan

def test_plan_replayable():
    """Same seed, same plan — the determinism the chaos property leans on."""
    a = FaultPlan.random(7, n_faults=6, max_step=20, uids=(1, 2, 3))
    b = FaultPlan.random(7, n_faults=6, max_step=20, uids=(1, 2, 3))
    assert a == b and len(a) == 6 and a.seed == 7
    c = FaultPlan.random(8, n_faults=6, max_step=20, uids=(1, 2, 3))
    assert a != c


def test_plan_validates_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(kind="meteor_strike", step=1)


def test_injector_counts_and_records():
    """Exception faults raise ``count`` times then heal; every firing lands
    in the replay record."""
    plan = FaultPlan(faults=(Fault(kind="lane_exception", step=2, count=2),))
    inj = FaultInjector(plan)
    inj.begin_step(1)
    inj.raise_if("lane_forward")              # step 1: not yet armed
    inj.begin_step(2)
    with pytest.raises(TransientFault):
        inj.raise_if("lane_forward")
    with pytest.raises(TransientFault):
        inj.raise_if("lane_forward")
    inj.raise_if("lane_forward")              # count exhausted: healed
    assert inj.exhausted
    assert [k for _, k, _ in inj.fired] == ["lane_exception"] * 2
    assert inj.summary()["planned"] == 1


def test_uid_fault_stays_pending_until_resolvable():
    """A uid-targeted fault must not fire (or be dropped) while its target
    is not yet decoding."""
    plan = FaultPlan(faults=(Fault(kind="nan_logits", step=1, uid=42),))
    inj = FaultInjector(plan)
    inj.begin_step(3)
    lg = jnp.zeros((2, 8))
    out, poisoned = inj.poison_logits(lg, lambda f: None)   # unresolvable
    assert not poisoned and not inj.exhausted
    out, poisoned = inj.poison_logits(lg, lambda f: 1)      # now in slot 1
    assert poisoned and inj.exhausted
    assert bool(jnp.all(jnp.isnan(out[1]))) and bool(jnp.all(out[0] == 0))


# --------------------------------------------------- quarantine isolation

@pytest.mark.parametrize("kind", ["nan_logits", "inf_logits"])
def test_quarantine_survivor_bit_identity(lm, kind):
    """Poisoning one slot's logits quarantines exactly that request; the
    co-batched survivor's tokens are bit-identical to a solo run (i.e. to a
    pool where the victim never existed)."""
    _, model, params = lm
    plan = FaultPlan(faults=(Fault(kind=kind, step=5, uid=2),))
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=2, max_len=64, prefill_chunk=8, faults=plan))
    surv = Request(uid=1, prompt=_prompt(6, 1), max_new=8)
    victim = Request(uid=2, prompt=_prompt(6, 2), max_new=8)
    assert eng.try_add(surv) and eng.try_add(victim)
    _drive(eng, [surv, victim])
    assert victim.phase == QUARANTINED and victim.done
    assert victim.result is not None and victim.result.phase == QUARANTINED
    assert eng.quarantined == [(5, 2)]
    # poisoned logits never reached the victim's stream: tokens stop at the
    # last CLEAN step (the fault fired at step 5; admission took 1 step)
    assert len(victim.out) < 8
    assert surv.phase == DONE
    assert surv.out == _solo(model, params, 6, 1, 8)
    # the freed slot is immediately reusable and exact
    r3 = Request(uid=3, prompt=_prompt(5, 3), max_new=4)
    assert eng.try_add(r3)
    _drive(eng, [r3])
    assert r3.out == _solo(model, params, 5, 3, 4)


def test_quarantine_survivor_bit_identity_recurrent_stack():
    """The same quarantine-isolation bar on a RECURRENT stack (mamba2): the
    pad-masked ssm lanes admit co-batched, the victim's poisoned logits
    quarantine exactly it, and the survivor's carried state — and tokens —
    are bit-identical to a solo run."""
    cfg = ARCHS["mamba2-780m"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(6))
    plan = FaultPlan(faults=(Fault(kind="nan_logits", step=5, uid=2),))
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=2, max_len=64, prefill_chunk=8, chunks_per_step=2,
        faults=plan))
    surv = Request(uid=1, prompt=_prompt(6, 1), max_new=8)
    victim = Request(uid=2, prompt=_prompt(6, 2), max_new=8)
    assert eng.try_add(surv) and eng.try_add(victim)
    _drive(eng, [surv, victim])
    assert victim.phase == QUARANTINED and victim.done
    assert eng.quarantined == [(5, 2)]
    assert surv.phase == DONE
    solo = list(np.asarray(generate(
        model, params, {"tokens": jnp.asarray(_prompt(6, 1)[None])},
        8).tokens[0]))
    assert surv.out == solo
    # the freed slot is immediately reusable and exact on this stack too
    r3 = Request(uid=3, prompt=_prompt(5, 3), max_new=4)
    assert eng.try_add(r3)
    _drive(eng, [r3])
    assert r3.out == list(np.asarray(generate(
        model, params, {"tokens": jnp.asarray(_prompt(5, 3)[None])},
        4).tokens[0]))


def test_kv_corrupt_quarantines_via_detection(lm):
    """A corrupted KV write is not directly observable — it surfaces as
    non-finite logits on a later step, and the quarantine guard catches it
    there.  The engine never crashes and accounting stays clean."""
    _, model, params = lm
    plan = FaultPlan(faults=(Fault(kind="kv_corrupt", step=4, uid=1),))
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=2, max_len=64, prefill_chunk=8, faults=plan))
    victim = Request(uid=1, prompt=_prompt(6, 7), max_new=20)
    surv = Request(uid=2, prompt=_prompt(6, 8), max_new=8)
    assert eng.try_add(victim) and eng.try_add(surv)
    _drive(eng, [victim, surv])
    assert victim.phase == QUARANTINED
    assert [u for _, u in eng.quarantined] == [1]
    assert surv.out == _solo(model, params, 6, 8, 8)


def test_quarantine_disabled_is_off(lm):
    """``quarantine_nonfinite=False`` turns the guard off: the poisoned
    request keeps emitting (garbage) tokens instead of being evicted —
    proving the detection path is the thing doing the work."""
    _, model, params = lm
    plan = FaultPlan(faults=(Fault(kind="nan_logits", step=4, uid=1),))
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=1, max_len=64, prefill_chunk=8, faults=plan,
        quarantine_nonfinite=False))
    r = Request(uid=1, prompt=_prompt(6, 9), max_new=6)
    assert eng.try_add(r)
    _drive(eng, [r])
    assert r.phase == DONE and len(r.out) == 6
    assert eng.quarantined == []


# ------------------------------------------------- transient failures

def test_lane_exception_recovery_token_exact(lm):
    """A transient lane-forward failure within the retry budget recovers
    with EXACT tokens: the tick is transactional, so the retry re-runs the
    same chunk against the same state."""
    _, model, params = lm
    plan = FaultPlan(faults=(Fault(kind="lane_exception", step=1, count=1),))
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=1, max_len=64, prefill_chunk=8, faults=plan))
    r = Request(uid=1, prompt=_prompt(12, 4), max_new=5)
    assert eng.try_add(r)
    _drive(eng, [r])
    assert r.out == _solo(model, params, 12, 4, 5)
    assert eng.errors and eng.errors[0][1] == "admission"
    assert "TransientFault" in eng.errors[0][2]


def test_decode_exception_stalls_then_recovers_exact(lm):
    """A decode forward failing past the retry budget stalls the pool for
    exactly that step (state untouched) and the stream stays token-exact."""
    _, model, params = lm
    plan = FaultPlan(faults=(Fault(kind="decode_exception", step=3,
                                   count=2),))
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=1, max_len=64, prefill_chunk=8, faults=plan,
        max_step_retries=1))
    r = Request(uid=1, prompt=_prompt(6, 30), max_new=6)
    assert eng.try_add(r)
    _drive(eng, [r])
    assert r.out == _solo(model, params, 6, 30, 6)
    assert len(eng.errors) == 2                      # 1 retry + exhaustion
    # the stalled step emitted nothing: token cadence has a 1-step gap
    assert 3 not in r.token_steps


def test_admission_exhaustion_fails_inflight_only(lm):
    """Admission raising past every retry evicts the in-flight tasks as
    FAILED so the lanes recover; the engine keeps serving afterwards."""
    _, model, params = lm
    plan = FaultPlan(faults=(Fault(kind="admission_exception", step=2,
                                   count=99),))
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=1, max_len=64, prefill_chunk=4, faults=plan,
        max_step_retries=1))
    r = Request(uid=1, prompt=_prompt(12, 31), max_new=4)
    assert eng.try_add(r)
    _drive(eng, [r], max_steps=20)
    assert r.phase == FAILED and r.done and r.result.phase == FAILED
    # the injector healed after its 99-count window never re-arms new
    # steps?  No: count=99 keeps raising — every later step retries
    # admission, fails, but the pool itself still works: once the plan is
    # REPLACED by a healed engine, serving is normal.  Here just assert the
    # faulted engine's accounting stayed clean throughout (done in _drive)
    # and the queue did not wedge.
    assert eng.queue_depth == 0


def test_step_never_raises_under_any_single_fault(lm):
    """Every exception-kind fault, injected alone: step() never raises and
    invariants hold every tick."""
    _, model, params = lm
    for kind in ("lane_exception", "admission_exception",
                 "decode_exception"):
        plan = FaultPlan(faults=(Fault(kind=kind, step=2, count=1),))
        eng = ServeEngine(model, params, ServeConfig(
            n_slots=1, max_len=64, prefill_chunk=8, faults=plan))
        r = Request(uid=1, prompt=_prompt(10, 40), max_new=4)
        assert eng.try_add(r)
        _drive(eng, [r])
        assert r.out == _solo(model, params, 10, 40, 4), kind


# --------------------------------------------------------- deadlines

def test_default_deadline_times_out_and_frees_slot(lm):
    _, model, params = lm
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=1, max_len=64, prefill_chunk=8, default_deadline_steps=3))
    r = Request(uid=1, prompt=_prompt(4, 5), max_new=50)
    assert eng.try_add(r)
    _drive(eng, [r], max_steps=10)
    assert r.phase == TIMEOUT and r.done
    assert r.result is not None and r.result.phase == TIMEOUT
    assert r.result.tokens == r.out          # partial output preserved
    assert eng.timeouts == [(4, 1)]          # first step past the deadline
    # slot is reusable and exact
    r2 = Request(uid=2, prompt=_prompt(4, 6), max_new=3)
    assert eng.try_add(r2)
    _drive(eng, [r2])
    assert r2.out == _solo(model, params, 4, 6, 3)


def test_request_deadline_overrides_default(lm):
    """Per-request ``deadline_steps`` wins over the engine default, in both
    directions (tighter and looser)."""
    _, model, params = lm
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=2, max_len=64, prefill_chunk=8, default_deadline_steps=100))
    tight = Request(uid=1, prompt=_prompt(4, 11), max_new=50,
                    deadline_steps=2)
    loose = Request(uid=2, prompt=_prompt(4, 12), max_new=4)
    assert eng.try_add(tight) and eng.try_add(loose)
    _drive(eng, [tight, loose], max_steps=20)
    assert tight.phase == TIMEOUT
    assert loose.phase == DONE
    assert loose.out == _solo(model, params, 4, 12, 4)


def test_queued_request_can_time_out(lm):
    """Deadlines bind from ENQUEUE, not from admission: a request starved
    in the queue times out without ever touching a slot."""
    _, model, params = lm
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=1, max_len=64, prefill_chunk=8))
    hog = Request(uid=1, prompt=_prompt(4, 13), max_new=30)
    starved = Request(uid=2, prompt=_prompt(4, 14), max_new=4,
                      deadline_steps=3)
    assert eng.try_add(hog) and eng.try_add(starved)
    for _ in range(8):
        eng.step()
        check_invariants(eng)
    assert starved.phase == TIMEOUT and starved.out == []
    assert not hog.done                       # the hog keeps decoding


def test_no_deadline_means_no_timeout(lm):
    _, model, params = lm
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=1, max_len=128, prefill_chunk=8))
    r = Request(uid=1, prompt=_prompt(4, 15), max_new=40)
    assert eng.try_add(r)
    _drive(eng, [r], max_steps=60)
    assert r.phase == DONE and len(r.out) == 40 and eng.timeouts == []


# ----------------------------------------------------- drain / close

def test_drain_finishes_everything(lm):
    _, model, params = lm
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=2, max_len=64, prefill_chunk=8))
    rs = [Request(uid=i, prompt=_prompt(6, 50 + i), max_new=4)
          for i in range(4)]
    for r in rs:
        assert eng.try_add(r)
    fin = eng.drain()
    assert sorted(r.uid for r in fin) == [0, 1, 2, 3]
    assert all(r.out == _solo(model, params, 6, 50 + r.uid, 4) for r in rs)
    assert eng.live_requests() == []
    check_invariants(eng)


def test_drain_bound_raises_on_lost_liveness(lm):
    """An engine that cannot make progress (admission permanently raising)
    blows the drain bound with a RuntimeError instead of spinning."""
    _, model, params = lm
    plan = FaultPlan(faults=(Fault(kind="admission_exception", step=1,
                                   count=10**6),))
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=1, max_len=64, prefill_chunk=8, faults=plan))
    # queued request: admission never succeeds, so it never terminates
    r = Request(uid=1, prompt=_prompt(6, 60), max_new=4)
    assert eng.try_add(r)
    with pytest.raises(RuntimeError, match="drain did not converge"):
        eng.drain(max_steps=6)


def test_close_cancels_and_seals(lm):
    _, model, params = lm
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=1, max_len=64, prefill_chunk=4))
    decoding = Request(uid=1, prompt=_prompt(4, 61), max_new=30)
    prefilling = Request(uid=2, prompt=_prompt(12, 62), max_new=4)
    queued = Request(uid=3, prompt=_prompt(4, 63), max_new=4)
    for r in (decoding, prefilling, queued):
        assert eng.try_add(r)
    eng.step()                      # uid 1 admitted + decoding
    eng.step()                      # uid 2 starts prefilling
    cancelled = eng.close()
    assert sorted(r.uid for r in cancelled) == [1, 2, 3]
    assert all(r.done and r.phase == CANCELLED and r.result is not None
               for r in (decoding, prefilling, queued))
    assert eng.closed and eng.close() == []        # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        eng.step()
    with pytest.raises(RuntimeError, match="closed"):
        eng.try_add(Request(uid=9, prompt=_prompt(4), max_new=2))
    check_invariants(eng)           # closed engine holds no work


def test_drain_then_close_is_clean_shutdown(lm):
    _, model, params = lm
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=2, max_len=64, prefill_chunk=8))
    rs = [Request(uid=i, prompt=_prompt(5, 70 + i), max_new=3)
          for i in range(3)]
    for r in rs:
        assert eng.try_add(r)
    eng.drain()
    assert eng.close() == []        # nothing left to cut
    assert eng.closed


# ------------------------------------------- satellite: stream abandon

def test_abandoned_stream_cancels_request(lm):
    """Breaking out of / closing a ``stream`` generator cancels the
    request — slot and lane free instead of leaking forever."""
    _, model, params = lm
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=1, max_len=64, prefill_chunk=8))
    r = Request(uid=1, prompt=_prompt(4, 20), max_new=10)
    it = eng.stream(r)
    assert isinstance(next(it), int)
    it.close()                                # GeneratorExit path
    assert r.done and r.phase == CANCELLED
    check_invariants(eng)
    # pool fully reusable, next stream exact
    r2 = Request(uid=2, prompt=_prompt(4, 21), max_new=3)
    assert list(eng.stream(r2)) == _solo(model, params, 4, 21, 3)


def test_stream_break_mid_iteration(lm):
    _, model, params = lm
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=1, max_len=64, prefill_chunk=8))
    r = Request(uid=1, prompt=_prompt(4, 22), max_new=10)
    got = []
    for tok in eng.stream(r):
        got.append(tok)
        if len(got) == 2:
            break                              # abandon via break + gc
    del tok
    assert r.done and r.phase == CANCELLED and len(r.out) >= 2
    assert eng.live_requests() == []


def test_finished_stream_not_cancelled(lm):
    """A stream consumed to completion finishes DONE, not CANCELLED."""
    _, model, params = lm
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=1, max_len=64, prefill_chunk=8))
    r = Request(uid=1, prompt=_prompt(4, 23), max_new=4)
    toks = list(eng.stream(r))
    assert r.phase == DONE and toks == _solo(model, params, 4, 23, 4)


# --------------------------------------- satellite: try_add validation

def test_try_add_rejects_garbage_prompts(lm):
    _, model, params = lm
    eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_len=32))
    vocab = model.cfg.vocab_size
    cases = {
        "float dtype": np.array([1.5, 2.5]),
        "2-D": np.array([[1, 2]]),
        "negative id": np.array([-1, 2]),
        "out of vocab": np.array([1, vocab]),
        "empty": np.array([], np.int32),
    }
    for label, bad in cases.items():
        with pytest.raises(ValueError):
            eng.try_add(Request(uid=99, prompt=bad, max_new=2))
    # list prompts still work (coerced to ndarray)
    r = Request(uid=1, prompt=[1, 2, 3], max_new=2)
    assert eng.try_add(r)
    assert isinstance(r.prompt, np.ndarray)
    _drive(eng, [r])
    assert r.phase == DONE


def test_rejected_request_leaves_engine_clean(lm):
    """A ValueError'd request must not occupy queue accounting."""
    _, model, params = lm
    eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_len=32))
    with pytest.raises(ValueError):
        eng.try_add(Request(uid=1, prompt=np.array([-5]), max_new=2))
    assert eng.queue_depth == 0
    check_invariants(eng)


# ------------------------- satellite: queue overflow + cancel storms

def test_queue_overflow_preserves_fifo(lm):
    """Rejected ``try_add``s (queue full) must not perturb the FIFO order
    of already-accepted admissions."""
    _, model, params = lm
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=1, max_len=64, prefill_chunk=8, max_queue=3))
    accepted = [Request(uid=i, prompt=_prompt(4, 80 + i), max_new=2)
                for i in range(3)]
    for r in accepted:
        assert eng.try_add(r)
    for i in range(3, 8):            # overflow storm: all bounce
        assert not eng.try_add(
            Request(uid=i, prompt=_prompt(4, 80 + i), max_new=2))
    check_invariants(eng)
    order = []
    for r in accepted:
        r.on_token = lambda rq, tok, step, _o=order: \
            _o.append(rq.uid) if len(rq.out) == 1 else None
    _drive(eng, accepted)
    assert order == [0, 1, 2]        # strict arrival order on 1 slot
    # queue drained: a bounced uid can come back and run
    late = Request(uid=9, prompt=_prompt(4, 89), max_new=2)
    assert eng.try_add(late)
    _drive(eng, [late])
    assert late.phase == DONE


def test_cancel_storm_leaves_engine_reusable(lm):
    """Cancelling EVERY queued + in-flight request leaves queue_depth == 0
    and the lanes/slots immediately reusable."""
    _, model, params = lm
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=2, max_len=64, prefill_chunk=4))
    rs = [Request(uid=i, prompt=_prompt(10, 90 + i), max_new=4)
          for i in range(5)]
    for r in rs:
        assert eng.try_add(r)
    eng.step()                       # some reach lanes / slots
    for r in rs:
        eng.cancel(r.uid)
    assert eng.queue_depth == 0
    assert all(r.done and r.phase == CANCELLED for r in rs)
    assert eng.live_requests() == []
    check_invariants(eng)
    fresh = Request(uid=50, prompt=_prompt(6, 99), max_new=3)
    assert eng.try_add(fresh)
    _drive(eng, [fresh])
    assert fresh.out == _solo(model, params, 6, 99, 3)


def test_plan_driven_cancel_storm(lm):
    """Cancel faults fire from the plan — a storm is replayable data."""
    _, model, params = lm
    plan = FaultPlan(faults=tuple(
        Fault(kind="cancel", step=3, uid=u) for u in (1, 2, 3)))
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=2, max_len=64, prefill_chunk=8, faults=plan))
    rs = [Request(uid=i, prompt=_prompt(5, 100 + i), max_new=8)
          for i in (1, 2, 3)]
    for r in rs:
        assert eng.try_add(r)
    _drive(eng, rs, max_steps=20)
    assert all(r.phase == CANCELLED for r in rs)
    assert {t for _, k, t in eng.injector.fired if k == "cancel"} \
        == {1, 2, 3}


def test_slow_step_fires(lm):
    _, model, params = lm
    plan = FaultPlan(faults=(Fault(kind="slow_step", step=2, value=0.01),))
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=1, max_len=64, prefill_chunk=8, faults=plan))
    r = Request(uid=1, prompt=_prompt(4, 110), max_new=3)
    assert eng.try_add(r)
    _drive(eng, [r])
    assert ("slow_step" in {k for _, k, _ in eng.injector.fired})
    assert r.out == _solo(model, params, 4, 110, 3)


# ------------------------------------------------- seeded chaos property

@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_chaos_property(lm, seed):
    """A seeded random storm over every fault kind: the engine never
    raises, invariants hold after every step, every request terminates in
    a legal phase, and any request the storm did NOT touch matches its solo
    tokens exactly."""
    _, model, params = lm
    uids = (1, 2, 3)
    plan = FaultPlan.random(seed, n_faults=5, max_step=16, n_slots=2,
                            uids=uids,
                            kinds=("nan_logits", "inf_logits", "kv_corrupt",
                                   "lane_exception", "decode_exception",
                                   "cancel", "slow_step"))
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=2, max_len=64, prefill_chunk=8, faults=plan,
        default_deadline_steps=64))
    rs = [Request(uid=u, prompt=_prompt(6, 200 + u), max_new=6)
          for u in uids]
    for r in rs:
        assert eng.try_add(r)
    for _ in range(80):
        eng.step()
        assert audit_engine(eng) == []
        if all(r.done for r in rs):
            break
    legal = {DONE, CANCELLED, TIMEOUT, QUARANTINED, FAILED}
    assert all(r.done and r.phase in legal for r in rs)
    touched = {t for _, k, t in eng.injector.fired
               if k in ("nan_logits", "inf_logits", "kv_corrupt", "cancel")}
    # slot-targeted logit/kv faults can hit anyone; only claim exactness
    # when the storm contained no slot-targeted corruption at all
    slot_targeted = any(
        f.uid is None and f.kind in ("nan_logits", "inf_logits",
                                     "kv_corrupt")
        for f in plan.faults)
    if not slot_targeted:
        for r in rs:
            if r.uid not in touched and r.phase == DONE:
                assert r.out == _solo(model, params, 6, 200 + r.uid, 6), \
                    f"untouched uid {r.uid} diverged under {plan}"
