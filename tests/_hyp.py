"""Optional-``hypothesis`` shim so the suite collects everywhere.

``pip install -e .[test]`` brings in hypothesis and the property tests run
for real.  Without the extra (the seed container, minimal envs), importing
``given``/``settings``/``st`` from here makes the property tests SKIP at
collection instead of erroring the whole module — the deterministic tests in
the same files keep running either way.
"""

import os

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAS_HYPOTHESIS = True

    # Tier-1 determinism: property tests run DERANDOMIZED by default (the
    # "ci" profile) so the CI job cannot flake on a fresh example draw — a
    # failure always reproduces.  Engine-level properties spin up whole
    # ServeEngines per example, so examples are capped low; export
    # HYPOTHESIS_PROFILE=dev locally for a randomized, deeper search.
    settings.register_profile("ci", derandomize=True, max_examples=8,
                              deadline=None)
    settings.register_profile("dev", max_examples=25, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: any strategy constructor
        returns None — only ever passed to the no-op ``given`` below."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed "
                                       "(pip install -e .[test])")
