"""Checkpointer (atomicity, integrity, async, GC) + data pipeline
(determinism, restart)."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import TokenPipeline, _hash_tokens
from repro.data.mnist import synth_mnist


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16),
                       "c": jnp.asarray(3, jnp.int32)}}


def test_roundtrip_exact(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(7, t)
    r = ck.restore(7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        ck.save_async(s, t)
        ck.wait()
    assert ck.all_steps() == [3, 4]


def test_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    path = ck.save(1, t)
    man = json.load(open(os.path.join(path, "manifest.json")))
    first = next(iter(man["leaves"]))
    man["leaves"][first]["crc32"] ^= 0xDEADBEEF
    json.dump(man, open(os.path.join(path, "manifest.json"), "w"))
    with pytest.raises(IOError, match="corruption"):
        ck.restore(1, t)


def test_uncommitted_checkpoint_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    os.makedirs(str(tmp_path / "step_00000002"))   # no _COMMITTED marker
    assert ck.latest_step() == 1


def test_pipeline_determinism_and_restart():
    p1 = TokenPipeline(vocab=97, seq_len=16, global_batch=4, seed=5)
    a = p1.next_host_batch()
    st = p1.state()
    b = p1.next_host_batch()
    p2 = TokenPipeline(vocab=97, seq_len=16, global_batch=4, seed=5)
    p2.restore(st)
    b2 = p2.next_host_batch()
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_pipeline_learnable_structure():
    toks = _hash_tokens(0, np.arange(8), 17, 251)
    odd = toks[:, 1::2]
    even = toks[:, 0::2][:, : odd.shape[1]]
    np.testing.assert_array_equal(odd, (even * 7 + 13) % 251)


def test_synth_mnist():
    imgs, labels = synth_mnist(5, seed=1)
    assert imgs.shape == (50, 28, 28) and labels.shape == (50,)
    assert imgs.min() >= 0.0 and imgs.max() <= 1.0
    assert set(np.unique(labels)) == set(range(10))
    # class structure: per-class mean images are mutually distinct, and the
    # generator is deterministic in its seed
    means = np.stack([imgs[labels == d].mean(0) for d in range(10)])
    for a in range(10):
        for b in range(a + 1, 10):
            assert np.abs(means[a] - means[b]).mean() > 0.02, (a, b)
    imgs2, labels2 = synth_mnist(5, seed=1)
    np.testing.assert_array_equal(imgs, imgs2)
    np.testing.assert_array_equal(labels, labels2)
