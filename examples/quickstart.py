"""Quickstart: the DSLOT-NN core in five minutes.

1. multiply two numbers digit-serially (MSDF) and watch the digits converge;
2. run a sum-of-products through a PE with Algorithm-1 early termination;
3. run the TPU adaptation: a digit-plane matmul that skips MXU passes on
   provably-negative output tiles.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (early_termination, fixed_to_sd, online_mult_sp,
                        pe_schedule, pe_sop_digits, sd_prefix_values,
                        sd_to_value)
from repro.kernels.ops import dslot_matmul

# ---- 1. online (MSDF) multiplication: digits arrive most-significant first
xq, wq = 113, -97                       # 8-bit operands
x_digits = fixed_to_sd(jnp.asarray([xq]), 8)          # value 113/256
z = online_mult_sp(x_digits, jnp.float32(wq / 256.0), n_out=16)
prefixes = sd_prefix_values(z)[:, 0] * 2.0 ** 16
print("true product:", xq * wq)
for j in (1, 2, 4, 8, 16):
    print(f"  after {j:2d} digits the prefix is {float(prefixes[j-1]):9.1f} "
          f"(sign known: {'yes' if prefixes[j-1] < 0 else 'not yet'})")

# ---- 2. a 5x5 PE with early termination (paper Algorithm 1)
sch = pe_schedule(k=5, p_mult=16)
print(f"\nPE schedule (paper eq.6): {sch.total_cycles} cycles, "
      f"p_out={sch.p_out}")
rng = np.random.default_rng(0)
window = rng.integers(0, 128, size=(25, 4))           # 4 conv windows
kernel = rng.integers(-127, -16, size=(25,))          # negative-leaning
sop = pe_sop_digits(fixed_to_sd(jnp.asarray(window), 8),
                    jnp.asarray(kernel / 256.0, jnp.float32)[:, None], sch)
rep = early_termination(sop, sch)
print("cycles used per window:", np.asarray(rep.cycles_used),
      f"(full = {rep.cycles_full})")
print("cycle savings:", [f"{s:.0%}" for s in np.asarray(rep.savings_frac)])

# ---- 3. TPU adaptation: digit-plane matmul with tile termination
x = jnp.asarray(np.maximum(rng.normal(0.3, 0.4, (128, 64)), 0), jnp.float32)
w = rng.normal(0, 0.05, (64, 128)).astype(np.float32)
w[:, ::2] -= 0.08                                     # half the neurons dead
out, stats = dslot_matmul(x, jnp.asarray(w), backend="jnp",
                          sort_columns=True, block_m=32, block_n=32)
print(f"\ndigit-plane matmul: {float(stats.skipped_frac):.0%} of MXU "
      f"passes skipped (D={stats.n_planes} planes), result == relu(x@w)")
print("max err vs dense:",
      float(jnp.abs(out - jnp.maximum(x @ jnp.asarray(w), 0)).max()))
