"""End-to-end training driver: a ~100M-parameter LM for a few hundred steps.

Uses the same stack as the production launcher (model zoo, FSDPxTP-ready
shardings, grad accumulation, async checkpointing) on a single host.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

On this CPU container a step takes a few seconds; the loss curve on the
structured synthetic stream drops visibly within ~50 steps.
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import TokenPipeline
from repro.models.model_zoo import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: olmo family scaled down (8L x 512, vocab 32768)
    cfg = dataclasses.replace(
        get_arch("olmo-1b"),
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
        vocab_size=32_768, scan_unroll=2, attn_chunk=128, dtype="float32")
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model: {cfg.n_layers}L d{cfg.d_model} -> {n/1e6:.1f}M params")

    opt = AdamWConfig(peak_lr=1e-3, warmup_steps=20, decay_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    pipe = TokenPipeline(vocab=cfg.vocab_size, seq_len=256, global_batch=8,
                         microbatches=2)
    ck = Checkpointer(args.ckpt_dir, keep=2)

    t0 = time.time()
    for s in range(args.steps):
        batch = jax.tree.map(jnp.asarray, pipe.next_host_batch())
        state, m = step_fn(state, batch)
        if (s + 1) % 10 == 0 or s == 0:
            rate = 8 * 256 * (s + 1) / (time.time() - t0)
            print(f"step {s+1:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  {rate:.0f} tok/s",
                  flush=True)
        if (s + 1) % 50 == 0:
            ck.save_async(s + 1, state)
    ck.wait()
    print(f"done in {time.time()-t0:.0f}s; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
