"""Serving example: slot-pool continuous batching + DSLOT digit-serial MLPs
+ SLO-driven precision elasticity.

Serves the seamless-m4t backbone (the assigned arch whose ReLU FFN admits
full DSLOT early-negative-termination) in reduced form through the batch
``generate`` API, then drives the slot-pool ``ServeEngine`` — streaming
tokens as they land, and shedding digit planes per QoS tier when an
admission burst overloads the pool.

    PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import DslotConfig
from repro.configs.registry import get_arch
from repro.models import stats
from repro.models.model_zoo import build_model
from repro.serve import (DEGRADABLE, RESERVED, STANDARD, Request,
                         ServeConfig, ServeEngine, SloConfig, generate)


def main():
    cfg = get_arch("seamless-m4t-medium").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)

    batch = {
        "tokens": jax.random.randint(key, (4, 12), 0, cfg.vocab_size),
        "src_embeds": jax.random.normal(key, (4, 8, cfg.d_model)) * 0.02,
    }
    res = generate(model, params, batch, 8)
    print("enc-dec batched generation:", res.tokens.shape)

    # ---- DSLOT digit-serial MLPs (ReLU FFN -> early termination applies)
    dcfg = dataclasses.replace(cfg, dslot=DslotConfig(
        enabled=True, n_planes=8, block_m=16, block_n=16))
    dmodel = build_model(dcfg)
    dparams = dmodel.prepare_dslot(params)      # weight-stationary lowering,
    res2 = generate(dmodel, dparams, batch, 8)  # done once for all requests
    same = bool(jnp.mean((res.tokens == res2.tokens)
                         .astype(jnp.float32)) > 0.9)
    print("dslot-mode generation agrees with dense:", same)
    # per-request runtime precision + planes-executed accounting, all on
    # the one GenerateResult
    res3 = generate(dmodel, dparams, batch, 8,
                    n_planes=jnp.asarray([8, 8, 4, 2], jnp.int32))
    if res3.planes_used_mean is not None:
        used = np.asarray(res3.planes_used_mean)
        skip = np.asarray(res3.skipped_frac)
        for i in range(used.shape[0]):
            print(f"  request {i}: planes/row {used[i]:.2f}, "
                  f"skipped {skip[i]:.1%}")
    # eager forward statistics through the (scan-safe) stats side channel
    with stats.collect() as sink:
        dmodel.forward(dparams, batch)
    vals = [float(jnp.mean(v)) for v in jax.device_get(
        sink.get("mlp_dslot_skipped_frac", []))]
    if vals:
        print(f"digit-serial MLP calls: {len(vals)}, mean skipped MXU "
              f"passes {np.mean(vals):.1%}")

    # ---- slot-pool continuous batching with batched chunked admission
    # try_add only enqueues; each engine step interleaves ONE batched
    # admission forward — up to chunks_per_step PREFILLING prompts advance
    # together, one prefill_chunk each, at ragged per-request offsets — so
    # long prompts trickle in without stalling live slots for a full
    # forward, and bursts drain two prompts at a time (watch two slots sit
    # in 'prefilling' simultaneously below).
    lcfg = get_arch("olmo-1b").reduced()
    lmodel = build_model(lcfg)
    lparams = lmodel.init(jax.random.PRNGKey(2))
    eng = ServeEngine(lmodel, lparams, ServeConfig(
        n_slots=2, max_len=48, prefill_chunk=4, chunks_per_step=2))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, lcfg.vocab_size,
                                        size=3 + 4 * i).astype(np.int32),
                    max_new=3 + i) for i in range(4)]
    # streaming, push form: uid 0 reports every token the step it lands
    reqs[0].on_token = lambda req, tok, step: print(
        f"    uid {req.uid} token {tok} @ step {step}")
    for r in reqs:
        eng.try_add(r)                   # non-blocking: queued, FIFO
    finished = []
    while len(finished) < len(reqs):
        finished += eng.step()
        print(f"  step {eng.steps:2d}: slots={eng.slot_phases()} "
              f"queued={eng.queue_depth}")
    print("continuous batching: served", len(finished), "requests;",
          {r.uid: (len(r.out), f"ttft={r.result.ttft_steps} steps")
           for r in finished})
    # streaming, pull form: a generator handle drives the engine itself
    tail = Request(uid=99, prompt=rng.integers(
        0, lcfg.vocab_size, size=6).astype(np.int32), max_new=4)
    print("  streamed:", list(eng.stream(tail)), "ttft =",
          tail.result.ttft_steps, "steps")

    # ---- SLO-aware precision elasticity: QoS tiers under an overload burst
    # A calibrated DSLOT model (fixed act_scale -> chunk-invariant
    # quantization) serves a 4x burst; the SloController sheds degradable
    # tiers' digit planes to hold latency, never touches reserved's floor,
    # and restores the planes once the queue drains.
    scfg = dataclasses.replace(
        lcfg, act="relu", glu=False,
        dslot=DslotConfig(enabled=True, block_m=16, block_n=32, block_k=16,
                          act_scale=0.05))
    smodel = build_model(scfg)
    sparams = smodel.init(jax.random.PRNGKey(3))
    eng2 = ServeEngine(smodel, sparams, ServeConfig(
        n_slots=2, max_len=48, prefill_chunk=4, chunks_per_step=2,
        slo=SloConfig(queue_high_water=2, shed_patience=2,
                      restore_patience=2, target_ttft_steps=8)))
    tiers = [RESERVED, STANDARD] + [DEGRADABLE] * 6
    burst = [Request(uid=i, tier=t,
                     prompt=rng.integers(0, scfg.vocab_size,
                                         size=8).astype(np.int32),
                     max_new=4)
             for i, t in enumerate(tiers)]
    for r in burst:
        eng2.try_add(r)
    while not all(r.done for r in burst):
        eng2.step()
    for tier in (RESERVED, STANDARD, DEGRADABLE):
        rs = [r.result for r in burst if r.tier == tier]
        print(f"  {tier:10s} planes/row "
              f"{np.mean([r.planes_used_mean for r in rs]):.2f}  "
              f"ttft p95 {np.percentile([r.ttft_steps for r in rs], 95):.0f}"
              f" steps  [{len(rs)} reqs]")
    print("  controller:", eng2.slo.summary())


if __name__ == "__main__":
    main()
