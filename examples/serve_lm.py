"""Serving example: slot-pool continuous batching + DSLOT digit-serial MLPs.

Serves the seamless-m4t backbone (the assigned arch whose ReLU FFN admits
full DSLOT early-negative-termination) in reduced form, first through the
plain engine, then with the digit-serial execution mode enabled, reporting
the skipped-MXU-pass statistics that correspond to the paper's saved cycles.

    PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import DslotConfig
from repro.configs.registry import get_arch
from repro.models import stats
from repro.models.model_zoo import build_model
from repro.serve import Request, ServeConfig, ServeEngine, generate


def main():
    cfg = get_arch("seamless-m4t-medium").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)

    batch = {
        "tokens": jax.random.randint(key, (4, 12), 0, cfg.vocab_size),
        "src_embeds": jax.random.normal(key, (4, 8, cfg.d_model)) * 0.02,
    }
    toks = generate(model, params, batch, 8)
    print("enc-dec batched generation:", toks.shape)

    # ---- DSLOT digit-serial MLPs (ReLU FFN -> early termination applies)
    dcfg = dataclasses.replace(cfg, dslot=DslotConfig(
        enabled=True, n_planes=8, block_m=16, block_n=16))
    dmodel = build_model(dcfg)
    dparams = dmodel.prepare_dslot(params)      # weight-stationary lowering,
    toks2 = generate(dmodel, dparams, batch, 8)  # done once for all requests
    same = bool(jnp.mean((toks == toks2).astype(jnp.float32)) > 0.9)
    print("dslot-mode generation agrees with dense:", same)
    # per-request runtime precision + planes-executed accounting
    toks3, dstats = generate(dmodel, dparams, batch, 8,
                             n_planes=jnp.asarray([8, 8, 4, 2], jnp.int32),
                             return_stats=True)
    if dstats:
        used = np.asarray(dstats["planes_used_mean"])
        skip = np.asarray(dstats["skipped_frac"])
        for i in range(used.shape[0]):
            print(f"  request {i}: planes/row {used[i]:.2f}, "
                  f"skipped {skip[i]:.1%}")
    # eager forward statistics through the (scan-safe) stats side channel
    with stats.collect() as sink:
        dmodel.forward(dparams, batch)
    vals = [float(jnp.mean(v)) for v in jax.device_get(
        sink.get("mlp_dslot_skipped_frac", []))]
    if vals:
        print(f"digit-serial MLP calls: {len(vals)}, mean skipped MXU "
              f"passes {np.mean(vals):.1%}")

    # ---- slot-pool continuous batching with batched chunked admission
    # try_add only enqueues; each engine step interleaves ONE batched
    # admission forward — up to chunks_per_step PREFILLING prompts advance
    # together, one prefill_chunk each, at ragged per-request offsets — so
    # long prompts trickle in without stalling live slots for a full
    # forward, and bursts drain two prompts at a time (watch two slots sit
    # in 'prefilling' simultaneously below).
    lcfg = get_arch("olmo-1b").reduced()
    lmodel = build_model(lcfg)
    lparams = lmodel.init(jax.random.PRNGKey(2))
    eng = ServeEngine(lmodel, lparams, n_slots=2, max_len=48,
                      serve_config=ServeConfig(prefill_chunk=4,
                                               chunks_per_step=2))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, lcfg.vocab_size,
                                        size=3 + 4 * i).astype(np.int32),
                    max_new=3 + i) for i in range(4)]
    for r in reqs:
        eng.try_add(r)                   # non-blocking: queued, FIFO
    finished = []
    while len(finished) < len(reqs):
        finished += eng.step()
        print(f"  step {eng.steps:2d}: slots={eng.slot_phases()} "
              f"queued={eng.queue_depth}")
    print("continuous batching: served", len(finished), "requests;",
          {r.uid: (len(r.out), f"ttft={r.ttft_steps} steps")
           for r in finished})


if __name__ == "__main__":
    main()
