"""Serving example: slot-pool continuous batching + DSLOT digit-serial MLPs.

Serves the seamless-m4t backbone (the assigned arch whose ReLU FFN admits
full DSLOT early-negative-termination) in reduced form, first through the
plain engine, then with the digit-serial execution mode enabled, reporting
the skipped-MXU-pass statistics that correspond to the paper's saved cycles.

    PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import DslotConfig
from repro.configs.registry import get_arch
from repro.models import stats
from repro.models.model_zoo import build_model
from repro.serve.engine import Request, ServeEngine, generate


def main():
    cfg = get_arch("seamless-m4t-medium").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)

    batch = {
        "tokens": jax.random.randint(key, (4, 12), 0, cfg.vocab_size),
        "src_embeds": jax.random.normal(key, (4, 8, cfg.d_model)) * 0.02,
    }
    toks = generate(model, params, batch, 8)
    print("enc-dec batched generation:", toks.shape)

    # ---- DSLOT digit-serial MLPs (ReLU FFN -> early termination applies)
    dcfg = dataclasses.replace(cfg, dslot=DslotConfig(
        enabled=True, n_planes=8, block_m=16, block_n=16))
    dmodel = build_model(dcfg)
    toks2 = generate(dmodel, params, batch, 8)
    same = bool(jnp.mean((toks == toks2).astype(jnp.float32)) > 0.9)
    print("dslot-mode generation agrees with dense:", same)
    # skipped-pass statistics from one eager forward (stats recorded inside
    # the scanned decode loop would be traced values, not observables)
    with stats.collect() as sink:
        dmodel.forward(params, batch)
    vals = [float(v) for v in jax.device_get(
        sink.get("mlp_dslot_skipped_frac", []))]
    if vals:
        print(f"digit-serial MLP calls: {len(vals)}, mean skipped MXU "
              f"passes {np.mean(vals):.1%}")

    # ---- slot-pool continuous batching (decoder-only pool)
    lcfg = get_arch("olmo-1b").reduced()
    lmodel = build_model(lcfg)
    lparams = lmodel.init(jax.random.PRNGKey(2))
    eng = ServeEngine(lmodel, lparams, n_slots=2, max_len=48)
    reqs = [Request(uid=i, prompt=np.full((6,), i + 3, np.int32),
                    max_new=3 + i) for i in range(4)]
    pending = list(reqs)
    finished = []
    while len(finished) < len(reqs):
        while pending and eng.try_add(pending[0]):
            pending.pop(0)
        finished += eng.step()
    print("continuous batching: served", len(finished), "requests;",
          {r.uid: len(r.out) for r in finished})


if __name__ == "__main__":
    main()
