"""End-to-end reproduction of the paper's experiment (Figs. 6-9):

train the bias-free 5x5 CNN, then run its conv+ReLU+maxpool layers through
the DSLOT-NN digit-serial engine, reporting per-class negative-activation
rates (Fig. 8) and cycle savings (Fig. 9), plus the SIP baseline comparison.
The whole network is then re-run through the unified layer API
(``DslotConv2d``/``DslotDense`` -> digit-plane kernel) with per-layer
``planes_used`` statistics — ``--use-pallas`` executes the Pallas kernel
(interpret mode on CPU), ``--block-k`` streams weights in K chunks.

Run:  PYTHONPATH=src python examples/mnist_dslot.py [--per-class 30]
          [--use-pallas] [--block-k 64] [--n-planes 8]
"""

import argparse

import numpy as np
import jax.numpy as jnp

from repro.configs.dslot_mnist import CONFIG
from repro.core import dslot_conv2d_stats, sip_conv2d, table1_model
from repro.core.mnist_cnn import forward, forward_dslot, train_cnn
from repro.data.mnist import synth_mnist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--per-class", type=int, default=30)
    ap.add_argument("--use-pallas", action="store_true",
                    help="run the Pallas kernel (interpret mode off-TPU)")
    ap.add_argument("--block-k", type=int, default=None,
                    help="K chunk size streamed through VMEM (None = auto)")
    ap.add_argument("--n-planes", type=int, default=None,
                    help="runtime precision knob (digit planes <= n_bits)")
    args = ap.parse_args()

    imgs, labels = synth_mnist(args.per_class + 8, seed=0)
    n_eval = 8 * 10
    params, acc = train_cnn(CONFIG, imgs[:-n_eval], labels[:-n_eval],
                            epochs=20, lr=2e-2)
    print(f"trained bias-free CNN (synthetic MNIST): accuracy {acc:.1%}")

    ex, ey = imgs[-n_eval:], labels[-n_eval:]
    print("\nclass  neg-rate  cycles-saved   (paper Fig. 8 / Fig. 9)")
    rates = []
    for d in range(10):
        res = dslot_conv2d_stats(jnp.asarray(ex[ey == d]),
                                 jnp.asarray(params.conv))
        r = float(res.report.negative_rate)
        s = float(jnp.mean(res.report.savings_frac))
        rates.append(r)
        print(f"  {d}     {r:6.1%}     {s:6.1%}")
    print(f"mean negative rate {np.mean(rates):.1%} (paper: ~12.5%)")

    # bit-exactness vs the Stripes SIP baseline
    res = dslot_conv2d_stats(jnp.asarray(ex[:16]), jnp.asarray(params.conv))
    ref = sip_conv2d(jnp.asarray(ex[:16]), jnp.asarray(params.conv))
    print("\nDSLOT vs SIP max abs diff:",
          float(jnp.abs(res.y_conv - ref).max()), "(bit-exact path)")

    m = table1_model()
    print(f"modeled perf density: DSLOT {m['dslot'].gops_per_watt:.1f} "
          f"GOPS/W vs SIP {m['stripes'].gops_per_watt:.1f} GOPS/W "
          f"(+{m['dslot'].gops_per_watt/m['stripes'].gops_per_watt-1:.0%})")

    # full network through the unified layer API (digit-plane kernel)
    backend = "pallas(interpret)" if args.use_pallas else "jnp"
    print(f"\nlayer-API forward ({backend}, block_k={args.block_k}, "
          f"n_planes={args.n_planes or CONFIG.n_bits}):")
    xe = jnp.asarray(ex)
    res = forward_dslot(params, xe, CONFIG, use_pallas=args.use_pallas,
                        block_k=args.block_k, n_planes=args.n_planes,
                        block_m=32)
    ref_logits = forward(params, xe, CONFIG)
    agree = float(jnp.mean(jnp.argmax(res.logits, -1)
                           == jnp.argmax(ref_logits, -1)))
    dslot_acc = float(jnp.mean(jnp.argmax(res.logits, -1)
                               == jnp.asarray(ey)))
    for name, st in res.layer_stats.items():
        used = np.asarray(st.planes_used)
        print(f"  {name:8s} planes_used mean {used.mean():.2f}/{st.n_planes}"
              f"  skipped {float(st.skipped_frac):6.1%}"
              f"  tiles {used.shape[0]}x{used.shape[1]}")
    print(f"  argmax agreement with float forward: {agree:.1%}; "
          f"digit-serial accuracy {dslot_acc:.1%}")


if __name__ == "__main__":
    main()
