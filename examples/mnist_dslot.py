"""End-to-end reproduction of the paper's experiment (Figs. 6-9):

train the bias-free 5x5 CNN, then run its conv+ReLU+maxpool layers through
the DSLOT-NN digit-serial engine, reporting per-class negative-activation
rates (Fig. 8) and cycle savings (Fig. 9), plus the SIP baseline comparison.

The whole network then goes through the prepare/execute split: the trained
weights are lowered ONCE (``prepare_cnn`` — column sorts, block geometry,
termination tables), activation scales are fixed from a calibration batch
(``calibrate_cnn``), and the same prepared state serves every request — a
runtime precision sweep re-executes at 8..2 digit planes without ever
re-preparing (the paper's "precision tuned at run-time" as a request
parameter).  Per-precision accuracy and planes-skipped are printed and
optionally written as JSON (the CI artifact).

Run:  PYTHONPATH=src python examples/mnist_dslot.py [--per-class 30]
          [--use-pallas] [--block-k 64] [--n-planes 8] [--smoke]
          [--json planes.json]
"""

import argparse
import json

import numpy as np
import jax.numpy as jnp

from repro.configs.dslot_mnist import CONFIG
from repro.core import dslot_conv2d_stats, sip_conv2d, table1_model
from repro.core.mnist_cnn import (calibrate_cnn, forward, forward_dslot,
                                  prepare_cnn, train_cnn)
from repro.data.mnist import synth_mnist
from repro.kernels import ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--per-class", type=int, default=30)
    ap.add_argument("--use-pallas", action="store_true",
                    help="run the Pallas kernel (interpret mode off-TPU)")
    ap.add_argument("--block-k", type=int, default=None,
                    help="K chunk size streamed through VMEM (None = auto)")
    ap.add_argument("--n-planes", type=int, default=None,
                    help="runtime precision knob (digit planes <= n_bits)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny end-to-end run for CI (fewer samples/epochs)")
    ap.add_argument("--json", type=str, default=None,
                    help="write the per-precision planes-skipped sweep here")
    args = ap.parse_args()
    if args.smoke:
        args.per_class = min(args.per_class, 12)
    epochs = 3 if args.smoke else 20

    imgs, labels = synth_mnist(args.per_class + 8, seed=0)
    n_eval = 8 * 10
    params, acc = train_cnn(CONFIG, imgs[:-n_eval], labels[:-n_eval],
                            epochs=epochs, lr=2e-2)
    print(f"trained bias-free CNN (synthetic MNIST): accuracy {acc:.1%}")

    ex, ey = imgs[-n_eval:], labels[-n_eval:]
    if not args.smoke:
        print("\nclass  neg-rate  cycles-saved   (paper Fig. 8 / Fig. 9)")
        rates = []
        for d in range(10):
            res = dslot_conv2d_stats(jnp.asarray(ex[ey == d]),
                                     jnp.asarray(params.conv))
            r = float(res.report.negative_rate)
            s = float(jnp.mean(res.report.savings_frac))
            rates.append(r)
            print(f"  {d}     {r:6.1%}     {s:6.1%}")
        print(f"mean negative rate {np.mean(rates):.1%} (paper: ~12.5%)")

    # bit-exactness vs the Stripes SIP baseline
    res = dslot_conv2d_stats(jnp.asarray(ex[:16]), jnp.asarray(params.conv))
    ref = sip_conv2d(jnp.asarray(ex[:16]), jnp.asarray(params.conv))
    print("\nDSLOT vs SIP max abs diff:",
          float(jnp.abs(res.y_conv - ref).max()), "(bit-exact path)")

    m = table1_model()
    print(f"modeled perf density: DSLOT {m['dslot'].gops_per_watt:.1f} "
          f"GOPS/W vs SIP {m['stripes'].gops_per_watt:.1f} GOPS/W "
          f"(+{m['dslot'].gops_per_watt/m['stripes'].gops_per_watt-1:.0%})")

    # ---- prepare once / execute many: the weight-stationary serving path
    backend = "pallas(interpret)" if args.use_pallas else "jnp"
    xe = jnp.asarray(ex)
    ref_logits = forward(params, xe, CONFIG)
    n0 = ops.prepare_call_count()
    prep = prepare_cnn(params, CONFIG, use_pallas=args.use_pallas,
                       block_k=args.block_k, block_m=32)
    prep = calibrate_cnn(prep, xe[:16], CONFIG)
    n_prepares = ops.prepare_call_count() - n0
    # weight-side static MSR plane bounds baked in at prepare time: tiles
    # with bound 0 are never issued by any backend (bit-exact saving)
    weight_side = {}
    for name, lp in (("conv1", prep.conv_params), ("dense1",
                                                   prep.head_params)):
        tbl = lp["dslot"].msr_bound
        tbl = None if tbl is None else np.asarray(tbl).tolist()
        weight_side[name] = {
            "bound_table": tbl,
            "bounded_tiles": 0 if tbl is None else sum(
                b < CONFIG.n_bits for b in tbl)}
    print(f"\nprepared {n_prepares} layers once ({backend}, "
          f"block_k={args.block_k}); weight-side bounded tiles: "
          + ", ".join(f"{n} {d['bounded_tiles']}"
                      for n, d in weight_side.items())
          + "; runtime precision sweep:")

    sweep = []
    planes_list = ([args.n_planes] if args.n_planes
                   else list(range(CONFIG.n_bits, 1, -2)))
    for n_planes in planes_list:
        res = forward_dslot(prep, xe, CONFIG, n_planes=n_planes)
        agree = float(jnp.mean(jnp.argmax(res.logits, -1)
                               == jnp.argmax(ref_logits, -1)))
        dslot_acc = float(jnp.mean(jnp.argmax(res.logits, -1)
                                   == jnp.asarray(ey)))
        row = {"n_planes": n_planes, "argmax_agreement": agree,
               "accuracy": dslot_acc, "layers": {}}
        for name, st in res.layer_stats.items():
            used = np.asarray(st.planes_used)
            row["layers"][name] = {
                "planes_used_mean": float(used.mean()),
                "skipped_frac": float(st.skipped_frac),
                # weight-side planes saved: granted budget minus the
                # static MSR bound, per tile (0 unless weights carry
                # inert tiles — see bench_kernel.py --msr-profile)
                "planes_bounded_mean": (
                    None if st.planes_bounded is None else
                    float(np.asarray(st.planes_bounded).mean())),
            }
            print(f"  D={n_planes}  {name:8s} planes_used "
                  f"{used.mean():5.2f}  skipped "
                  f"{float(st.skipped_frac):6.1%}", end="")
        print(f"   acc {dslot_acc:5.1%}  agree {agree:5.1%}")
        sweep.append(row)
    assert ops.prepare_call_count() - n0 == n_prepares, \
        "precision sweep must not re-prepare weights"

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"smoke": args.smoke, "backend": backend,
                       "train_accuracy": acc, "prepares": n_prepares,
                       "weight_side": weight_side,
                       "precision_sweep": sweep}, f, indent=2)
        print(f"wrote per-precision planes-skipped sweep to {args.json}")


if __name__ == "__main__":
    main()
