"""Digit-plane DSLOT kernel benchmark: skipped-MXU-pass fraction vs output
negativity (the TPU adaptation of Fig. 9), runtime-precision scaling,
``block_k`` streaming sweep, and per-layer planes-skipped for the MNIST
network through the unified layer API — the software proxy for the paper's
energy-saving claim.  Wall-times are for the jnp path (CPU container; Pallas
numbers are structural — interpret mode is not a performance proxy).

``--sweep-precision`` measures the prepare/execute split: calls/s of
``dslot_execute`` against cached weight tables vs the fused per-call
``dslot_matmul`` (which re-sorts/re-encodes the weight side every call),
plus skipped-frac per runtime precision — written to ``BENCH_precision.json``.

``--compare-encoding`` measures fused in-kernel digit encoding against the
pre-fusion materialized (D, M, K) plane-tensor path (kept verbatim in this
file as the baseline): wall-clock, XLA bytes-moved via
``jax.jit(...).lower().compile().cost_analysis()``, the activation-stream
footprint, and a bit-exactness cross-check — written to
``BENCH_kernel.json``.  Exits nonzero (CI-fatal) if the fused path moves
more activation bytes than the materialized one.

Standalone CLI (used by the CI smoke job):
    python benchmarks/bench_kernel.py [--smoke] [--json out.json]
        [--sweep-precision [--precision-json BENCH_precision.json]]
        [--compare-encoding [--kernel-json BENCH_kernel.json]]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ops import dslot_matmul


def _timeit(fn, *args, iters=3, **kw):
    fn(*args, **kw)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run(smoke: bool = False) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    M = K = N = 64 if smoke else 256
    x = jnp.asarray(np.maximum(rng.normal(0.3, 0.4, (M, K)), 0), jnp.float32)
    bm = bn = 32 if smoke else 64

    for dead_frac in (0.0, 0.25, 0.5, 0.75):
        w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
        n_dead = int(N * dead_frac)
        if n_dead:
            w[:, rng.permutation(N)[:n_dead]] -= 0.10
        out, st = dslot_matmul(x, jnp.asarray(w), backend="jnp",
                               sort_columns=True, block_m=bm, block_n=bn)
        rows.append(f"kernel.skipped_frac_dead{int(dead_frac*100)},"
                    f"{float(st.skipped_frac):.4f},sorted-tiles")

    # block_k streaming sweep: same workload, weights streamed through VMEM
    # in chunks.  The chunk-aware bound can only terminate earlier, so the
    # skipped fraction is monotone non-decreasing as chunks shrink.
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    w[:, rng.permutation(N)[:N // 2]] -= 0.10
    for bk in (None, K, K // 2, K // 4):
        out, st = dslot_matmul(x, jnp.asarray(w), backend="jnp",
                               sort_columns=True, block_m=bm, block_n=bn,
                               block_k=bk)
        us = _timeit(dslot_matmul, x, jnp.asarray(w), backend="jnp",
                     sort_columns=True, block_m=bm, block_n=bn, block_k=bk)
        tag = "auto" if bk is None else str(bk)
        rows.append(f"kernel.blockk{tag}_skipped_frac,"
                    f"{float(st.skipped_frac):.4f},us={us:.0f}")

    w = jnp.asarray(rng.normal(0, 0.05, (K, N)), jnp.float32)
    for D in (8, 6, 4, 2):
        us = _timeit(dslot_matmul, x, w, backend="jnp", n_planes=D,
                     block_m=bm, block_n=bn)
        out, _ = dslot_matmul(x, w, backend="jnp", n_planes=D,
                              block_m=bm, block_n=bn)
        ref = jnp.maximum(x @ w, 0)
        rel = float(jnp.abs(out - ref).mean() / (jnp.abs(ref).mean() + 1e-9))
        rows.append(f"kernel.planes{D}_us,{us:.0f},rel_err={rel:.4f}")

    # per-layer planes-skipped for the MNIST network through the layer API
    # (trained-free: random weights biased negative in the head so early
    # termination has something to kill — the per-layer reporting path is
    # what's exercised here, not the paper's accuracies).
    from repro.configs.dslot_mnist import CONFIG
    from repro.core.mnist_cnn import forward_dslot, init_cnn
    params = init_cnn(CONFIG, jax.random.PRNGKey(0))
    imgs = jnp.asarray(rng.uniform(0, 1, (4 if smoke else 16, 28, 28)),
                       jnp.float32)
    res = forward_dslot(params, imgs, CONFIG, block_m=32,
                        block_k=None if smoke else 64)
    for name, st in res.layer_stats.items():
        used = np.asarray(st.planes_used)
        rows.append(f"kernel.layer_{name}_planes_used,"
                    f"{used.mean():.3f},skipped={float(st.skipped_frac):.4f}")

    # pallas interpret-mode parity check at bench scale, tiled K (the kernel
    # consumes quantized activations and encodes digits in-kernel; the
    # oracle evaluates over an explicitly materialized plane tensor)
    from repro.kernels.ref import make_planes, dslot_matmul_ref
    from repro.kernels.dslot_matmul import dslot_matmul_pallas
    aq = jnp.asarray(rng.integers(0, 256, (64, 64)), jnp.int32)
    wp = jnp.asarray(rng.normal(0, 0.05, (64, 64)), jnp.float32)
    o1 = dslot_matmul_pallas(aq, wp, block_m=32, block_n=32,
                             block_k=32).out
    o2 = dslot_matmul_ref(make_planes(aq, 8), wp, 8)
    rows.append(f"kernel.pallas_vs_ref_maxerr,"
                f"{float(jnp.abs(o1 - o2).max()):.2e},interpret-tiled-k")
    return rows


# --------------------------------------------------- encoding comparison

def _materialized_execute(prep, x, npl):
    """The PRE-FUSION execution path, kept verbatim as the benchmark
    baseline: encode ALL digit planes of the quantized activations into a
    (D, M, K) int8 tensor, restack it into per-step chunks, then stream the
    planes through the same scaled-matmul scan with the same chunk-aware
    termination replay.  This is what ``dslot_execute`` did before digit
    encoding was fused into the kernels — byte-for-byte the old dataflow,
    so the fused path can be gated on (a) moving strictly fewer bytes and
    (b) bit-exact outputs/planes_used against it.
    """
    from repro.kernels.ref import make_planes

    cfg = prep
    M, K = x.shape
    q, step = ops.quantize_activations(x, n_bits=cfg.n_bits,
                                       signed=cfg.signed, scale=cfg.x_scale)
    planes = make_planes(q, cfg.n_bits)                     # (D, M, K) HBM
    D = planes.shape[0]
    npl_c = jnp.clip(jnp.asarray(npl, jnp.int32), 1, D)
    pmask = (jnp.arange(D) < npl_c)[:, None, None]
    planes = planes * pmask.astype(planes.dtype)
    planes = jnp.pad(planes, [(0, 0), (0, (-M) % cfg.block_m),
                              (0, cfg.w.shape[0] - K)])
    D, Mp, Kp = planes.shape
    N = cfg.w.shape[1]
    bk = cfg.block_k
    Kt = Kp // bk
    Mt, Nt = Mp // cfg.block_m, N // cfg.block_n
    w_chunks = cfg.w.astype(jnp.float32).reshape(Kt, bk, N)
    # the old layout: every plane of every chunk, stacked — D*M*K int8
    p_chunks = planes.reshape(D, Mp, Kt, bk).transpose(0, 2, 1, 3) \
        .reshape(D * Kt, Mp, bk)
    scales = jnp.exp2(jnp.asarray(cfg.n_bits - 1, jnp.float32)
                      - jnp.arange(D, dtype=jnp.float32))
    tail = jnp.exp2(jnp.asarray(cfg.n_bits, jnp.float32)
                    - npl_c.astype(jnp.float32))
    step_rem = (scales[:, None, None] * cfg.suffix_colsum[None]
                + ((scales - tail)[:, None, None]
                   * cfg.total_colsum[0][None, None, :])).reshape(D * Kt, N)

    def body(acc, s):
        p, c, scale, rem = s
        wc = jax.lax.dynamic_index_in_dim(w_chunks, c, keepdims=False)
        acc = acc + scale * jnp.dot(p.astype(jnp.float32), wc,
                                    preferred_element_type=jnp.float32)
        dead = jnp.all((acc + rem[None, :]).reshape(
            Mt, cfg.block_m, Nt, cfg.block_n) < 0.0, axis=(1, 3))
        return acc, dead

    c_idx = jnp.tile(jnp.arange(Kt), D)
    acc, dead_after = jax.lax.scan(
        body, jnp.zeros((Mp, N), jnp.float32),
        (p_chunks, c_idx, jnp.repeat(scales, Kt), step_rem))
    out = jnp.maximum(acc, 0.0)
    ever = jnp.any(dead_after, axis=0)
    first = jnp.argmax(dead_after, axis=0)
    used = jnp.where(ever, first // Kt + 1, D).astype(jnp.int32)
    used = jnp.minimum(used, npl_c)
    return out[:M, :cfg.d_out] * step, used


def _bytes_accessed(fn, *args) -> float:
    cost = jax.jit(fn).lower(*args).compile().cost_analysis()
    if not isinstance(cost, dict):                  # some versions: [dict]
        cost = cost[0]
    return float(cost.get("bytes accessed", float("nan")))


def _max_int_tensor_bytes(fn, *args) -> int:
    """Largest integer-typed tensor anywhere in ``fn``'s jaxpr, in bytes.

    The structural detector for a reintroduced digit-plane materialization:
    the old path's (D, M, K) plane tensor (or its (D*Kt, M, bk) restack) is
    by far the largest integer intermediate either path could create, so
    'fused max int tensor < plane-tensor bytes' proves no plane-sized
    activation encoding exists in the traced graph — independent of
    whatever XLA's cost model reports.
    """
    import re

    txt = str(jax.make_jaxpr(fn)(*args))            # includes scan bodies
    best = 0
    for m in re.finditer(r"\b[iu](\d+)\[([\d,]+)\]", txt):
        elems = 1
        for d in m.group(2).split(","):
            elems *= int(d)
        best = max(best, elems * int(m.group(1)) // 8)
    return best


def run_encoding_comparison(smoke: bool = False) -> dict:
    """Fused in-kernel digit encoding vs the materialized (D, M, K) plane
    tensor: wall-clock, XLA bytes-moved (``cost_analysis``), the
    activation-stream footprint each path hands to its compute, and a
    bit-exactness cross-check.  Emits the ``BENCH_kernel.json`` payload;
    byte regressions (fused moving MORE than materialized, or a <4x
    activation-stream reduction) are recorded in ``report["violations"]``
    and turned into a nonzero exit by the CLI AFTER the artifact is
    written; diverging outputs/planes_used raise immediately.
    """
    from repro.kernels.dslot_matmul import q_storage_dtype

    rng = np.random.default_rng(0)
    M = K = N = 64 if smoke else 256
    bm = bn = 32 if smoke else 64
    bk = K // 2
    n_bits = 8
    x = jnp.asarray(np.maximum(rng.normal(0.3, 0.4, (M, K)), 0), jnp.float32)
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    w[:, rng.permutation(N)[:N // 2]] -= 0.10
    prep = ops.dslot_prepare(jnp.asarray(w), n_bits=n_bits, relu=True,
                             block_m=bm, block_n=bn, block_k=bk,
                             backend="jnp")
    prep = prep.with_scale(ops.calibrate_scale(x))
    iters = 3 if smoke else 10

    def fused(prep, x, npl):
        return ops._execute_core(prep, x, npl)

    fused_jit = jax.jit(fused)
    mat_jit = jax.jit(_materialized_execute)
    report = {"smoke": smoke, "shape": [M, K, N], "block": [bm, bn, bk],
              "n_bits": n_bits, "sweep": [], "violations": []}
    D = n_bits
    q_itemsize = q_storage_dtype(n_bits, prep.signed).itemsize
    Kp = prep.w.shape[0]
    # bytes moved are a property of the lowered graph, not of the traced
    # runtime precision — measure each path once, outside the sweep
    npl0 = jnp.asarray(n_bits, jnp.int32)
    fused_bytes = _bytes_accessed(fused, prep, x, npl0)
    mat_bytes = _bytes_accessed(_materialized_execute, prep, x, npl0)
    bytes_known = not (np.isnan(fused_bytes) or np.isnan(mat_bytes))
    # structural gate on the REAL traced graphs: the fused path must not
    # contain any plane-tensor-sized integer intermediate (and the detector
    # is validated against the materialized path, which must contain one)
    plane_bytes = D * ((M + bm - 1) // bm * bm) * Kp
    fused_int_max = _max_int_tensor_bytes(fused, prep, x, npl0)
    mat_int_max = _max_int_tensor_bytes(_materialized_execute, prep, x, npl0)
    assert mat_int_max >= plane_bytes, \
        (mat_int_max, plane_bytes, "detector failed to see the plane tensor")
    report["plane_tensor_bytes"] = plane_bytes
    report["max_int_tensor_bytes"] = {"fused": fused_int_max,
                                      "materialized": mat_int_max}
    if fused_int_max >= plane_bytes:
        report["violations"].append(
            f"fused graph contains a plane-tensor-sized integer "
            f"intermediate ({fused_int_max} >= {plane_bytes} B): digit "
            f"encoding is being materialized again")
    # the activation-stream model (what each path hands its kernel/scan):
    # analytic by construction; the structural gate above checks the graph
    act_fused = M * Kp * q_itemsize
    act_mat = D * M * Kp * 1
    if act_mat / act_fused < 4.0:
        report["violations"].append(
            f"activation-stream reduction {act_mat / act_fused:.1f}x "
            f"< 4x at n_bits={n_bits}")
    for npl_i in (8, 4, 2):
        npl = jnp.asarray(npl_i, jnp.int32)
        of, sf = fused_jit(prep, x, npl)
        om, um = mat_jit(prep, x, npl)
        np.testing.assert_array_equal(np.asarray(of), np.asarray(om),
                                      err_msg=f"n_planes={npl_i}")
        np.testing.assert_array_equal(np.asarray(sf.planes_used),
                                      np.asarray(um),
                                      err_msg=f"n_planes={npl_i}")
        fused_us = _timeit(fused_jit, prep, x, npl, iters=iters)
        mat_us = _timeit(mat_jit, prep, x, npl, iters=iters)
        report["sweep"].append({
            "n_planes": npl_i,
            "wall_us": {"fused": fused_us, "materialized": mat_us},
            "bit_exact": True,
        })
    # the activation tensor each path streams through its compute: the
    # fused kernels read the quantized block itself; the old path wrote
    # and re-read every digit plane of it
    report["activation_stream_bytes"] = {
        "fused": act_fused, "materialized": act_mat,
        "reduction": act_mat / act_fused}
    report["bytes_accessed"] = {
        "fused": fused_bytes, "materialized": mat_bytes,
        "known": bytes_known,
        "reduction": mat_bytes / fused_bytes if bytes_known else None}
    if bytes_known and fused_bytes > mat_bytes:
        report["violations"].append(
            f"fused path moves MORE bytes than materialized: "
            f"{fused_bytes} > {mat_bytes}")
    return report


def run_precision_sweep(smoke: bool = False) -> dict:
    """Prepare-once/execute-many amortization + skipped-frac per precision.

    Two costs are measured per precision D:

    * ``first_call_us`` — latency of the FIRST call at a new precision.
      The fused path takes D as a static argument, so every precision is a
      fresh trace + compile; ``dslot_execute`` takes it as a runtime value
      against cached weight tables, so switching precision costs one normal
      dispatch.  This is the serving-path win: precision becomes a
      per-request parameter instead of a recompile.
    * ``steady_us`` — steady-state per-call latency (jnp backend on CPU;
      note the split path always scans ``n_bits`` plane chunks with masked
      digits — on TPU the Pallas kernel predicates those passes off).
    """
    rng = np.random.default_rng(0)
    M = K = N = 64 if smoke else 256
    bm = bn = 32 if smoke else 64
    bk = K // 4
    x = jnp.asarray(np.maximum(rng.normal(0.3, 0.4, (M, K)), 0), jnp.float32)
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    w[:, rng.permutation(N)[:N // 2]] -= 0.10          # dead columns
    w = jnp.asarray(w)
    iters = 3 if smoke else 10

    # fused baseline: first call per precision = fresh trace + compile
    fused_first, fused_steady = {}, {}
    for D in (8, 6, 4, 2):
        t0 = time.perf_counter()
        dslot_matmul(x, w, backend="jnp", n_planes=D, sort_columns=True,
                     block_m=bm, block_n=bn, block_k=bk)[0] \
            .block_until_ready()
        fused_first[D] = (time.perf_counter() - t0) * 1e6
        fused_steady[D] = _timeit(
            dslot_matmul, x, w, backend="jnp", n_planes=D,
            sort_columns=True, block_m=bm, block_n=bn, block_k=bk,
            iters=iters)

    n0 = ops.prepare_call_count()
    t0 = time.perf_counter()
    prep = ops.dslot_prepare(w, relu=True, sort_columns=True, block_m=bm,
                             block_n=bn, block_k=bk, backend="jnp")
    prep = prep.with_scale(ops.calibrate_scale(x))
    prepare_us = (time.perf_counter() - t0) * 1e6
    prepares = ops.prepare_call_count() - n0

    ops.dslot_execute(prep, x, n_planes=8)[0].block_until_ready()  # warm
    n1 = ops.prepare_call_count()
    sweep = []
    for D in (8, 6, 4, 2):
        t0 = time.perf_counter()
        out, st = ops.dslot_execute(prep, x, n_planes=D)
        out.block_until_ready()
        ex_first = (time.perf_counter() - t0) * 1e6
        ex_us = _timeit(ops.dslot_execute, prep, x, n_planes=D, iters=iters)
        ref = jnp.maximum(x @ w, 0)
        rel = float(jnp.abs(out - ref).mean()
                    / (jnp.abs(ref).mean() + 1e-9))
        sweep.append({
            "n_planes": D,
            "first_call_us": {"fused": fused_first[D], "execute": ex_first},
            "precision_switch_speedup": fused_first[D] / ex_first,
            "steady_us": {"fused": fused_steady[D], "execute": ex_us},
            "execute_calls_per_s": 1e6 / ex_us,
            "skipped_frac": float(st.skipped_frac),
            "planes_used_mean": float(jnp.mean(
                st.planes_used.astype(jnp.float32))),
            "rel_err_vs_float": rel,
        })
    assert ops.prepare_call_count() == n1, \
        "execute sweep must not re-prepare weights"
    return {"smoke": smoke, "shape": [M, K, N], "block": [bm, bn, bk],
            "prepares": prepares, "prepare_us": prepare_us, "sweep": sweep}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI smoke job)")
    ap.add_argument("--json", type=str, default=None,
                    help="also write rows as JSON to this path")
    ap.add_argument("--sweep-precision", action="store_true",
                    help="measure prepare-once/execute-many amortization "
                         "and skipped-frac per runtime precision")
    ap.add_argument("--precision-json", type=str,
                    default="BENCH_precision.json",
                    help="output path for the --sweep-precision report")
    ap.add_argument("--compare-encoding", action="store_true",
                    help="fused in-kernel digit encoding vs the "
                         "materialized (D, M, K) plane-tensor baseline "
                         "(wall-clock, bytes moved, bit-exactness)")
    ap.add_argument("--kernel-json", type=str, default="BENCH_kernel.json",
                    help="output path for the --compare-encoding report")
    args = ap.parse_args()
    if args.compare_encoding:
        report = run_encoding_comparison(smoke=args.smoke)
        print("n_planes,fused_us,materialized_us")
        for row in report["sweep"]:
            print(f"{row['n_planes']},{row['wall_us']['fused']:.0f},"
                  f"{row['wall_us']['materialized']:.0f}")
        a = report["activation_stream_bytes"]
        print(f"activation stream: fused={a['fused']} B "
              f"materialized={a['materialized']} B ({a['reduction']:.1f}x)")
        i = report["max_int_tensor_bytes"]
        print(f"largest int tensor in graph: fused={i['fused']} B "
              f"materialized={i['materialized']} B "
              f"(plane tensor = {report['plane_tensor_bytes']} B)")
        b = report["bytes_accessed"]
        print(f"bytes accessed (XLA): fused={b['fused']:.0f} "
              f"materialized={b['materialized']:.0f}"
              + (f" ({b['reduction']:.2f}x)" if b["known"] else
                 " (cost_analysis unavailable: gate skipped)"))
        # write the artifact BEFORE gating so a red CI still uploads the
        # numbers that explain the regression
        with open(args.kernel_json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.kernel_json}")
        if report["violations"]:
            raise SystemExit("; ".join(report["violations"]))
        return
    if args.sweep_precision:
        report = run_precision_sweep(smoke=args.smoke)
        print("n_planes,switch_us_fused,switch_us_execute,switch_speedup,"
              "steady_us_execute,skipped_frac")
        for row in report["sweep"]:
            print(f"{row['n_planes']},{row['first_call_us']['fused']:.0f},"
                  f"{row['first_call_us']['execute']:.0f},"
                  f"{row['precision_switch_speedup']:.1f},"
                  f"{row['steady_us']['execute']:.0f},"
                  f"{row['skipped_frac']:.4f}")
        with open(args.precision_json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.precision_json}")
        return
    rows = run(smoke=args.smoke)
    print("name,value,derived")
    for row in rows:
        print(row, flush=True)
    if args.json:
        records = []
        for row in rows:
            name, value, derived = row.split(",", 2)
            records.append({"name": name, "value": value, "derived": derived})
        with open(args.json, "w") as f:
            json.dump({"smoke": args.smoke, "rows": records}, f, indent=2)


if __name__ == "__main__":
    main()
