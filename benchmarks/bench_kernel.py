"""Digit-plane DSLOT kernel benchmark: skipped-MXU-pass fraction vs output
negativity (the TPU adaptation of Fig. 9), runtime-precision scaling,
``block_k`` streaming sweep, and per-layer planes-skipped for the MNIST
network through the unified layer API — the software proxy for the paper's
energy-saving claim.  Wall-times are for the jnp path (CPU container; Pallas
numbers are structural — interpret mode is not a performance proxy).

``--sweep-precision`` measures the prepare/execute split: calls/s of
``dslot_execute`` against cached weight tables vs the fused per-call
``dslot_matmul`` (which re-sorts/re-encodes the weight side every call),
plus skipped-frac per runtime precision — written to ``BENCH_precision.json``.

Standalone CLI (used by the CI smoke job):
    python benchmarks/bench_kernel.py [--smoke] [--json out.json]
        [--sweep-precision [--precision-json BENCH_precision.json]]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ops import dslot_matmul


def _timeit(fn, *args, iters=3, **kw):
    fn(*args, **kw)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run(smoke: bool = False) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    M = K = N = 64 if smoke else 256
    x = jnp.asarray(np.maximum(rng.normal(0.3, 0.4, (M, K)), 0), jnp.float32)
    bm = bn = 32 if smoke else 64

    for dead_frac in (0.0, 0.25, 0.5, 0.75):
        w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
        n_dead = int(N * dead_frac)
        if n_dead:
            w[:, rng.permutation(N)[:n_dead]] -= 0.10
        out, st = dslot_matmul(x, jnp.asarray(w), backend="jnp",
                               sort_columns=True, block_m=bm, block_n=bn)
        rows.append(f"kernel.skipped_frac_dead{int(dead_frac*100)},"
                    f"{float(st.skipped_frac):.4f},sorted-tiles")

    # block_k streaming sweep: same workload, weights streamed through VMEM
    # in chunks.  The chunk-aware bound can only terminate earlier, so the
    # skipped fraction is monotone non-decreasing as chunks shrink.
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    w[:, rng.permutation(N)[:N // 2]] -= 0.10
    for bk in (None, K, K // 2, K // 4):
        out, st = dslot_matmul(x, jnp.asarray(w), backend="jnp",
                               sort_columns=True, block_m=bm, block_n=bn,
                               block_k=bk)
        us = _timeit(dslot_matmul, x, jnp.asarray(w), backend="jnp",
                     sort_columns=True, block_m=bm, block_n=bn, block_k=bk)
        tag = "auto" if bk is None else str(bk)
        rows.append(f"kernel.blockk{tag}_skipped_frac,"
                    f"{float(st.skipped_frac):.4f},us={us:.0f}")

    w = jnp.asarray(rng.normal(0, 0.05, (K, N)), jnp.float32)
    for D in (8, 6, 4, 2):
        us = _timeit(dslot_matmul, x, w, backend="jnp", n_planes=D,
                     block_m=bm, block_n=bn)
        out, _ = dslot_matmul(x, w, backend="jnp", n_planes=D,
                              block_m=bm, block_n=bn)
        ref = jnp.maximum(x @ w, 0)
        rel = float(jnp.abs(out - ref).mean() / (jnp.abs(ref).mean() + 1e-9))
        rows.append(f"kernel.planes{D}_us,{us:.0f},rel_err={rel:.4f}")

    # per-layer planes-skipped for the MNIST network through the layer API
    # (trained-free: random weights biased negative in the head so early
    # termination has something to kill — the per-layer reporting path is
    # what's exercised here, not the paper's accuracies).
    from repro.configs.dslot_mnist import CONFIG
    from repro.core.mnist_cnn import forward_dslot, init_cnn
    params = init_cnn(CONFIG, jax.random.PRNGKey(0))
    imgs = jnp.asarray(rng.uniform(0, 1, (4 if smoke else 16, 28, 28)),
                       jnp.float32)
    res = forward_dslot(params, imgs, CONFIG, block_m=32,
                        block_k=None if smoke else 64)
    for name, st in res.layer_stats.items():
        used = np.asarray(st.planes_used)
        rows.append(f"kernel.layer_{name}_planes_used,"
                    f"{used.mean():.3f},skipped={float(st.skipped_frac):.4f}")

    # pallas interpret-mode parity check at bench scale, tiled K
    from repro.kernels.ref import make_planes, dslot_matmul_ref
    from repro.kernels.dslot_matmul import dslot_matmul_pallas
    aq = jnp.asarray(rng.integers(0, 256, (64, 64)), jnp.int32)
    wp = jnp.asarray(rng.normal(0, 0.05, (64, 64)), jnp.float32)
    planes = make_planes(aq, 8)
    o1 = dslot_matmul_pallas(planes, wp, block_m=32, block_n=32,
                             block_k=32).out
    o2 = dslot_matmul_ref(planes, wp, 8)
    rows.append(f"kernel.pallas_vs_ref_maxerr,"
                f"{float(jnp.abs(o1 - o2).max()):.2e},interpret-tiled-k")
    return rows


def run_precision_sweep(smoke: bool = False) -> dict:
    """Prepare-once/execute-many amortization + skipped-frac per precision.

    Two costs are measured per precision D:

    * ``first_call_us`` — latency of the FIRST call at a new precision.
      The fused path takes D as a static argument, so every precision is a
      fresh trace + compile; ``dslot_execute`` takes it as a runtime value
      against cached weight tables, so switching precision costs one normal
      dispatch.  This is the serving-path win: precision becomes a
      per-request parameter instead of a recompile.
    * ``steady_us`` — steady-state per-call latency (jnp backend on CPU;
      note the split path always scans ``n_bits`` plane chunks with masked
      digits — on TPU the Pallas kernel predicates those passes off).
    """
    rng = np.random.default_rng(0)
    M = K = N = 64 if smoke else 256
    bm = bn = 32 if smoke else 64
    bk = K // 4
    x = jnp.asarray(np.maximum(rng.normal(0.3, 0.4, (M, K)), 0), jnp.float32)
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    w[:, rng.permutation(N)[:N // 2]] -= 0.10          # dead columns
    w = jnp.asarray(w)
    iters = 3 if smoke else 10

    # fused baseline: first call per precision = fresh trace + compile
    fused_first, fused_steady = {}, {}
    for D in (8, 6, 4, 2):
        t0 = time.perf_counter()
        dslot_matmul(x, w, backend="jnp", n_planes=D, sort_columns=True,
                     block_m=bm, block_n=bn, block_k=bk)[0] \
            .block_until_ready()
        fused_first[D] = (time.perf_counter() - t0) * 1e6
        fused_steady[D] = _timeit(
            dslot_matmul, x, w, backend="jnp", n_planes=D,
            sort_columns=True, block_m=bm, block_n=bn, block_k=bk,
            iters=iters)

    n0 = ops.prepare_call_count()
    t0 = time.perf_counter()
    prep = ops.dslot_prepare(w, relu=True, sort_columns=True, block_m=bm,
                             block_n=bn, block_k=bk, backend="jnp")
    prep = prep.with_scale(ops.calibrate_scale(x))
    prepare_us = (time.perf_counter() - t0) * 1e6
    prepares = ops.prepare_call_count() - n0

    ops.dslot_execute(prep, x, n_planes=8)[0].block_until_ready()  # warm
    n1 = ops.prepare_call_count()
    sweep = []
    for D in (8, 6, 4, 2):
        t0 = time.perf_counter()
        out, st = ops.dslot_execute(prep, x, n_planes=D)
        out.block_until_ready()
        ex_first = (time.perf_counter() - t0) * 1e6
        ex_us = _timeit(ops.dslot_execute, prep, x, n_planes=D, iters=iters)
        ref = jnp.maximum(x @ w, 0)
        rel = float(jnp.abs(out - ref).mean()
                    / (jnp.abs(ref).mean() + 1e-9))
        sweep.append({
            "n_planes": D,
            "first_call_us": {"fused": fused_first[D], "execute": ex_first},
            "precision_switch_speedup": fused_first[D] / ex_first,
            "steady_us": {"fused": fused_steady[D], "execute": ex_us},
            "execute_calls_per_s": 1e6 / ex_us,
            "skipped_frac": float(st.skipped_frac),
            "planes_used_mean": float(jnp.mean(
                st.planes_used.astype(jnp.float32))),
            "rel_err_vs_float": rel,
        })
    assert ops.prepare_call_count() == n1, \
        "execute sweep must not re-prepare weights"
    return {"smoke": smoke, "shape": [M, K, N], "block": [bm, bn, bk],
            "prepares": prepares, "prepare_us": prepare_us, "sweep": sweep}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI smoke job)")
    ap.add_argument("--json", type=str, default=None,
                    help="also write rows as JSON to this path")
    ap.add_argument("--sweep-precision", action="store_true",
                    help="measure prepare-once/execute-many amortization "
                         "and skipped-frac per runtime precision")
    ap.add_argument("--precision-json", type=str,
                    default="BENCH_precision.json",
                    help="output path for the --sweep-precision report")
    args = ap.parse_args()
    if args.sweep_precision:
        report = run_precision_sweep(smoke=args.smoke)
        print("n_planes,switch_us_fused,switch_us_execute,switch_speedup,"
              "steady_us_execute,skipped_frac")
        for row in report["sweep"]:
            print(f"{row['n_planes']},{row['first_call_us']['fused']:.0f},"
                  f"{row['first_call_us']['execute']:.0f},"
                  f"{row['precision_switch_speedup']:.1f},"
                  f"{row['steady_us']['execute']:.0f},"
                  f"{row['skipped_frac']:.4f}")
        with open(args.precision_json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.precision_json}")
        return
    rows = run(smoke=args.smoke)
    print("name,value,derived")
    for row in rows:
        print(row, flush=True)
    if args.json:
        records = []
        for row in rows:
            name, value, derived = row.split(",", 2)
            records.append({"name": name, "value": value, "derived": derived})
        with open(args.json, "w") as f:
            json.dump({"smoke": args.smoke, "rows": records}, f, indent=2)


if __name__ == "__main__":
    main()
