"""Digit-plane DSLOT kernel benchmark: skipped-MXU-pass fraction vs output
negativity (the TPU adaptation of Fig. 9), runtime-precision scaling,
``block_k`` streaming sweep, and per-layer planes-skipped for the MNIST
network through the unified layer API — the software proxy for the paper's
energy-saving claim.  Wall-times are for the jnp path (CPU container; Pallas
numbers are structural — interpret mode is not a performance proxy).

``--sweep-precision`` measures the prepare/execute split: calls/s of
``dslot_execute`` against cached weight tables vs the fused per-call
``dslot_matmul`` (which re-sorts/re-encodes the weight side every call),
plus skipped-frac per runtime precision — written to ``BENCH_precision.json``.

``--compare-encoding`` measures fused in-kernel digit encoding against the
pre-fusion materialized (D, M, K) plane-tensor path (kept verbatim in this
file as the baseline): wall-clock, XLA bytes-moved via
``jax.jit(...).lower().compile().cost_analysis()``, the activation-stream
footprint, and a bit-exactness cross-check — written to
``BENCH_kernel.json``.  Exits nonzero (CI-fatal) if the fused path moves
more activation bytes than the materialized one.

``--msr-profile`` profiles weight-side digit sparsity on the MNIST CNN:
per-layer MSR (Most-Significant-Run) histograms of the quantized weights,
the measured planes-ISSUED reduction from the static per-N-tile MSR bound
(``dslot_prepare(msr_bound=True)``) on a channel-pruned variant with full
forward bit-exactness against the unbounded path, and the CSD/Booth
nonzero-digit enumeration prototype (``core.csd``) head-to-head against
the dense-plane scan's digit-slot count.  Results MERGE into the same
``BENCH_kernel.json`` under ``"msr_profile"``; exits nonzero if outputs
diverge, the bound saves nothing, or CSD is not sparser than binary.

Standalone CLI (used by the CI smoke job):
    python benchmarks/bench_kernel.py [--smoke] [--json out.json]
        [--sweep-precision [--precision-json BENCH_precision.json]]
        [--compare-encoding [--kernel-json BENCH_kernel.json]]
        [--msr-profile [--kernel-json BENCH_kernel.json]]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ops import dslot_matmul


def _timeit(fn, *args, iters=3, **kw):
    fn(*args, **kw)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run(smoke: bool = False) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    M = K = N = 64 if smoke else 256
    x = jnp.asarray(np.maximum(rng.normal(0.3, 0.4, (M, K)), 0), jnp.float32)
    bm = bn = 32 if smoke else 64

    for dead_frac in (0.0, 0.25, 0.5, 0.75):
        w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
        n_dead = int(N * dead_frac)
        if n_dead:
            w[:, rng.permutation(N)[:n_dead]] -= 0.10
        out, st = dslot_matmul(x, jnp.asarray(w), backend="jnp",
                               sort_columns=True, block_m=bm, block_n=bn)
        rows.append(f"kernel.skipped_frac_dead{int(dead_frac*100)},"
                    f"{float(st.skipped_frac):.4f},sorted-tiles")

    # block_k streaming sweep: same workload, weights streamed through VMEM
    # in chunks.  The chunk-aware bound can only terminate earlier, so the
    # skipped fraction is monotone non-decreasing as chunks shrink.
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    w[:, rng.permutation(N)[:N // 2]] -= 0.10
    for bk in (None, K, K // 2, K // 4):
        out, st = dslot_matmul(x, jnp.asarray(w), backend="jnp",
                               sort_columns=True, block_m=bm, block_n=bn,
                               block_k=bk)
        us = _timeit(dslot_matmul, x, jnp.asarray(w), backend="jnp",
                     sort_columns=True, block_m=bm, block_n=bn, block_k=bk)
        tag = "auto" if bk is None else str(bk)
        rows.append(f"kernel.blockk{tag}_skipped_frac,"
                    f"{float(st.skipped_frac):.4f},us={us:.0f}")

    w = jnp.asarray(rng.normal(0, 0.05, (K, N)), jnp.float32)
    for D in (8, 6, 4, 2):
        us = _timeit(dslot_matmul, x, w, backend="jnp", n_planes=D,
                     block_m=bm, block_n=bn)
        out, _ = dslot_matmul(x, w, backend="jnp", n_planes=D,
                              block_m=bm, block_n=bn)
        ref = jnp.maximum(x @ w, 0)
        rel = float(jnp.abs(out - ref).mean() / (jnp.abs(ref).mean() + 1e-9))
        rows.append(f"kernel.planes{D}_us,{us:.0f},rel_err={rel:.4f}")

    # per-layer planes-skipped for the MNIST network through the layer API
    # (trained-free: random weights biased negative in the head so early
    # termination has something to kill — the per-layer reporting path is
    # what's exercised here, not the paper's accuracies).
    from repro.configs.dslot_mnist import CONFIG
    from repro.core.mnist_cnn import forward_dslot, init_cnn
    params = init_cnn(CONFIG, jax.random.PRNGKey(0))
    imgs = jnp.asarray(rng.uniform(0, 1, (4 if smoke else 16, 28, 28)),
                       jnp.float32)
    res = forward_dslot(params, imgs, CONFIG, block_m=32,
                        block_k=None if smoke else 64)
    for name, st in res.layer_stats.items():
        used = np.asarray(st.planes_used)
        rows.append(f"kernel.layer_{name}_planes_used,"
                    f"{used.mean():.3f},skipped={float(st.skipped_frac):.4f}")

    # pallas interpret-mode parity check at bench scale, tiled K (the kernel
    # consumes quantized activations and encodes digits in-kernel; the
    # oracle evaluates over an explicitly materialized plane tensor)
    from repro.kernels.ref import make_planes, dslot_matmul_ref
    from repro.kernels.dslot_matmul import dslot_matmul_pallas
    aq = jnp.asarray(rng.integers(0, 256, (64, 64)), jnp.int32)
    wp = jnp.asarray(rng.normal(0, 0.05, (64, 64)), jnp.float32)
    o1 = dslot_matmul_pallas(aq, wp, block_m=32, block_n=32,
                             block_k=32).out
    o2 = dslot_matmul_ref(make_planes(aq, 8), wp, 8)
    rows.append(f"kernel.pallas_vs_ref_maxerr,"
                f"{float(jnp.abs(o1 - o2).max()):.2e},interpret-tiled-k")
    return rows


# --------------------------------------------------- encoding comparison

def _materialized_execute(prep, x, npl):
    """The PRE-FUSION execution path, kept verbatim as the benchmark
    baseline: encode ALL digit planes of the quantized activations into a
    (D, M, K) int8 tensor, restack it into per-step chunks, then stream the
    planes through the same scaled-matmul scan with the same chunk-aware
    termination replay.  This is what ``dslot_execute`` did before digit
    encoding was fused into the kernels — byte-for-byte the old dataflow,
    so the fused path can be gated on (a) moving strictly fewer bytes and
    (b) bit-exact outputs/planes_used against it.
    """
    from repro.kernels.ref import make_planes

    cfg = prep
    M, K = x.shape
    q, step = ops.quantize_activations(x, n_bits=cfg.n_bits,
                                       signed=cfg.signed, scale=cfg.x_scale)
    planes = make_planes(q, cfg.n_bits)                     # (D, M, K) HBM
    D = planes.shape[0]
    npl_c = jnp.clip(jnp.asarray(npl, jnp.int32), 1, D)
    pmask = (jnp.arange(D) < npl_c)[:, None, None]
    planes = planes * pmask.astype(planes.dtype)
    planes = jnp.pad(planes, [(0, 0), (0, (-M) % cfg.block_m),
                              (0, cfg.w.shape[0] - K)])
    D, Mp, Kp = planes.shape
    N = cfg.w.shape[1]
    bk = cfg.block_k
    Kt = Kp // bk
    Mt, Nt = Mp // cfg.block_m, N // cfg.block_n
    w_chunks = cfg.w.astype(jnp.float32).reshape(Kt, bk, N)
    # the old layout: every plane of every chunk, stacked — D*M*K int8
    p_chunks = planes.reshape(D, Mp, Kt, bk).transpose(0, 2, 1, 3) \
        .reshape(D * Kt, Mp, bk)
    scales = jnp.exp2(jnp.asarray(cfg.n_bits - 1, jnp.float32)
                      - jnp.arange(D, dtype=jnp.float32))
    tail = jnp.exp2(jnp.asarray(cfg.n_bits, jnp.float32)
                    - npl_c.astype(jnp.float32))
    step_rem = (scales[:, None, None] * cfg.suffix_colsum[None]
                + ((scales - tail)[:, None, None]
                   * cfg.total_colsum[0][None, None, :])).reshape(D * Kt, N)

    def body(acc, s):
        p, c, scale, rem = s
        wc = jax.lax.dynamic_index_in_dim(w_chunks, c, keepdims=False)
        acc = acc + scale * jnp.dot(p.astype(jnp.float32), wc,
                                    preferred_element_type=jnp.float32)
        dead = jnp.all((acc + rem[None, :]).reshape(
            Mt, cfg.block_m, Nt, cfg.block_n) < 0.0, axis=(1, 3))
        return acc, dead

    c_idx = jnp.tile(jnp.arange(Kt), D)
    acc, dead_after = jax.lax.scan(
        body, jnp.zeros((Mp, N), jnp.float32),
        (p_chunks, c_idx, jnp.repeat(scales, Kt), step_rem))
    out = jnp.maximum(acc, 0.0)
    ever = jnp.any(dead_after, axis=0)
    first = jnp.argmax(dead_after, axis=0)
    used = jnp.where(ever, first // Kt + 1, D).astype(jnp.int32)
    used = jnp.minimum(used, npl_c)
    return out[:M, :cfg.d_out] * step, used


def _bytes_accessed(fn, *args) -> float:
    cost = jax.jit(fn).lower(*args).compile().cost_analysis()
    if not isinstance(cost, dict):                  # some versions: [dict]
        cost = cost[0]
    return float(cost.get("bytes accessed", float("nan")))


def _max_int_tensor_bytes(fn, *args) -> int:
    """Largest integer-typed tensor anywhere in ``fn``'s jaxpr, in bytes.

    The structural detector for a reintroduced digit-plane materialization:
    the old path's (D, M, K) plane tensor (or its (D*Kt, M, bk) restack) is
    by far the largest integer intermediate either path could create, so
    'fused max int tensor < plane-tensor bytes' proves no plane-sized
    activation encoding exists in the traced graph — independent of
    whatever XLA's cost model reports.
    """
    import re

    txt = str(jax.make_jaxpr(fn)(*args))            # includes scan bodies
    best = 0
    for m in re.finditer(r"\b[iu](\d+)\[([\d,]+)\]", txt):
        elems = 1
        for d in m.group(2).split(","):
            elems *= int(d)
        best = max(best, elems * int(m.group(1)) // 8)
    return best


def run_encoding_comparison(smoke: bool = False) -> dict:
    """Fused in-kernel digit encoding vs the materialized (D, M, K) plane
    tensor: wall-clock, XLA bytes-moved (``cost_analysis``), the
    activation-stream footprint each path hands to its compute, and a
    bit-exactness cross-check.  Emits the ``BENCH_kernel.json`` payload;
    byte regressions (fused moving MORE than materialized, or a <4x
    activation-stream reduction) are recorded in ``report["violations"]``
    and turned into a nonzero exit by the CLI AFTER the artifact is
    written; diverging outputs/planes_used raise immediately.
    """
    from repro.kernels.dslot_matmul import q_storage_dtype

    rng = np.random.default_rng(0)
    M = K = N = 64 if smoke else 256
    bm = bn = 32 if smoke else 64
    bk = K // 2
    n_bits = 8
    x = jnp.asarray(np.maximum(rng.normal(0.3, 0.4, (M, K)), 0), jnp.float32)
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    w[:, rng.permutation(N)[:N // 2]] -= 0.10
    prep = ops.dslot_prepare(jnp.asarray(w), n_bits=n_bits, relu=True,
                             block_m=bm, block_n=bn, block_k=bk,
                             backend="jnp")
    prep = prep.with_scale(ops.calibrate_scale(x))
    iters = 3 if smoke else 10

    def fused(prep, x, npl):
        return ops._execute_core(prep, x, npl)

    fused_jit = jax.jit(fused)
    mat_jit = jax.jit(_materialized_execute)
    report = {"smoke": smoke, "shape": [M, K, N], "block": [bm, bn, bk],
              "n_bits": n_bits, "sweep": [], "violations": []}
    D = n_bits
    q_itemsize = q_storage_dtype(n_bits, prep.signed).itemsize
    Kp = prep.w.shape[0]
    # bytes moved are a property of the lowered graph, not of the traced
    # runtime precision — measure each path once, outside the sweep
    npl0 = jnp.asarray(n_bits, jnp.int32)
    fused_bytes = _bytes_accessed(fused, prep, x, npl0)
    mat_bytes = _bytes_accessed(_materialized_execute, prep, x, npl0)
    bytes_known = not (np.isnan(fused_bytes) or np.isnan(mat_bytes))
    # structural gate on the REAL traced graphs: the fused path must not
    # contain any plane-tensor-sized integer intermediate (and the detector
    # is validated against the materialized path, which must contain one)
    plane_bytes = D * ((M + bm - 1) // bm * bm) * Kp
    fused_int_max = _max_int_tensor_bytes(fused, prep, x, npl0)
    mat_int_max = _max_int_tensor_bytes(_materialized_execute, prep, x, npl0)
    assert mat_int_max >= plane_bytes, \
        (mat_int_max, plane_bytes, "detector failed to see the plane tensor")
    report["plane_tensor_bytes"] = plane_bytes
    report["max_int_tensor_bytes"] = {"fused": fused_int_max,
                                      "materialized": mat_int_max}
    if fused_int_max >= plane_bytes:
        report["violations"].append(
            f"fused graph contains a plane-tensor-sized integer "
            f"intermediate ({fused_int_max} >= {plane_bytes} B): digit "
            f"encoding is being materialized again")
    # the activation-stream model (what each path hands its kernel/scan):
    # analytic by construction; the structural gate above checks the graph
    act_fused = M * Kp * q_itemsize
    act_mat = D * M * Kp * 1
    if act_mat / act_fused < 4.0:
        report["violations"].append(
            f"activation-stream reduction {act_mat / act_fused:.1f}x "
            f"< 4x at n_bits={n_bits}")
    for npl_i in (8, 4, 2):
        npl = jnp.asarray(npl_i, jnp.int32)
        of, sf = fused_jit(prep, x, npl)
        om, um = mat_jit(prep, x, npl)
        np.testing.assert_array_equal(np.asarray(of), np.asarray(om),
                                      err_msg=f"n_planes={npl_i}")
        np.testing.assert_array_equal(np.asarray(sf.planes_used),
                                      np.asarray(um),
                                      err_msg=f"n_planes={npl_i}")
        fused_us = _timeit(fused_jit, prep, x, npl, iters=iters)
        mat_us = _timeit(mat_jit, prep, x, npl, iters=iters)
        report["sweep"].append({
            "n_planes": npl_i,
            "wall_us": {"fused": fused_us, "materialized": mat_us},
            "bit_exact": True,
        })
    # the activation tensor each path streams through its compute: the
    # fused kernels read the quantized block itself; the old path wrote
    # and re-read every digit plane of it
    report["activation_stream_bytes"] = {
        "fused": act_fused, "materialized": act_mat,
        "reduction": act_mat / act_fused}
    report["bytes_accessed"] = {
        "fused": fused_bytes, "materialized": mat_bytes,
        "known": bytes_known,
        "reduction": mat_bytes / fused_bytes if bytes_known else None}
    if bytes_known and fused_bytes > mat_bytes:
        report["violations"].append(
            f"fused path moves MORE bytes than materialized: "
            f"{fused_bytes} > {mat_bytes}")
    return report


# --------------------------------------------------- weight-side sparsity

def run_msr_profile(smoke: bool = False) -> dict:
    """Weight-side digit sparsity on the paper's MNIST CNN.

    Three measurements, one artifact block:

    * **MSR histograms** — per-layer Most-Significant-Run depth of the
      int8-quantized weights (``core.msr.msr_histogram``), the trained-net
      statistic the static plane bound exploits.
    * **Static MSR bound, measured** — the network's conv layer is
      structurally pruned (the weakest half of its output channels zeroed
      — the standard dead-neuron deployment transform) and prepared with
      ``sort_columns=True`` so the zero columns cluster into whole N-tiles;
      the same prepared state runs with and without ``msr_bound`` and the
      report carries Σ planes-issued (and MXU passes) for both, gated on
      (a) bit-identical logits and (b) a strictly positive reduction.
      The unpruned network is profiled alongside for honesty: dense random
      weights have no output-inert tile, so its reduction is 0 — the bound
      is a *sparsity* win, not a free lunch.
    * **CSD head-to-head** — the activations' CSD/Booth recoding
      (``core.csd``) vs plain binary vs the dense plane scan: essential
      (nonzero) digit count per path, with ``csd_matmul`` asserted
      bit-equal to the integer product ``q @ w_q``.
    """
    import dataclasses

    from repro.configs.dslot_mnist import CONFIG
    from repro.core.conv import im2col
    from repro.core.csd import (binary_digit_count, csd_matmul, csd_recode,
                                essential_digit_count)
    from repro.core.mnist_cnn import _pool_flatten, init_cnn
    from repro.core.msr import msr_histogram, quantize_weights
    from repro.layers import DslotConv2d, DslotDense

    rng = np.random.default_rng(0)
    cfg = CONFIG
    m, k = cfg.conv_channels, cfg.kernel_size
    side = (cfg.image_size - k + 1) // cfg.pool
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    imgs = jnp.asarray(rng.uniform(0, 1, (4 if smoke else 16, 28, 28)),
                       jnp.float32)

    # conv weights as the (k*k, M) im2col matrix the kernel actually sees
    conv_mat = np.asarray(jnp.transpose(params.conv, (1, 2, 0))
                          .reshape(k * k, m))
    dense_mat = np.asarray(params.dense)

    # structured pruning: zero the weakest half of the conv output channels
    l2 = np.linalg.norm(conv_mat, axis=0)
    pruned_ch = np.argsort(l2)[:m // 2]
    conv_pruned = conv_mat.copy()
    conv_pruned[:, pruned_ch] = 0.0

    report = {"smoke": smoke, "n_bits": cfg.n_bits,
              "pruned_channels": sorted(int(c) for c in pruned_ch),
              "violations": [],
              "msr_histograms": {
                  "conv1": msr_histogram(jnp.asarray(conv_mat), cfg.n_bits),
                  "conv1_pruned": msr_histogram(jnp.asarray(conv_pruned),
                                                cfg.n_bits),
                  "dense1": msr_histogram(jnp.asarray(dense_mat),
                                          cfg.n_bits)}}

    def _forward(conv_w, *, msr_bound):
        """Full-network forward through the layer API; returns logits and
        Σ planes-issued / Σ MXU passes / Σ planes-bounded per layer."""
        conv = DslotConv2d(in_channels=1, out_channels=m, kernel_size=k,
                           name="conv1", n_bits=cfg.n_bits, relu=True,
                           sort_columns=True, block_m=32, block_n=2)
        head = DslotDense(d_in=m * side * side, d_out=cfg.n_classes,
                          name="dense1", n_bits=cfg.n_bits, relu=False,
                          signed=False, block_m=32, block_n=2)
        wc = jnp.asarray(conv_w).reshape(k, k, 1, m)
        cp = conv.prepare({"w": wc})
        hp = head.prepare({"w": jnp.asarray(dense_mat)})
        if not msr_bound:
            cp = {**cp, "dslot": dataclasses.replace(cp["dslot"],
                                                     msr_bound=None)}
            hp = {**hp, "dslot": dataclasses.replace(hp["dslot"],
                                                     msr_bound=None)}
        x, conv_st = conv.apply(cp, imgs[..., None])
        logits, head_st = head.apply(hp, _pool_flatten(x, cfg))
        layers = {}
        for name, st, prep in (("conv1", conv_st, cp["dslot"]),
                               ("dense1", head_st, hp["dslot"])):
            Kt = prep.w.shape[0] // prep.block_k
            issued = int(np.asarray(st.planes_used).sum())
            layers[name] = {
                "planes_issued": issued,
                "mxu_passes": issued * Kt,
                "planes_bounded": (0 if st.planes_bounded is None else
                                   int(np.asarray(st.planes_bounded).sum())),
                "bound_table": (None if prep.msr_bound is None else
                                np.asarray(prep.msr_bound).tolist()),
            }
        return np.asarray(logits), layers

    for tag, conv_w in (("pruned", conv_pruned), ("unpruned", conv_mat)):
        yb, lb = _forward(conv_w, msr_bound=True)
        yu, lu = _forward(conv_w, msr_bound=False)
        np.testing.assert_array_equal(
            yb, yu, err_msg=f"MSR bound changed {tag} logits")
        issued_b = sum(d["planes_issued"] for d in lb.values())
        issued_u = sum(d["planes_issued"] for d in lu.values())
        passes_b = sum(d["mxu_passes"] for d in lb.values())
        passes_u = sum(d["mxu_passes"] for d in lu.values())
        report[tag] = {
            "bit_exact": True,
            "layers": {n: {"bounded": lb[n], "unbounded": lu[n]}
                       for n in lb},
            "planes_issued": {"bounded": issued_b, "unbounded": issued_u,
                              "reduction": 1.0 - issued_b / issued_u},
            "mxu_passes": {"bounded": passes_b, "unbounded": passes_u,
                           "reduction": 1.0 - passes_b / passes_u},
        }
    if report["pruned"]["planes_issued"]["reduction"] <= 0.0:
        report["violations"].append(
            "MSR bound saved no issued planes on the pruned CNN "
            f"({report['pruned']['planes_issued']})")

    # CSD/Booth nonzero-digit enumeration vs the dense-plane scan, on the
    # conv layer's real activation stream (im2col'd images, quantized)
    cols = im2col(imgs[..., None], k, 1, "valid").reshape(-1, k * k)
    q, _ = ops.quantize_activations(cols, n_bits=cfg.n_bits, signed=False)
    q = q[:64 if smoke else 512]
    w_q = quantize_weights(jnp.asarray(conv_mat), cfg.n_bits)
    out_csd, nz_planes = csd_matmul(q, w_q, cfg.n_bits)
    np.testing.assert_array_equal(
        np.asarray(out_csd), np.asarray(q) @ np.asarray(w_q),
        err_msg="CSD matmul diverged from the integer product")
    essential = int(essential_digit_count(csd_recode(q, cfg.n_bits)))
    binary = int(binary_digit_count(q, cfg.n_bits))
    dense_slots = cfg.n_bits * int(q.size)
    report["csd"] = {
        "bit_exact": True,
        "activation_rows": int(q.shape[0]),
        "essential_digits_csd": essential,
        "nonzero_digits_binary": binary,
        "dense_plane_digit_slots": dense_slots,
        "nonzero_planes": int(nz_planes),
        "csd_vs_dense_reduction": 1.0 - essential / dense_slots,
        "csd_vs_binary_reduction": 1.0 - essential / max(binary, 1),
    }
    if essential > binary:
        report["violations"].append(
            f"CSD recoding is denser than binary ({essential} > {binary})")
    return report


def run_precision_sweep(smoke: bool = False) -> dict:
    """Prepare-once/execute-many amortization + skipped-frac per precision.

    Two costs are measured per precision D:

    * ``first_call_us`` — latency of the FIRST call at a new precision.
      The fused path takes D as a static argument, so every precision is a
      fresh trace + compile; ``dslot_execute`` takes it as a runtime value
      against cached weight tables, so switching precision costs one normal
      dispatch.  This is the serving-path win: precision becomes a
      per-request parameter instead of a recompile.
    * ``steady_us`` — steady-state per-call latency (jnp backend on CPU;
      note the split path always scans ``n_bits`` plane chunks with masked
      digits — on TPU the Pallas kernel predicates those passes off).
    """
    rng = np.random.default_rng(0)
    M = K = N = 64 if smoke else 256
    bm = bn = 32 if smoke else 64
    bk = K // 4
    x = jnp.asarray(np.maximum(rng.normal(0.3, 0.4, (M, K)), 0), jnp.float32)
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    w[:, rng.permutation(N)[:N // 2]] -= 0.10          # dead columns
    w = jnp.asarray(w)
    iters = 3 if smoke else 10

    # fused baseline: first call per precision = fresh trace + compile
    fused_first, fused_steady = {}, {}
    for D in (8, 6, 4, 2):
        t0 = time.perf_counter()
        dslot_matmul(x, w, backend="jnp", n_planes=D, sort_columns=True,
                     block_m=bm, block_n=bn, block_k=bk)[0] \
            .block_until_ready()
        fused_first[D] = (time.perf_counter() - t0) * 1e6
        fused_steady[D] = _timeit(
            dslot_matmul, x, w, backend="jnp", n_planes=D,
            sort_columns=True, block_m=bm, block_n=bn, block_k=bk,
            iters=iters)

    n0 = ops.prepare_call_count()
    t0 = time.perf_counter()
    prep = ops.dslot_prepare(w, relu=True, sort_columns=True, block_m=bm,
                             block_n=bn, block_k=bk, backend="jnp")
    prep = prep.with_scale(ops.calibrate_scale(x))
    prepare_us = (time.perf_counter() - t0) * 1e6
    prepares = ops.prepare_call_count() - n0

    ops.dslot_execute(prep, x, n_planes=8)[0].block_until_ready()  # warm
    n1 = ops.prepare_call_count()
    sweep = []
    for D in (8, 6, 4, 2):
        t0 = time.perf_counter()
        out, st = ops.dslot_execute(prep, x, n_planes=D)
        out.block_until_ready()
        ex_first = (time.perf_counter() - t0) * 1e6
        ex_us = _timeit(ops.dslot_execute, prep, x, n_planes=D, iters=iters)
        ref = jnp.maximum(x @ w, 0)
        rel = float(jnp.abs(out - ref).mean()
                    / (jnp.abs(ref).mean() + 1e-9))
        sweep.append({
            "n_planes": D,
            "first_call_us": {"fused": fused_first[D], "execute": ex_first},
            "precision_switch_speedup": fused_first[D] / ex_first,
            "steady_us": {"fused": fused_steady[D], "execute": ex_us},
            "execute_calls_per_s": 1e6 / ex_us,
            "skipped_frac": float(st.skipped_frac),
            "planes_used_mean": float(jnp.mean(
                st.planes_used.astype(jnp.float32))),
            "rel_err_vs_float": rel,
        })
    assert ops.prepare_call_count() == n1, \
        "execute sweep must not re-prepare weights"
    return {"smoke": smoke, "shape": [M, K, N], "block": [bm, bn, bk],
            "prepares": prepares, "prepare_us": prepare_us, "sweep": sweep}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI smoke job)")
    ap.add_argument("--json", type=str, default=None,
                    help="also write rows as JSON to this path")
    ap.add_argument("--sweep-precision", action="store_true",
                    help="measure prepare-once/execute-many amortization "
                         "and skipped-frac per runtime precision")
    ap.add_argument("--precision-json", type=str,
                    default="BENCH_precision.json",
                    help="output path for the --sweep-precision report")
    ap.add_argument("--compare-encoding", action="store_true",
                    help="fused in-kernel digit encoding vs the "
                         "materialized (D, M, K) plane-tensor baseline "
                         "(wall-clock, bytes moved, bit-exactness)")
    ap.add_argument("--kernel-json", type=str, default="BENCH_kernel.json",
                    help="output path for the --compare-encoding and "
                         "--msr-profile reports (merged, not clobbered)")
    ap.add_argument("--msr-profile", action="store_true",
                    help="weight-side digit sparsity: per-layer MSR "
                         "histograms, static-bound planes-issued reduction "
                         "on the MNIST CNN (bit-exact gated), and the "
                         "CSD/Booth vs dense-plane digit count")
    args = ap.parse_args()
    if args.msr_profile:
        import os
        report = run_msr_profile(smoke=args.smoke)
        for tag in ("pruned", "unpruned"):
            pi = report[tag]["planes_issued"]
            print(f"{tag}: planes issued {pi['bounded']} bounded vs "
                  f"{pi['unbounded']} unbounded "
                  f"({pi['reduction']:.1%} reduction, bit-exact)")
        c = report["csd"]
        print(f"csd: {c['essential_digits_csd']} essential digits vs "
              f"{c['nonzero_digits_binary']} binary nonzeros vs "
              f"{c['dense_plane_digit_slots']} dense plane slots "
              f"({c['csd_vs_dense_reduction']:.1%} vs dense)")
        # merge into the shared kernel artifact: --compare-encoding runs
        # earlier in the CI job and owns the top-level keys
        merged = {}
        if os.path.exists(args.kernel_json):
            with open(args.kernel_json) as f:
                merged = json.load(f)
        merged["msr_profile"] = report
        with open(args.kernel_json, "w") as f:
            json.dump(merged, f, indent=2)
        print(f"merged msr_profile into {args.kernel_json}")
        if report["violations"]:
            raise SystemExit("; ".join(report["violations"]))
        return
    if args.compare_encoding:
        report = run_encoding_comparison(smoke=args.smoke)
        print("n_planes,fused_us,materialized_us")
        for row in report["sweep"]:
            print(f"{row['n_planes']},{row['wall_us']['fused']:.0f},"
                  f"{row['wall_us']['materialized']:.0f}")
        a = report["activation_stream_bytes"]
        print(f"activation stream: fused={a['fused']} B "
              f"materialized={a['materialized']} B ({a['reduction']:.1f}x)")
        i = report["max_int_tensor_bytes"]
        print(f"largest int tensor in graph: fused={i['fused']} B "
              f"materialized={i['materialized']} B "
              f"(plane tensor = {report['plane_tensor_bytes']} B)")
        b = report["bytes_accessed"]
        print(f"bytes accessed (XLA): fused={b['fused']:.0f} "
              f"materialized={b['materialized']:.0f}"
              + (f" ({b['reduction']:.2f}x)" if b["known"] else
                 " (cost_analysis unavailable: gate skipped)"))
        # write the artifact BEFORE gating so a red CI still uploads the
        # numbers that explain the regression
        with open(args.kernel_json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.kernel_json}")
        if report["violations"]:
            raise SystemExit("; ".join(report["violations"]))
        return
    if args.sweep_precision:
        report = run_precision_sweep(smoke=args.smoke)
        print("n_planes,switch_us_fused,switch_us_execute,switch_speedup,"
              "steady_us_execute,skipped_frac")
        for row in report["sweep"]:
            print(f"{row['n_planes']},{row['first_call_us']['fused']:.0f},"
                  f"{row['first_call_us']['execute']:.0f},"
                  f"{row['precision_switch_speedup']:.1f},"
                  f"{row['steady_us']['execute']:.0f},"
                  f"{row['skipped_frac']:.4f}")
        with open(args.precision_json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.precision_json}")
        return
    rows = run(smoke=args.smoke)
    print("name,value,derived")
    for row in rows:
        print(row, flush=True)
    if args.json:
        records = []
        for row in rows:
            name, value, derived = row.split(",", 2)
            records.append({"name": name, "value": value, "derived": derived})
        with open(args.json, "w") as f:
            json.dump({"smoke": args.smoke, "rows": records}, f, indent=2)


if __name__ == "__main__":
    main()
