"""Digit-plane DSLOT kernel benchmark: skipped-MXU-pass fraction vs output
negativity (the TPU adaptation of Fig. 9), runtime-precision scaling, and
wall-time of the jnp path (CPU container; Pallas numbers are structural —
interpret mode is not a performance proxy)."""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.ops import dslot_matmul


def _timeit(fn, *args, iters=3, **kw):
    fn(*args, **kw)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    M, K, N = 256, 256, 256
    x = jnp.asarray(np.maximum(rng.normal(0.3, 0.4, (M, K)), 0), jnp.float32)

    for dead_frac in (0.0, 0.25, 0.5, 0.75):
        w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
        n_dead = int(N * dead_frac)
        if n_dead:
            w[:, rng.permutation(N)[:n_dead]] -= 0.10
        out, st = dslot_matmul(x, jnp.asarray(w), backend="jnp",
                               sort_columns=True, block_m=64, block_n=64)
        rows.append(f"kernel.skipped_frac_dead{int(dead_frac*100)},"
                    f"{float(st.skipped_frac):.4f},sorted-tiles")

    w = jnp.asarray(rng.normal(0, 0.05, (K, N)), jnp.float32)
    for D in (8, 6, 4, 2):
        us = _timeit(dslot_matmul, x, w, backend="jnp", n_planes=D,
                     block_m=64, block_n=64)
        out, _ = dslot_matmul(x, w, backend="jnp", n_planes=D,
                              block_m=64, block_n=64)
        ref = jnp.maximum(x @ w, 0)
        rel = float(jnp.abs(out - ref).mean() / (jnp.abs(ref).mean() + 1e-9))
        rows.append(f"kernel.planes{D}_us,{us:.0f},rel_err={rel:.4f}")

    # pallas interpret-mode parity check at bench scale (small shape)
    from repro.kernels.ref import make_planes, dslot_matmul_ref
    from repro.kernels.dslot_matmul import dslot_matmul_pallas
    aq = jnp.asarray(rng.integers(0, 256, (64, 64)), jnp.int32)
    wp = jnp.asarray(rng.normal(0, 0.05, (64, 64)), jnp.float32)
    planes = make_planes(aq, 8)
    o1 = dslot_matmul_pallas(planes, wp, block_m=32, block_n=32).out
    o2 = dslot_matmul_ref(planes, wp, 8)
    rows.append(f"kernel.pallas_vs_ref_maxerr,"
                f"{float(jnp.abs(o1 - o2).max()):.2e},interpret-mode")
    return rows
