"""Serving-layer benchmark: time-to-first-token and decode-stall under
staggered admissions, with and without the chunked-prefill pipeline.

What it measures (all wall-clock, host-synchronized — ``ServeEngine.step``
device-gets the sampled tokens, so ``perf_counter`` around it is honest):

* ``prefill_full_ms`` — one full-prompt prefill forward.  This is exactly
  what the pre-pipeline blocking ``try_add`` cost every live slot per
  admission.
* ``decode_step_ms`` — steady-state pooled decode step, no admission work.
* ``step_admission_ms`` — a decode step with one chunk of admission work
  riding along (median over a long prompt's prefill steps).
* ``decode_stall_ms = step_admission_ms - decode_step_ms`` — what an
  admission now costs the live slots per step.  The acceptance bar is
  ``decode_stall_ms < prefill_full_ms`` strictly: chunked admission must
  beat parking the pool for a whole prompt.
* per-request TTFT (steps and ms) under a staggered admission schedule.

Emits ``BENCH_serve.json``.  CPU numbers from the tiny reduced config are a
scheduling proxy, not TPU performance; the *ratios* (stall vs full prefill)
are the contract.

Standalone CLI (used by the CI smoke job):
    python benchmarks/bench_serve.py [--smoke] [--json BENCH_serve.json]
        [--prompt-len N] [--chunk N] [--slots N]
"""

from __future__ import annotations

import argparse
import json
import statistics
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS
from repro.models.model_zoo import build_model
from repro.serve import Request, ServeConfig, ServeEngine


def _mk_prompt(rng, n, vocab):
    return rng.integers(0, vocab, size=n).astype(np.int32)


def _timed_step(eng):
    t0 = time.perf_counter()
    done = eng.step()
    return (time.perf_counter() - t0) * 1e3, done


def run(prompt_len: int, chunk: int, n_slots: int, max_new: int,
        smoke: bool) -> dict:
    cfg = ARCHS["olmo-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    max_len = prompt_len + max_new + 8

    # ---- baseline: one full-prompt prefill forward (the blocking cost)
    full = {"tokens": jnp.asarray(_mk_prompt(rng, prompt_len,
                                             cfg.vocab_size)[None])}
    model.prefill(params, full, max_len=max_len)[0].block_until_ready()
    reps = 2 if smoke else 5
    t0 = time.perf_counter()
    for _ in range(reps):
        model.prefill(params, full, max_len=max_len)[0].block_until_ready()
    prefill_full_ms = (time.perf_counter() - t0) / reps * 1e3

    # ---- engine with live decoding slots
    eng = ServeEngine(model, params, n_slots=n_slots, max_len=max_len,
                      serve_config=ServeConfig(prefill_chunk=chunk))
    live = [Request(uid=100 + i,
                    prompt=_mk_prompt(rng, chunk, cfg.vocab_size),
                    max_new=max_len - chunk - 1)
            for i in range(n_slots - 1)]
    for r in live:
        eng.try_add(r)
    # warmup: admissions trace the chunk/extend/decode shapes once
    warm = Request(uid=0, prompt=_mk_prompt(rng, prompt_len, cfg.vocab_size),
                   max_new=1)
    eng.try_add(warm)
    while not warm.done:
        eng.step()

    # steady-state decode, no admission in flight
    plain = [_timed_step(eng)[0] for _ in range(3 if smoke else 10)]
    decode_step_ms = statistics.median(plain)

    # ---- staggered chunked admissions: step times while prefill in flight
    admit_times, ttft = [], []
    n_admissions = 2 if smoke else 4
    for a in range(n_admissions):
        req = Request(uid=a + 1,
                      prompt=_mk_prompt(rng, prompt_len, cfg.vocab_size),
                      max_new=max_new)
        t_enq = time.perf_counter()
        if not eng.try_add(req):
            raise RuntimeError(f"admission queue rejected uid {req.uid}")
        while req.phase in ("pending", "prefilling"):
            ms, _ = _timed_step(eng)
            # only steps that actually carried admission work count toward
            # the stall metric — a step spent waiting for a free slot
            # (phase still "pending" afterwards) ran zero chunks and would
            # deflate the median toward the plain decode time
            if req.phase != "pending":
                admit_times.append(ms)
        ttft_ms = (time.perf_counter() - t_enq) * 1e3
        ttft.append({"uid": req.uid, "prompt_len": prompt_len,
                     "ttft_steps": req.ttft_steps, "ttft_ms": ttft_ms})
        for _ in range(2):                       # let the pool breathe
            eng.step()

    step_admission_ms = statistics.median(admit_times)
    decode_stall_ms = max(0.0, step_admission_ms - decode_step_ms)
    return {
        "config": {"arch": "olmo-1b.reduced", "n_slots": n_slots,
                   "max_len": max_len, "prompt_len": prompt_len,
                   "prefill_chunk": chunk, "max_new": max_new,
                   "smoke": smoke},
        "prefill_full_ms": round(prefill_full_ms, 3),
        "decode_step_ms": round(decode_step_ms, 3),
        "step_admission_ms": round(step_admission_ms, 3),
        "decode_stall_ms": round(decode_stall_ms, 3),
        "stall_below_full_prefill": decode_stall_ms < prefill_full_ms,
        "ttft": ttft,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes / few reps for CI")
    ap.add_argument("--json", default="BENCH_serve.json")
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--chunk", type=int, default=None)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()
    prompt_len = args.prompt_len if args.prompt_len is not None \
        else (48 if args.smoke else 192)
    chunk = args.chunk if args.chunk is not None \
        else (8 if args.smoke else 16)

    out = run(prompt_len, chunk, args.slots, args.max_new, args.smoke)
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"full-prompt prefill     {out['prefill_full_ms']:9.2f} ms")
    print(f"decode step (no admit)  {out['decode_step_ms']:9.2f} ms")
    print(f"decode step (+1 chunk)  {out['step_admission_ms']:9.2f} ms")
    print(f"decode stall/admission  {out['decode_stall_ms']:9.2f} ms  "
          f"({'OK' if out['stall_below_full_prefill'] else 'FAIL'}: "
          f"< full prefill)")
    for t in out["ttft"]:
        print(f"  ttft uid={t['uid']}: {t['ttft_steps']} steps, "
              f"{t['ttft_ms']:.1f} ms")
    print(f"wrote {args.json}")
    if not out["stall_below_full_prefill"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
