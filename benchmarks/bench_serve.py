"""Serving-layer benchmark: time-to-first-token and decode-stall under
staggered admissions, with and without the chunked-prefill pipeline.

What it measures (all wall-clock, host-synchronized — ``ServeEngine.step``
device-gets the sampled tokens, so ``perf_counter`` around it is honest):

* ``prefill_full_ms`` — one full-prompt prefill forward.  This is exactly
  what the pre-pipeline blocking ``try_add`` cost every live slot per
  admission.
* ``decode_step_ms`` — steady-state pooled decode step, no admission work.
* ``step_admission_ms`` — a decode step with one chunk of admission work
  riding along (median over a long prompt's prefill steps).
* ``decode_stall_ms = step_admission_ms - decode_step_ms`` — what an
  admission now costs the live slots per step.  The acceptance bar is
  ``decode_stall_ms < prefill_full_ms`` strictly: chunked admission must
  beat parking the pool for a whole prompt.
* per-request TTFT (steps and ms) under a staggered admission schedule.
* BURST admission (``"burst"`` key): N prompts enqueued at once, drained
  sequentially (``chunks_per_step=1``) vs batched (``chunks_per_step>1``,
  co-batched admission lanes).  Reports TTFT p50/p95 (ms and engine steps)
  and the total decode-stall of draining the burst.  The acceptance bar is
  the STEPS-domain form of "batched <= sequential stall", which is
  deterministic: every admission step stalls the pool exactly once, and
  batched admission must stall the pool on no more steps — and reach every
  request's first token in no more steps — than the sequential drain
  (expected: K-fold fewer with K lanes).  Wall-clock stall totals are
  reported alongside but NOT gated: at smoke scale a chunk forward is
  ~1-4 ms, so the ms-domain difference of two drains is timer-noise-bound
  on shared CI runners (the per-step cost bound is already gated by
  ``decode_stall_ms < prefill_full_ms`` above).

* ZOO-STACK bursts (``"burst_swa"`` / ``"burst_ssm"`` keys, PR 10): the
  same sequential-vs-batched burst drain on a sliding-window-attention
  stack (``h2o-danube-3-4b`` reduced) and a recurrent SSM stack
  (``mamba2-780m`` reduced).  Batched admission is no longer an
  attention-only fast path — every zoo stack rides the lanes — so each of
  these carries the same deterministic steps-domain gate
  (``batched_stall_leq_sequential``) as the primary burst.

* OVERLOAD (``"overload"`` key): the SLO control loop under a 4x burst.  A
  calibrated DSLOT model serves ``4 * n_slots`` requests enqueued at once,
  tiers cycling reserved/standard/degradable, with ``ServeConfig.slo`` set.
  Reports the accuracy-vs-latency Pareto sweep per QoS tier — mean planes
  actually executed (the accuracy/energy side) against p95 TTFT in ENGINE
  STEPS (the deterministic latency domain) — plus the weight-side
  ``mean_planes_bounded`` (digit planes never issued because of the static
  MSR bound baked into the prepared weights; request-independent, so it
  compounds with per-tier shedding) and the controller account
  (shed/restore events, minimum levels).  Gated (steps domain, so CI-safe):
  p95 TTFT stays within the analytic drain bound, the degradable tier's
  mean planes degrades below full precision (shedding did real work),
  reserved slots NEVER decode below their plane floor, and every tier's
  level is restored to its ceiling after the queue drains.

* CHAOS (``"chaos"`` key, PR 9): the hardened engine under a deterministic
  ``FaultPlan`` — a burst with an injected NaN (quarantine), a plan-driven
  cancel storm, and a transient lane failure, all in one run.  Gated
  (bit-exact / steps domain): no crash, invariants hold after EVERY tick,
  exactly the poisoned request quarantined, survivors' token streams
  bit-identical to the same run with no fault plan, recovery within an
  analytic bound of the fault-free drain.  ``--chaos-only`` runs just this
  scenario (the CI chaos lane), adding a ``"chaos_mesh"`` mirror on a
  2-shard tensor-parallel engine when >= 2 devices are visible.

Emits ``BENCH_serve.json``.  CPU numbers from the tiny reduced config are a
scheduling proxy, not TPU performance; the *ratios* (stall vs full prefill,
batched vs sequential burst) are the contract.

Standalone CLI (used by the CI smoke job):
    python benchmarks/bench_serve.py [--smoke] [--json BENCH_serve.json]
        [--prompt-len N] [--chunk N] [--slots N] [--burst N]
        [--burst-lanes N] [--chaos-only]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import statistics
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS
from repro.models.model_zoo import build_model
from repro.serve import (DEGRADABLE, RESERVED, STANDARD, Request,
                         ServeConfig, ServeEngine, SloConfig)


def _mk_prompt(rng, n, vocab):
    return rng.integers(0, vocab, size=n).astype(np.int32)


def _timed_step(eng):
    t0 = time.perf_counter()
    done = eng.step()
    return (time.perf_counter() - t0) * 1e3, done


def run(model, params, cfg, prompt_len: int, chunk: int, n_slots: int,
        max_new: int, smoke: bool) -> dict:
    rng = np.random.default_rng(0)
    max_len = prompt_len + max_new + 8

    # ---- baseline: one full-prompt prefill forward (the blocking cost)
    full = {"tokens": jnp.asarray(_mk_prompt(rng, prompt_len,
                                             cfg.vocab_size)[None])}
    model.prefill(params, full, max_len=max_len)[0].block_until_ready()
    reps = 2 if smoke else 5
    t0 = time.perf_counter()
    for _ in range(reps):
        model.prefill(params, full, max_len=max_len)[0].block_until_ready()
    prefill_full_ms = (time.perf_counter() - t0) / reps * 1e3

    # ---- engine with live decoding slots
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=n_slots, max_len=max_len, prefill_chunk=chunk))
    live = [Request(uid=100 + i,
                    prompt=_mk_prompt(rng, chunk, cfg.vocab_size),
                    max_new=max_len - chunk - 1)
            for i in range(n_slots - 1)]
    for r in live:
        eng.try_add(r)
    # warmup: admissions trace the chunk/extend/decode shapes once
    warm = Request(uid=0, prompt=_mk_prompt(rng, prompt_len, cfg.vocab_size),
                   max_new=1)
    eng.try_add(warm)
    while not warm.done:
        eng.step()

    # steady-state decode, no admission in flight
    plain = [_timed_step(eng)[0] for _ in range(3 if smoke else 10)]
    decode_step_ms = statistics.median(plain)

    # ---- staggered chunked admissions: step times while prefill in flight
    admit_times, ttft = [], []
    n_admissions = 2 if smoke else 4
    for a in range(n_admissions):
        req = Request(uid=a + 1,
                      prompt=_mk_prompt(rng, prompt_len, cfg.vocab_size),
                      max_new=max_new)
        t_enq = time.perf_counter()
        if not eng.try_add(req):
            raise RuntimeError(f"admission queue rejected uid {req.uid}")
        while req.phase in ("pending", "prefilling"):
            ms, _ = _timed_step(eng)
            # only steps that actually carried admission work count toward
            # the stall metric — a step spent waiting for a free slot
            # (phase still "pending" afterwards) ran zero chunks and would
            # deflate the median toward the plain decode time
            if req.phase != "pending":
                admit_times.append(ms)
        ttft_ms = (time.perf_counter() - t_enq) * 1e3
        ttft.append({"uid": req.uid, "prompt_len": prompt_len,
                     "ttft_steps": req.ttft_steps, "ttft_ms": ttft_ms})
        for _ in range(2):                       # let the pool breathe
            eng.step()

    step_admission_ms = statistics.median(admit_times)
    decode_stall_ms = max(0.0, step_admission_ms - decode_step_ms)
    return {
        "config": {"arch": "olmo-1b.reduced", "n_slots": n_slots,
                   "max_len": max_len, "prompt_len": prompt_len,
                   "prefill_chunk": chunk, "max_new": max_new,
                   "smoke": smoke},
        "prefill_full_ms": round(prefill_full_ms, 3),
        "decode_step_ms": round(decode_step_ms, 3),
        "step_admission_ms": round(step_admission_ms, 3),
        "decode_stall_ms": round(decode_stall_ms, 3),
        "stall_below_full_prefill": decode_stall_ms < prefill_full_ms,
        "ttft": ttft,
    }


def _drain_burst(model, params, prompts, *, chunk, lanes, n_slots, max_len,
                 max_new) -> dict:
    """Enqueue every prompt at once, step until all finish; return TTFT
    percentiles and the total decode-stall of the drain."""
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=n_slots, max_len=max_len, prefill_chunk=chunk,
        chunks_per_step=lanes))
    # warmup: trace the chunk forward + pooled decode shapes off the clock
    warm = Request(uid=0, prompt=prompts[0], max_new=max_new + 8)
    eng.try_add(warm)
    while warm.phase in ("pending", "prefilling"):
        eng.step()
    # steady-state decode baseline while the warm slot is live
    decode_ms = statistics.median(_timed_step(eng)[0] for _ in range(8))
    eng.cancel(warm.uid)

    reqs = [Request(uid=i + 1, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    t0 = time.perf_counter()
    for r in reqs:
        if not eng.try_add(r):
            raise RuntimeError(f"burst enqueue rejected uid {r.uid}")
    ttft_ms, admit_times = {}, []
    while not all(r.done for r in reqs):
        # only steps that actually ran admission forwards count as stalled
        # (a step spent waiting for a free slot — burst deeper than the
        # pool — is a plain decode step and would dilute the metric)
        f0 = eng.pipeline.forwards
        ms, _ = _timed_step(eng)
        if eng.pipeline.forwards > f0:
            admit_times.append(ms)
        for r in reqs:
            if r.uid not in ttft_ms and r.out:
                ttft_ms[r.uid] = (time.perf_counter() - t0) * 1e3
    # clamp at the drain level, not per step: per-step max(0, ...) would
    # rectify timer noise instead of letting it cancel
    total_stall = max(0.0, sum(admit_times) - len(admit_times) * decode_ms)
    ttfts = [ttft_ms[r.uid] for r in reqs]
    steps = [r.ttft_steps for r in reqs]
    return {
        "lanes": lanes,
        "decode_step_ms": round(decode_ms, 3),
        "admission_steps": len(admit_times),
        "total_stall_ms": round(total_stall, 3),
        "ttft_p50_ms": round(float(np.percentile(ttfts, 50)), 3),
        "ttft_p95_ms": round(float(np.percentile(ttfts, 95)), 3),
        "ttft_steps": steps,
        "ttft_steps_worst": max(steps),
    }


def run_burst(model, params, cfg, prompt_len: int, chunk: int, n_slots: int,
              max_new: int, n_burst: int, lanes: int, smoke: bool,
              arch: str = "olmo-1b.reduced") -> dict:
    """Burst admission: N queued prompts, sequential vs batched drain."""
    rng = np.random.default_rng(1)
    max_len = prompt_len + max_new + 8
    prompts = [_mk_prompt(rng, prompt_len, cfg.vocab_size)
               for _ in range(n_burst)]
    common = dict(chunk=chunk, n_slots=n_slots, max_len=max_len,
                  max_new=max_new)
    seq = _drain_burst(model, params, prompts, lanes=1, **common)
    bat = _drain_burst(model, params, prompts, lanes=lanes, **common)
    return {
        "config": {"arch": arch, "n_burst": n_burst, "prompt_len": prompt_len,
                   "prefill_chunk": chunk, "n_slots": n_slots,
                   "lanes": lanes, "max_new": max_new, "smoke": smoke},
        "sequential": seq,
        "batched": bat,
        # informational: ms-domain ratio (timer-noise-bound at smoke scale)
        "stall_ratio_ms": round(bat["total_stall_ms"]
                                / max(seq["total_stall_ms"], 1e-9), 3),
        # the gate: the deterministic steps-domain form of
        # "batched <= sequential stall" (see module docstring)
        "batched_stall_leq_sequential":
            bat["admission_steps"] <= seq["admission_steps"]
            and bat["ttft_steps_worst"] <= seq["ttft_steps_worst"],
    }


def run_overload(prompt_len: int, chunk: int, n_slots: int, max_new: int,
                 lanes: int, smoke: bool) -> dict:
    """SLO control loop under a 4x overload burst on a calibrated DSLOT
    model: the accuracy-vs-latency Pareto sweep per QoS tier.

    All gates are in the deterministic ENGINE-STEPS domain (wall-clock
    p95s on shared CI runners are noise; the step schedule is exact).
    """
    from repro.configs.base import DslotConfig

    cfg = dataclasses.replace(
        ARCHS["olmo-1b"].reduced(), act="relu", glu=False,
        dslot=DslotConfig(enabled=True, block_m=16, block_n=32, block_k=16,
                          act_scale=0.05))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    n_bits = cfg.dslot.n_bits
    rng = np.random.default_rng(2)
    max_len = prompt_len + max_new + 8
    n_burst = 4 * n_slots
    slo = SloConfig(queue_high_water=n_slots, shed_patience=2,
                    restore_patience=2, target_ttft_steps=4 * n_slots)
    eng = ServeEngine(model, params, ServeConfig(
        n_slots=n_slots, max_len=max_len, prefill_chunk=chunk,
        chunks_per_step=lanes, slo=slo))
    cycle = [RESERVED, STANDARD, DEGRADABLE, DEGRADABLE]
    reqs = [Request(uid=i + 1,
                    prompt=_mk_prompt(rng, prompt_len, cfg.vocab_size),
                    max_new=max_new, tier=cycle[i % len(cycle)])
            for i in range(n_burst)]
    for r in reqs:
        if not eng.try_add(r):
            raise RuntimeError(f"overload enqueue rejected uid {r.uid}")
    reserved_floor_held = True
    steps = 0
    while not all(r.done for r in reqs):
        eng.step()
        steps += 1
        if eng.last_budget is not None:
            for slot, req in enumerate(eng.slot_req):
                if req is not None and req.tier == RESERVED \
                        and eng.last_budget[slot] < eng.slo.floor(RESERVED):
                    reserved_floor_held = False
    # drain: slack steps must restore every tier's level to its ceiling
    # (the stale TTFT window expires after ttft_idle_expiry idle steps,
    # then one tier-restore lands every restore_patience steps)
    for _ in range(slo.ttft_idle_expiry + 3 * n_bits * slo.restore_patience):
        eng.step()
    restored = eng.slo.levels == {n: t.ceiling
                                  for n, t in eng.slo.tiers.items()}
    # analytic drain bound on TTFT (steps domain, deterministic): every
    # request's first token waits at worst for the whole burst's admission
    # work (n_burst * chunks, one batched tick per step) plus the decode
    # occupancy of the slot waves ahead of it, plus slack for the tick the
    # merge lands on
    chunks_each = -(-prompt_len // chunk)
    ttft_bound = (n_burst * chunks_each
                  + (n_burst // n_slots + 1) * max_new + 8)
    pareto = {}
    for tier in (RESERVED, STANDARD, DEGRADABLE):
        rs = [r for r in reqs if r.tier == tier]
        ttfts = [r.ttft_steps for r in rs]
        bnd = [r.result.planes_bounded_mean for r in rs
               if r.result.planes_bounded_mean is not None]
        pareto[tier] = {
            "n_requests": len(rs),
            "mean_planes_used": round(float(np.mean(
                [r.result.planes_used_mean for r in rs])), 3),
            # weight-side planes never issued (static MSR bound) — the
            # request-independent saving that compounds with shedding
            "mean_planes_bounded": (round(float(np.mean(bnd)), 3)
                                    if bnd else None),
            "ttft_p50_steps": float(np.percentile(ttfts, 50)),
            "ttft_p95_steps": float(np.percentile(ttfts, 95)),
            "floor": eng.slo.floor(tier),
            "min_level": eng.slo.min_levels[tier],
        }
    p95_all = float(np.percentile([r.ttft_steps for r in reqs], 95))
    gates = {
        "reserved_floor_held": reserved_floor_held,
        "shed_occurred": eng.slo.shed_events > 0,
        "degraded_gracefully":
            pareto[DEGRADABLE]["mean_planes_used"] < float(n_bits),
        "ttft_p95_within_bound": p95_all <= ttft_bound,
        "budgets_restored_after_drain": restored,
    }
    return {
        "config": {"arch": "olmo-1b.reduced+dslot", "n_burst": n_burst,
                   "n_slots": n_slots, "prompt_len": prompt_len,
                   "prefill_chunk": chunk, "lanes": lanes,
                   "max_new": max_new, "n_bits": n_bits, "smoke": smoke,
                   "slo": {"queue_high_water": slo.queue_high_water,
                           "shed_patience": slo.shed_patience,
                           "restore_patience": slo.restore_patience,
                           "target_ttft_steps": slo.target_ttft_steps}},
        "drain_steps": steps,
        "ttft_p95_steps": p95_all,
        "ttft_bound_steps": ttft_bound,
        "pareto": pareto,
        "controller": eng.slo.summary(),
        "gates": gates,
        "ok": all(gates.values()),
    }


def run_chaos(prompt_len: int, chunk: int, n_slots: int, max_new: int,
              smoke: bool, mesh=None) -> dict:
    """Chaos scenario on the calibrated DSLOT model: a burst with an
    injected NaN (quarantine), a plan-driven cancel storm, and a transient
    lane failure — all from ONE deterministic ``FaultPlan``.

    Gates (all steps-domain / bit-exact, CI-safe):

    * ``no_crash`` — every ``step()`` returned (nothing raised) and the
      engine drained;
    * ``invariants_every_step`` — ``audit_engine`` returned [] after every
      single tick, faulted ones included;
    * ``quarantine_fired`` — exactly the poisoned request was evicted with
      ``phase == "quarantined"``;
    * ``cancel_storm_clean`` — every plan-cancelled request terminal, and
      the queue fully accounted for;
    * ``survivors_token_identical`` — every surviving request's stream is
      BIT-identical to the same engine run with no fault plan at all (the
      isolation + transactional-retry contract, end to end);
    * ``recovered_within_bound`` — the faulted drain finished within the
      analytic bound of the fault-free drain plus the injected stall steps.
    """
    from repro.configs.base import DslotConfig
    from repro.serve import FaultPlan, Fault, QUARANTINED, audit_engine

    cfg = dataclasses.replace(
        ARCHS["olmo-1b"].reduced(), act="relu", glu=False,
        dslot=DslotConfig(enabled=True, block_m=16, block_n=32, block_k=16,
                          act_scale=0.05))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    max_len = prompt_len + max_new + 8
    n_burst = 2 * n_slots
    victim_uid, storm_uids = 2, (3, 4)
    plan = FaultPlan(faults=(
        Fault(kind="lane_exception", step=1, count=1),     # transient
        Fault(kind="nan_logits", step=6, uid=victim_uid),  # poison
        Fault(kind="cancel", step=4, uid=storm_uids[0]),   # storm
        Fault(kind="cancel", step=4, uid=storm_uids[1]),
        Fault(kind="slow_step", step=2, value=0.001),
    ))
    prompts = [_mk_prompt(rng, prompt_len, cfg.vocab_size)
               for _ in range(n_burst)]

    def drive(faults):
        if mesh is not None:
            from repro.models import pspec
            pspec.set_mesh(None)           # engine installs the mesh itself
        eng = ServeEngine(model, params, ServeConfig(
            n_slots=n_slots, max_len=max_len, prefill_chunk=chunk,
            chunks_per_step=2, faults=faults, default_deadline_steps=200,
            mesh=mesh))
        reqs = [Request(uid=i + 1, prompt=p, max_new=max_new)
                for i, p in enumerate(prompts)]
        for r in reqs:
            if not eng.try_add(r):
                raise RuntimeError(f"chaos enqueue rejected uid {r.uid}")
        steps, invariants_ok, crashed = 0, True, False
        try:
            while not all(r.done for r in reqs):
                eng.step()
                steps += 1
                if audit_engine(eng):
                    invariants_ok = False
                if steps > 2000:
                    raise RuntimeError("chaos drain wedged")
        except Exception:
            crashed = True
        return eng, reqs, steps, invariants_ok, crashed

    ref_eng, ref_reqs, ref_steps, ref_inv, ref_crash = drive(None)
    eng, reqs, steps, invariants_ok, crashed = drive(plan)

    evicted = {victim_uid, *storm_uids}
    survivors = [r for r in reqs if r.uid not in evicted]
    ident = all(
        list(r.out) == list(ref.out)
        for r, ref in zip(reqs, ref_reqs) if r.uid not in evicted)
    victim = next(r for r in reqs if r.uid == victim_uid)
    stormed = [r for r in reqs if r.uid in storm_uids]
    # bound: the faulted drain saves the evicted requests' decode work but
    # pays the injected stall; it must land within the fault-free drain
    # plus slack for the retry + slow + quarantine steps
    recovery_bound = ref_steps + 8
    gates = {
        "no_crash": not crashed and not ref_crash,
        "invariants_every_step": invariants_ok and ref_inv,
        "quarantine_fired":
            victim.phase == QUARANTINED
            and [u for _, u in eng.quarantined] == [victim_uid],
        "cancel_storm_clean":
            all(r.done and r.phase == "cancelled" for r in stormed)
            and eng.queue_depth == 0,
        "lane_failure_absorbed":
            any(site == "admission" for _, site, _ in eng.errors),
        "survivors_token_identical":
            ident and all(r.phase == "done" and len(r.out) == max_new
                          for r in survivors),
        "recovered_within_bound": steps <= recovery_bound,
    }
    return {
        "config": {"arch": "olmo-1b.reduced+dslot", "n_burst": n_burst,
                   "n_slots": n_slots, "prompt_len": prompt_len,
                   "prefill_chunk": chunk, "max_new": max_new,
                   "smoke": smoke,
                   "mesh": None if mesh is None else dict(mesh.shape)},
        "plan": [{"kind": f.kind, "step": f.step, "slot": f.slot,
                  "uid": f.uid, "count": f.count, "value": f.value}
                 for f in plan.faults],
        "fired": eng.injector.summary()["fired"],
        "drain_steps": steps,
        "reference_drain_steps": ref_steps,
        "recovery_bound_steps": recovery_bound,
        "errors_absorbed": len(eng.errors),
        "quarantined": eng.quarantined,
        "timeouts": eng.timeouts,
        "gates": gates,
        "ok": all(gates.values()),
    }


def run_chaos_mesh(prompt_len: int, chunk: int, n_slots: int, max_new: int,
                   smoke: bool) -> dict | None:
    """The same chaos gates on a 2-shard tensor-parallel engine — skipped
    (returns None) when fewer than 2 devices are visible.  The CI chaos
    lane forces 2 host devices via XLA_FLAGS."""
    if len(jax.devices()) < 2:
        return None
    from repro.launch.mesh import make_test_mesh
    from repro.models import pspec

    try:
        return run_chaos(prompt_len, chunk, n_slots, max_new, smoke,
                         mesh=make_test_mesh(n_devices=2, model=2))
    finally:
        pspec.set_mesh(None)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes / few reps for CI")
    ap.add_argument("--json", default="BENCH_serve.json")
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--chunk", type=int, default=None)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--burst", type=int, default=None,
                    help="burst size (default 4 smoke / 8)")
    ap.add_argument("--burst-lanes", type=int, default=4,
                    help="chunks_per_step for the batched burst drain")
    ap.add_argument("--chaos-only", action="store_true",
                    help="run only the chaos scenario (the CI chaos lane)")
    args = ap.parse_args()
    prompt_len = args.prompt_len if args.prompt_len is not None \
        else (48 if args.smoke else 192)
    chunk = args.chunk if args.chunk is not None \
        else (8 if args.smoke else 16)
    n_burst = args.burst if args.burst is not None \
        else (4 if args.smoke else 8)

    if args.chaos_only:
        out = {"chaos": run_chaos(3 * chunk, chunk, args.slots,
                                  args.max_new, args.smoke)}
        mesh_out = run_chaos_mesh(3 * chunk, chunk, args.slots,
                                  args.max_new, args.smoke)
        if mesh_out is not None:
            out["chaos_mesh"] = mesh_out
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        for key in ("chaos", "chaos_mesh"):
            if key not in out:
                print(f"{key}: skipped (needs >= 2 devices)")
                continue
            c = out[key]
            print(f"{key}: drained in {c['drain_steps']} steps "
                  f"(ref {c['reference_drain_steps']}, bound "
                  f"{c['recovery_bound_steps']}); "
                  f"{c['errors_absorbed']} errors absorbed, "
                  f"quarantined {c['quarantined']}")
            for gate, okv in c["gates"].items():
                print(f"  gate {gate}: {'OK' if okv else 'FAIL'}")
        print(f"wrote {args.json}")
        if not all(out[k]["ok"] for k in out):
            raise SystemExit(1)
        return

    cfg = ARCHS["olmo-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    out = run(model, params, cfg, prompt_len, chunk, args.slots,
              args.max_new, args.smoke)
    out["burst"] = run_burst(model, params, cfg, prompt_len, chunk,
                             args.slots, args.max_new, n_burst,
                             args.burst_lanes, args.smoke)
    # every zoo stack batches now: the same burst drain + gate on a
    # sliding-window and a recurrent stack (ragged lanes, no serial path)
    for key, zoo_arch in (("burst_swa", "h2o-danube-3-4b"),
                          ("burst_ssm", "mamba2-780m")):
        zcfg = ARCHS[zoo_arch].reduced()
        zmodel = build_model(zcfg)
        zparams = zmodel.init(jax.random.PRNGKey(0))
        out[key] = run_burst(zmodel, zparams, zcfg, prompt_len, chunk,
                             args.slots, args.max_new, n_burst,
                             args.burst_lanes, args.smoke,
                             arch=f"{zoo_arch}.reduced")
    out["overload"] = run_overload(3 * chunk, chunk, args.slots,
                                   args.max_new, 2, args.smoke)
    out["chaos"] = run_chaos(3 * chunk, chunk, args.slots, args.max_new,
                             args.smoke)
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"full-prompt prefill     {out['prefill_full_ms']:9.2f} ms")
    print(f"decode step (no admit)  {out['decode_step_ms']:9.2f} ms")
    print(f"decode step (+1 chunk)  {out['step_admission_ms']:9.2f} ms")
    print(f"decode stall/admission  {out['decode_stall_ms']:9.2f} ms  "
          f"({'OK' if out['stall_below_full_prefill'] else 'FAIL'}: "
          f"< full prefill)")
    for t in out["ttft"]:
        print(f"  ttft uid={t['uid']}: {t['ttft_steps']} steps, "
              f"{t['ttft_ms']:.1f} ms")
    for bkey in ("burst", "burst_swa", "burst_ssm"):
        b = out[bkey]
        print(f"{bkey} [{b['config']['arch']}]")
        for mode in ("sequential", "batched"):
            m = b[mode]
            print(f"  {mode:10s}  lanes={m['lanes']}  "
                  f"ttft p50 {m['ttft_p50_ms']:8.1f} ms  "
                  f"p95 {m['ttft_p95_ms']:8.1f} ms  "
                  f"total stall {m['total_stall_ms']:8.1f} ms over "
                  f"{m['admission_steps']} stalled steps "
                  f"(worst ttft {m['ttft_steps_worst']} steps)")
        print(f"  stall ratio ms (informational) {b['stall_ratio_ms']:.3f}; "
              f"stalled-steps {b['batched']['admission_steps']} vs "
              f"{b['sequential']['admission_steps']}, worst ttft "
              f"{b['batched']['ttft_steps_worst']} vs "
              f"{b['sequential']['ttft_steps_worst']} steps "
              f"({'OK' if b['batched_stall_leq_sequential'] else 'FAIL'}: "
              f"batched <= sequential)")
    o = out["overload"]
    print(f"overload 4x burst ({o['config']['n_burst']} reqs, "
          f"{o['drain_steps']} steps to drain; ttft p95 "
          f"{o['ttft_p95_steps']:.0f} <= bound {o['ttft_bound_steps']}):")
    for tier, p in o["pareto"].items():
        print(f"  {tier:10s}  planes-used {p['mean_planes_used']:5.2f} "
              f"(floor {p['floor']}, min level {p['min_level']})  "
              f"ttft p95 {p['ttft_p95_steps']:5.0f} steps  "
              f"[{p['n_requests']} reqs]")
    c = o["controller"]
    print(f"  controller: {c['shed_events']} sheds / "
          f"{c['restore_events']} restores; levels {c['levels']}")
    for gate, okv in o["gates"].items():
        print(f"  gate {gate}: {'OK' if okv else 'FAIL'}")
    ch = out["chaos"]
    print(f"chaos: drained in {ch['drain_steps']} steps "
          f"(ref {ch['reference_drain_steps']}, bound "
          f"{ch['recovery_bound_steps']}); {ch['errors_absorbed']} errors "
          f"absorbed, quarantined {ch['quarantined']}")
    for gate, okv in ch["gates"].items():
        print(f"  gate {gate}: {'OK' if okv else 'FAIL'}")
    print(f"wrote {args.json}")
    if not out["stall_below_full_prefill"]:
        raise SystemExit(1)
    if not all(out[k]["batched_stall_leq_sequential"]
               for k in ("burst", "burst_swa", "burst_ssm")):
        raise SystemExit(1)
    if not o["ok"]:
        raise SystemExit(1)
    if not ch["ok"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
