"""Tensor-parallel DSLOT scaling benchmark -> ``BENCH_distributed.json``.

Measures the N-sharded ``dslot_execute`` (``kernels/ops.py`` tensor
parallelism) across 1/2/4/8 forced host devices on one CPU — wall-clock
per shard count, measured speedup vs 1 shard, and the
``launch.roofline.predict_tp_scaling`` model prediction next to it so
model drift is visible.  Also times the expert-parallel MoE dispatch
(``distributed/expert_parallel.apply_moe_ep``) for the two MoE zoo configs
(``mixtral_8x22b``, ``granite_moe_1b_a400m``, reduced shapes) under
per-expert digit-plane budgets.

CPU host devices share one socket, so measured "scaling" here is a
correctness-shaped smoke curve, not a hardware claim — the CI gate is
BIT-IDENTITY of every sharded result against the unsharded reference
(exit 1 on divergence), with the timing published for trend tracking.

This file must set the device-count override BEFORE jax initializes, so
all jax imports are deferred into main().

Standalone CLI (used by the CI multi-device lane):
    python benchmarks/bench_distributed.py [--smoke] [--json out.json]
"""

from __future__ import annotations

import argparse
import json
import os
import time


def _timeit(fn, *args, iters=3):
    import jax
    jax.tree.leaves(fn(*args))[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def bench_tp_matmul(shape, device_counts, iters):
    """Sharded dslot_execute: bit-identity gate + scaling curve."""
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels.ops import dslot_execute, dslot_prepare
    from repro.launch.mesh import make_test_mesh
    from repro.launch.roofline import predict_tp_scaling

    m, k, n = shape
    rng = np.random.default_rng(0)
    w = rng.normal(size=(k, n)).astype(np.float32)
    w[:, : n // 8] = 0.0
    x = rng.normal(size=(m, k)).astype(np.float32).clip(0)
    npl = jnp.asarray(rng.integers(4, 9, size=m), jnp.int32)
    kw = dict(n_bits=8, relu=True, sort_columns=True,
              block_m=32, block_n=32, block_k=32)

    ref = None
    rows, mismatches = [], 0
    for s in device_counts:
        mesh = None if s == 1 else make_test_mesh(n_devices=s, model=s)
        prep = dslot_prepare(w, mesh=mesh, **kw)
        us = _timeit(lambda p=prep: dslot_execute(p, x, n_planes=npl),
                     iters=iters)
        out, _ = dslot_execute(prep, x, n_planes=npl)
        out = np.asarray(out)
        if ref is None:
            ref, t1 = out, us
        elif not np.array_equal(out, ref):
            mismatches += 1
        rows.append({
            "devices": s, "wall_us": us,
            "measured_speedup": t1 / us,
            "predicted_speedup": predict_tp_scaling(
                m, k, n, s)["predicted_speedup"],
            "bit_identical": ref is not None and np.array_equal(out, ref),
        })
    return {"shape": {"m": m, "k": k, "n": n}, "curve": rows}, mismatches


def bench_moe_ep(arch_names, iters):
    """Expert-parallel MoE under per-expert plane budgets (8-way mesh)."""
    import dataclasses
    import importlib

    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.distributed.expert_parallel import apply_moe_ep
    from repro.launch.mesh import make_test_mesh
    from repro.models.moe import apply_moe, init_moe

    mesh = make_test_mesh(model=8)
    out = {}
    for name in arch_names:
        cfg = importlib.import_module(f"repro.configs.{name}").CONFIG
        cfg = dataclasses.replace(cfg.reduced(), n_experts=8, top_k=2)
        p = init_moe(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                              jnp.float32) * 0.5
        budgets = jnp.asarray([8, 8, 6, 6, 5, 5, 4, 4], jnp.int32)
        y_dense, _ = apply_moe(p, x, cfg)
        y_ep, _ = apply_moe_ep(p, x, cfg, mesh)
        y_bud, _ = apply_moe_ep(p, x, cfg, mesh, expert_planes=budgets)
        out[name] = {
            "ep_wall_us": _timeit(
                lambda: apply_moe_ep(p, x, cfg, mesh), iters=iters),
            "ep_budget_wall_us": _timeit(
                lambda: apply_moe_ep(p, x, cfg, mesh,
                                     expert_planes=budgets), iters=iters),
            "ep_vs_dense_maxerr": float(
                jnp.abs(y_ep - y_dense).max()),
            "budget_vs_ep_maxerr": float(jnp.abs(y_bud - y_ep).max()),
        }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few iters for the CI lane")
    ap.add_argument("--json", default="BENCH_distributed.json")
    ap.add_argument("--devices", type=int, nargs="+",
                    default=[1, 2, 4, 8])
    args = ap.parse_args()

    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    import jax
    n_dev = len(jax.devices())
    counts = [c for c in args.devices if c <= n_dev]
    if len(counts) < 2:
        raise SystemExit(
            f"need >=2 usable device counts, have {n_dev} devices — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8")

    shape = (64, 128, 256) if args.smoke else (256, 512, 1024)
    iters = 2 if args.smoke else 5
    tp, mismatches = bench_tp_matmul(shape, counts, iters)
    moe = bench_moe_ep(["mixtral_8x22b", "granite_moe_1b_a400m"],
                       iters=iters)

    rec = {"backend": jax.default_backend(), "host_devices": n_dev,
           "smoke": bool(args.smoke), "tp_matmul": tp, "moe_ep": moe}
    with open(args.json, "w") as fh:
        json.dump(rec, fh, indent=2)

    print(f"written to {args.json}")
    for r in tp["curve"]:
        print(f"  devices={r['devices']} wall={r['wall_us']:.0f}us "
              f"measured x{r['measured_speedup']:.2f} "
              f"predicted x{r['predicted_speedup']:.2f} "
              f"bit_identical={r['bit_identical']}")
    for name, m in moe.items():
        print(f"  moe_ep {name}: {m['ep_wall_us']:.0f}us "
              f"(budgets {m['ep_budget_wall_us']:.0f}us, "
              f"vs dense maxerr {m['ep_vs_dense_maxerr']:.2e})")
        if m["ep_vs_dense_maxerr"] > 2e-2:
            raise SystemExit(f"EP MoE diverged from dense for {name}")
    if mismatches:
        raise SystemExit(f"{mismatches} sharded results diverged "
                         "from the unsharded reference")


if __name__ == "__main__":
    main()
