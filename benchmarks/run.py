"""Benchmark driver — one section per paper table/figure.

Prints ``name,value,derived`` CSV lines (benchmark contract).  Sections:
  table1  — paper Table I (analytic FPGA model vs published)
  cycles  — paper eq. 6 schedules + latency/energy vs SIP
  mnist   — paper Figs. 8/9 (negative-activation + cycle-saving per class)
  kernel  — TPU digit-plane kernel (plane skipping, runtime precision)
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from . import bench_cycles, bench_kernel, bench_mnist_stats, bench_table1
    sections = [
        ("table1", bench_table1.run),
        ("cycles", bench_cycles.run),
        ("kernel", bench_kernel.run),
        ("mnist", bench_mnist_stats.run),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,value,derived")
    for name, fn in sections:
        if only and name != only:
            continue
        t0 = time.time()
        for row in fn():
            print(row, flush=True)
        print(f"_section.{name}_seconds,{time.time() - t0:.1f},", flush=True)


if __name__ == "__main__":
    main()
