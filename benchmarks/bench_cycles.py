"""Paper eq. 6 cycle schedule + DSLOT vs SIP cycle/energy comparison across
kernel sizes and feature-map counts (the latency analysis of §II-B)."""

from __future__ import annotations

from repro.core import pe_schedule, sip_schedule, table1_model


def run() -> list[str]:
    rows = []
    s = pe_schedule(k=5, n_fmaps=1, p_mult=16)
    rows.append(f"cycles.paper_example,{s.total_cycles},expected=33")
    for k in (3, 5, 7):
        for n in (1, 4, 16):
            s = pe_schedule(k=k, n_fmaps=n, p_mult=16)
            rows.append(f"cycles.k{k}_N{n},{s.total_cycles},"
                        f"p_out={s.p_out};fill={s.pipeline_fill}")
    m = table1_model()
    for k in (3, 5, 7):
        ds = pe_schedule(k=k, p_mult=16)
        ss = sip_schedule(k=k)
        t_d = ds.total_cycles * m["dslot"].cpd_ns
        t_s = ss.total_cycles * m["stripes"].cpd_ns
        e_d = t_d * m["dslot"].dynamic_power_mw
        e_s = t_s * m["stripes"].dynamic_power_mw
        rows.append(f"cycles.latency_ns_k{k},{t_d:.1f},sip={t_s:.1f}")
        rows.append(f"cycles.energy_pj_k{k},{e_d:.1f},sip={e_s:.1f}")
    return rows
