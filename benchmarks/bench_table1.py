"""Paper Table I: DSLOT-NN vs Stripes SIP on Virtex-7 — analytic model vs
published numbers (no FPGA in-container; model calibrated per DESIGN.md §2,
throughput IIs reverse-engineered to ~1%, assumption recorded)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import TABLE1_PUBLISHED, table1_model
from repro.core.cycle_model import t_dslot, t_ola, t_olm, t_sip


def run() -> list[str]:
    rows = []
    m = table1_model()
    pub_s, pub_d = TABLE1_PUBLISHED["stripes"], TABLE1_PUBLISHED["dslot"]
    rows.append(f"table1.sip_cpd_ns,{t_sip(5):.3f},published={pub_s['cpd_ns']}")
    rows.append(f"table1.dslot_cpd_ns,{t_dslot(5):.3f},"
                f"published={pub_d['cpd_ns']}")
    rows.append(f"table1.cpd_reduction,{1 - t_dslot(5)/t_sip(5):.4f},"
                f"paper=0.486")
    rows.append(f"table1.olm_ns,{t_olm():.3f},eq9")
    rows.append(f"table1.ola_ns,{t_ola():.3f},eq10")
    for name, eng in m.items():
        pub = TABLE1_PUBLISHED[name]["gops_per_watt"]
        rows.append(f"table1.{name}_gops_per_watt,{eng.gops_per_watt:.2f},"
                    f"published={pub}")
    gain = m["dslot"].gops_per_watt / m["stripes"].gops_per_watt - 1
    rows.append(f"table1.perf_density_gain,{gain:.4f},paper=0.497")
    # average-case with early termination (12.5% negatives x ~50% cycles)
    et = m["dslot"].with_early_termination(0.125 * 0.5)
    rows.append(f"table1.dslot_early_term_gops_per_watt,"
                f"{et.gops_per_watt:.2f},avg-case")
    return rows
