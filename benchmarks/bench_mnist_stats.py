"""Paper Figs. 8 & 9: per-class negative-activation rates and cycle savings
of the DSLOT early-termination engine on the (synthetic-)MNIST CNN.

Caveat recorded in EXPERIMENTS.md: the container is offline, so the CNN is
trained on procedurally generated digit glyphs (repro.data.mnist).  The paper
measured ~12.5% negatives on true MNIST with its specific trained weights;
here the *mechanism* (bias-free CNN, Algorithm-1 termination, per-class
variation) is reproduced and the numbers are of the same order.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.dslot_mnist import CONFIG
from repro.core import dslot_conv2d_stats
from repro.core.mnist_cnn import train_cnn
from repro.data.mnist import synth_mnist


def run(n_train_per_class: int = 40, n_eval_per_class: int = 10
        ) -> list[str]:
    rows = []
    imgs, labels = synth_mnist(n_train_per_class + n_eval_per_class, seed=0)
    n_eval = n_eval_per_class * 10
    train_x, train_y = imgs[:-n_eval], labels[:-n_eval]
    eval_x, eval_y = imgs[-n_eval:], labels[-n_eval:]

    params, acc = train_cnn(CONFIG, train_x, train_y, epochs=20, lr=2e-2)
    rows.append(f"mnist.train_accuracy,{acc:.3f},synthetic-digits")

    neg_rates, savings = [], []
    for d in range(10):
        xd = eval_x[eval_y == d]
        res = dslot_conv2d_stats(jnp.asarray(xd),
                                 jnp.asarray(params.conv),
                                 n_bits=CONFIG.n_bits)
        neg = float(res.report.negative_rate)
        # Fig. 9 reports savings over all convolutions (negatives terminate)
        sav = float(jnp.mean(res.report.savings_frac))
        neg_rates.append(neg)
        savings.append(sav)
        rows.append(f"mnist.fig8_neg_rate_class{d},{neg:.4f},")
        rows.append(f"mnist.fig9_cycles_saved_class{d},{sav:.4f},")
    rows.append(f"mnist.fig8_mean_neg_rate,{np.mean(neg_rates):.4f},"
                f"paper~0.125")
    rows.append(f"mnist.fig9_mean_savings,{np.mean(savings):.4f},")
    # savings conditional on negative windows (paper §II-B.2: 45-50%)
    res = dslot_conv2d_stats(jnp.asarray(eval_x[:40]),
                             jnp.asarray(params.conv), n_bits=CONFIG.n_bits)
    fired = np.asarray(res.report.is_negative)
    if fired.any():
        cond = float(np.asarray(res.report.savings_frac)[fired].mean())
        rows.append(f"mnist.savings_on_negatives,{cond:.4f},paper=0.45-0.50")
    return rows
