"""mixtral-8x22b [moe] — 8 experts top-2 with SWA [arXiv:2401.04088; hf].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    scan_unroll=4,
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16_384,
    vocab_size=32_768,
    attn_type="swa",
    window=4_096,
    n_experts=8,
    top_k=2,
    block_pattern=("moe",),
    norm="rmsnorm",
    act="silu",
    glu=True,
)
