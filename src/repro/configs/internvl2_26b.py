"""internvl2-26b [vlm] — InternViT + InternLM2 [arXiv:2404.16821; hf].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.  LM backbone only;
the InternViT frontend is a stub supplying precomputed patch embeddings.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    scan_unroll=4,
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16_384,
    vocab_size=92_553,
    norm="rmsnorm",
    act="silu",
    glu=True,
    frontend="vision",
    frontend_len=1024,      # precomputed ViT patch embeddings (stub)
)
