"""Config system: architecture + shape cells for the assigned benchmark grid.

Every assigned architecture is a ``ModelConfig``; every input-shape row is a
``ShapeConfig``.  A (ModelConfig, ShapeConfig) pair is one dry-run/roofline
cell.  ``reduced()`` produces the CPU smoke-test variant of any architecture
(same family/block pattern, tiny dims).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class DslotConfig:
    """Execution config for the paper's digit-serial inference mode."""
    enabled: bool = False
    n_bits: int = 8
    n_planes: int = 8          # runtime precision knob (<= n_bits)
    sort_columns: bool = True  # beyond-paper: cluster dead output columns
    block_m: int = 128
    block_n: int = 128
    block_k: int | None = None  # K chunk streamed through VMEM (None = auto)
    use_pallas: bool = False    # Pallas kernel (interpret off-TPU) vs jnp
    act_scale: float | None = None  # calibrated fixed activation-quant step
                                # stored at prepare time (None = per-call max)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # attention
    attn_type: str = "full"          # full | swa
    window: int = 0                  # swa / local-attn window
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"            # rmsnorm | nonparam_ln | layernorm
    act: str = "silu"                # silu | gelu | relu
    glu: bool = True
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid block pattern, tiled to n_layers (e.g. RG-LRU 1:2)
    block_pattern: tuple[str, ...] = ("attn",)
    rnn_width: int = 0               # rglru width (0 -> d_model)
    # enc-dec
    encoder_layers: int = 0
    cross_attention: bool = False
    # modality frontend stub ([audio]/[vlm]): precomputed embeddings
    frontend: str = ""               # "" | audio | vision
    frontend_len: int = 0            # frames/patches prepended to the sequence
    # execution
    tie_embeddings: bool = False
    remat: bool = True
    scan_layers: bool = True
    scan_unroll: int = 1             # pattern-periods per scan step: full remat
                                     # saves one carry per STEP, so memory for
                                     # saved activations scales 1/scan_unroll
    attn_chunk: int = 1024           # flash-style KV chunking
    dtype: str = "bfloat16"
    dslot: DslotConfig = field(default_factory=DslotConfig)

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid / SWA)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attn_type == "swa"

    def pattern_for_layers(self) -> tuple[str, ...]:
        reps = -(-self.n_layers // len(self.block_pattern))
        return (self.block_pattern * reps)[: self.n_layers]

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        pat_len = len(self.block_pattern)
        n_layers = max(pat_len, 2 if pat_len == 1 else pat_len)
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16,
            window=min(self.window, 32) if self.window else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            capacity_factor=4.0,   # dropless at test scale -> exact decode
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            rnn_width=64 if self.rnn_width else 0,
            encoder_layers=min(self.encoder_layers, 2),
            frontend_len=8 if self.frontend else 0,
            attn_chunk=16,
            dtype="float32",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatches: int = 1            # grad-accumulation steps (train only)

    def reduced(self) -> "ShapeConfig":
        return dataclasses.replace(
            self, seq_len=32, global_batch=2, microbatches=min(self.microbatches, 2))


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256, microbatches=8),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}
