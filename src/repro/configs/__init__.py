"""configs subpackage of the DSLOT-NN reproduction."""
