"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

from .base import SHAPES, ModelConfig, ShapeConfig
from . import (deepseek_67b, granite_moe_1b_a400m, h2o_danube_3_4b,
               internvl2_26b, mamba2_780m, mixtral_8x22b, olmo_1b,
               qwen2_5_3b, recurrentgemma_2b, seamless_m4t_medium)

ARCHS: dict[str, ModelConfig] = {
    "seamless-m4t-medium": seamless_m4t_medium.CONFIG,
    "deepseek-67b": deepseek_67b.CONFIG,
    "h2o-danube-3-4b": h2o_danube_3_4b.CONFIG,
    "olmo-1b": olmo_1b.CONFIG,
    "qwen2.5-3b": qwen2_5_3b.CONFIG,
    "mamba2-780m": mamba2_780m.CONFIG,
    "mixtral-8x22b": mixtral_8x22b.CONFIG,
    "granite-moe-1b-a400m": granite_moe_1b_a400m.CONFIG,
    "recurrentgemma-2b": recurrentgemma_2b.CONFIG,
    "internvl2-26b": internvl2_26b.CONFIG,
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def cell_is_live(arch: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules: which (arch x shape) cells run (DESIGN.md §6)."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention (full-attn arch)"
    return True, ""


def live_cells() -> list[tuple[str, str]]:
    cells = []
    for a, ac in ARCHS.items():
        for s, sc in SHAPES.items():
            ok, _ = cell_is_live(ac, sc)
            if ok:
                cells.append((a, s))
    return cells
