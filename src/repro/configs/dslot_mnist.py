"""The paper's own evaluation network (Fig. 6): MNIST CNN, conv 5x5 + ReLU +
2x2 maxpool accelerated by DSLOT-NN, trained WITHOUT bias terms (paper §III-A
attributes its 12.5% negative-activation rate partly to the missing biases).
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class MnistCNNConfig:
    name: str = "dslot-mnist-cnn"
    image_size: int = 28
    kernel_size: int = 5           # k=5 -> 25 OLMs per PE (paper config)
    conv_channels: int = 8
    n_classes: int = 10
    use_bias: bool = False         # paper: trained without bias
    n_bits: int = 8                # 8-bit fixed point operands
    pool: int = 2                  # 2x2 maxpool -> 4 PEs per pooling window


CONFIG = MnistCNNConfig()
