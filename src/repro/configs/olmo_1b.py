"""olmo-1b [dense] — non-parametric LN [arXiv:2402.00838; hf].

16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    scan_unroll=2,
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50_304,
    norm="nonparam_ln",
    act="silu",
    glu=True,
    tie_embeddings=True,
)
