"""seamless-m4t-medium [audio] — enc-dec multimodal [arXiv:2308.11596; hf].

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206.  Transformer backbone
only; the audio frontend is a stub supplying precomputed frame embeddings.
FFN activation is ReLU (as in the original architecture) — this is the one
assigned LM arch where DSLOT early-negative-termination applies end-to-end.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    scan_unroll=2,
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,
    norm="layernorm",
    act="relu",
    glu=False,
    encoder_layers=12,
    cross_attention=True,
    frontend="audio",
    frontend_len=1024,      # precomputed speech frame embeddings (stub)
    rope_theta=10_000.0,
)
