"""deepseek-67b [dense] — llama-arch [arXiv:2401.02954; hf].

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    scan_unroll=5,
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22_016,
    vocab_size=102_400,
    norm="rmsnorm",
    act="silu",
    glu=True,
)
