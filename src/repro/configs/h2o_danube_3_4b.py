"""h2o-danube-3-4b [dense] — llama+mistral mix with SWA [arXiv:2401.16818; unverified].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, sliding-window attention.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    scan_unroll=3,
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10_240,
    vocab_size=32_000,
    attn_type="swa",
    window=4_096,
    norm="rmsnorm",
    act="silu",
    glu=True,
)
