"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 [arXiv:2402.19427; hf].

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.  Griffin pattern:
two RG-LRU recurrent blocks per local-attention block; local window 2048;
head_dim 256; GeGLU MLP.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    scan_unroll=2,
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    attn_type="swa",
    window=2048,
    block_pattern=("rglru", "rglru", "attn"),
    rnn_width=2560,
    norm="rmsnorm",
    act="gelu",
    glu=True,
    tie_embeddings=True,
)
