"""repro — DSLOT-NN (digit-serial MSDF arithmetic with early negative
termination) reproduced in JAX and scaled into a multi-pod training/serving
framework.  See README.md / DESIGN.md / EXPERIMENTS.md.

Layout: ``core`` (paper's arithmetic), ``kernels`` (Pallas digit-plane
matmul), ``models``+``configs`` (10 assigned architectures), ``train``/
``serve``/``optim``/``data``/``checkpoint``/``distributed`` (substrates),
``launch`` (mesh, dry-run, roofline, train/serve entry points).
"""

__version__ = "1.0.0"
