"""Attention: GQA/MQA, full-causal, sliding-window/local, cross; flash-style.

Memory discipline: scores are never materialized for the full sequence.
``flash_attention`` scans KV in chunks with running-max online softmax
(O(S * chunk) score memory); the sliding-window path additionally chunks the
query axis and slices only the in-window KV span (O(S * W) compute — this is
what makes the `long_500k`/SWA cells sub-quadratic).

Decode uses a ring-buffer KV cache: slot = position % capacity, with an
explicit per-slot position array for exact masking.  Full attention uses
capacity = seq_len (no wraparound); SWA uses capacity = window, so the cache
footprint of a 500k-token stream is O(window).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import Params, apply_dense, apply_rope, init_dense
from .pspec import constrain, head_scheme

_NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array          # (B, C, Hkv, D)
    v: jax.Array          # (B, C, Hkv, D)
    positions: jax.Array  # (B, C) int32 per-sequence ring positions, -1 =
                          # empty.  Per-sequence (not shared) so a slot pool
                          # can hold requests at different decode depths.


def init_attention(cfg, key, cross: bool = False) -> Params:
    dt = jnp.dtype(cfg.dtype)
    hd = cfg.head_dim_
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], cfg.d_model, cfg.n_heads * hd, dt,
                         bias=cfg.qkv_bias),
        "wk": init_dense(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dt,
                         bias=cfg.qkv_bias),
        "wv": init_dense(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dt,
                         bias=cfg.qkv_bias),
        "wo": init_dense(ks[3], cfg.n_heads * hd, cfg.d_model, dt),
    }


def cache_capacity(cfg, seq_len: int) -> int:
    if cfg.attn_type == "swa" and cfg.window:
        return min(seq_len, cfg.window)
    return seq_len


def init_kv_cache(cfg, batch: int, seq_len: int, dtype) -> KVCache:
    C = cache_capacity(cfg, seq_len)
    hd = cfg.head_dim_
    return KVCache(
        k=jnp.zeros((batch, C, cfg.n_kv_heads, hd), dtype),
        v=jnp.zeros((batch, C, cfg.n_kv_heads, hd), dtype),
        positions=jnp.full((batch, C), -1, jnp.int32),
    )


# ------------------------------------------------------------------ softmax core

def _attend_block(q, k, v, mask, m, l, acc):
    """One online-softmax update.  q:(B,Sq,Hkv,G,D) k/v:(B,Ck,Hkv,D)
    mask:(Sq,Ck) or (B,Sq,Ck); m,l:(B,Sq,Hkv,G) acc:(B,Sq,Hkv,G,D)."""
    s = jnp.einsum("bqhgd,bkhd->bqhgk", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    if mask.ndim == 2:
        mask = mask[None]
    s = jnp.where(mask[:, :, None, None, :], s, _NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    # bf16 probabilities for the PV matmul (standard flash practice): halves
    # the per-chunk residuals saved for the backward pass, f32 accumulation.
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bqhgk,bkhd->bqhgd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32)
    return m_new, l_new, acc_new


def flash_attention(q, k, v, q_pos, k_pos, *, causal: bool, window: int,
                    chunk: int) -> jax.Array:
    """Chunked-KV online-softmax attention.

    q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D); positions int32 arrays
    (q_pos: (Sq,) or per-sequence (B, Sq); k_pos: (Sk,) or (B, Sk); k_pos
    may contain -1 = invalid slot).  2-D positions work on every path: the
    decode fast path (Sq == 1, a slot pool whose sequences sit at different
    depths) and the generic chunked-KV scan (Sq > 1, batched multi-token
    cache extension at ragged per-sequence offsets — each sequence gets its
    own causal/window mask against its own ring positions).  Shared 1-D
    positions keep the cheaper (Sq, ck) per-chunk mask.  GQA folds Hq into
    (Hkv, G).  Returns (B, Sq, Hq, D) in q.dtype.
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5
    qg = (q * scale).reshape(B, Sq, Hkv, G, D)

    Sk = k.shape[1]
    if Sq == 1:
        # Decode fast path: one un-chunked online-softmax block.  Keeps the
        # KV cache shardable along its sequence axis (context parallelism):
        # the softmax reductions over Sk become tiny cross-device
        # all-reduces instead of a scan over a sharded axis.
        qp = q_pos if q_pos.ndim == 2 else q_pos[None]       # (b?, Sq)
        kp = k_pos if k_pos.ndim == 2 else k_pos[None]       # (b?, Sk)
        mask = (kp >= 0)[:, None, :]
        if causal:
            mask = mask & (kp[:, None, :] <= qp[:, :, None])
        if window:
            mask = mask & (kp[:, None, :] > qp[:, :, None] - window)
        if mask.shape[0] == 1:
            mask = mask[0]                                   # shared (Sq, Sk)
        m0 = jnp.full((B, Sq, Hkv, G), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
        a0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)
        m, l, acc = _attend_block(qg, k, v, mask, m0, l0, a0)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.reshape(B, Sq, Hq, D).astype(q.dtype)

    shared = q_pos.ndim == 1 and k_pos.ndim == 1
    ck = min(chunk, Sk)
    n_chunks = -(-Sk // ck)
    pad = n_chunks * ck - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0),) * (k_pos.ndim - 1) + ((0, pad),),
                        constant_values=-1)

    kc = k.reshape(B, n_chunks, ck, Hkv, D)
    vc = v.reshape(B, n_chunks, ck, Hkv, D)
    if shared:
        pc = k_pos.reshape(n_chunks, ck)
    else:
        # per-sequence positions: each batch row masks against its OWN ring
        # offsets, so the mask carries the batch axis ((B, Sq, ck) instead of
        # a shared (Sq, ck)) and the KV-position chunks are scanned per-row.
        qp = q_pos if q_pos.ndim == 2 \
            else jnp.broadcast_to(q_pos[None], (B, Sq))
        kp = k_pos if k_pos.ndim == 2 \
            else jnp.broadcast_to(k_pos[None], (B, k_pos.shape[-1]))
        pc = jnp.moveaxis(kp.reshape(B, n_chunks, ck), 1, 0)

    m0 = jnp.full((B, Sq, Hkv, G), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)

    def body(carry, inputs):
        m, l, acc = carry
        kb, vb, pb = inputs
        valid = pb >= 0
        if shared:
            mask = valid[None, :]
            if causal:
                mask = mask & (pb[None, :] <= q_pos[:, None])
            if window:
                mask = mask & (pb[None, :] > q_pos[:, None] - window)
        else:
            mask = valid[:, None, :]
            if causal:
                mask = mask & (pb[:, None, :] <= qp[:, :, None])
            if window:
                mask = mask & (pb[:, None, :] > qp[:, :, None] - window)
        m, l, acc = _attend_block(qg, kb, vb, mask, m, l, acc)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), pc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def chunked_causal_attention(q, k, v, q_pos, k_pos, *, chunk: int
                             ) -> jax.Array:
    """Full causal attention with BOTH axes chunked: outer map over query
    chunks, inner flash scan over KV.  Bounds the score/mask working set to
    (B, cq, H, ck) regardless of sequence length — required for 32k+ prefill
    to fit HBM (the unchunked-query form hoists O(S^2/ck) masks)."""
    B, Sq, Hq, D = q.shape
    cq = min(chunk, Sq)
    n_q = -(-Sq // cq)
    pad_q = n_q * cq - Sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=-1)

    def one_chunk(i):
        qs = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, i * cq, cq)
        return flash_attention(qs, k, v, qp, k_pos, causal=True, window=0,
                               chunk=chunk)

    outs = jax.lax.map(one_chunk, jnp.arange(n_q))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, n_q * cq, Hq, D)
    return out[:, :Sq]


def swa_attention(q, k, v, q_pos, k_pos, *, window: int, q_chunk: int
                  ) -> jax.Array:
    """Sub-quadratic sliding-window attention: chunk queries, slice only the
    in-window KV span per chunk.  Compute O(S * (W + cq)), not O(S^2)."""
    B, Sq, Hq, D = q.shape
    Sk = k.shape[1]
    cq = min(q_chunk, Sq)
    n_q = -(-Sq // cq)
    pad_q = n_q * cq - Sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=-1)
    span = min(Sk, window + cq)

    def one_chunk(i):
        qs = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, i * cq, cq)
        # KV span covering (chunk_start - window, chunk_end]
        start = jnp.clip(i * cq + cq - span, 0, Sk - span)
        ks = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        kp = jax.lax.dynamic_slice_in_dim(k_pos, start, span)
        return flash_attention(qs, ks, vs, qp, kp, causal=True,
                               window=window, chunk=span)

    outs = jax.lax.map(one_chunk, jnp.arange(n_q))       # (n_q, B, cq, Hq, D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, n_q * cq, Hq, D)
    return out[:, :Sq]


# ------------------------------------------------------------------ module API

def attention_forward(p: Params, x: jax.Array, cfg, *, positions: jax.Array,
                      cache: KVCache | None = None,
                      kv_x: jax.Array | None = None,
                      causal: bool = True,
                      return_cache: bool = False,
                      is_cross: bool = False,
                      cache_len: int | None = None,
                      q_valid: jax.Array | None = None
                      ) -> tuple[jax.Array, KVCache | None]:
    """Full attention pass (train / prefill / decode / cross).

    x: (B, S, d_model).  positions: (S,) shared or (B, S) per-sequence int32
    absolute positions.
    cache: when given and S is small (decode), new KV are appended (ring) and
    attention runs against the cache; when ``return_cache`` on a long pass
    (prefill), the cache is built from this pass's KV.
    kv_x: encoder output for cross-attention (keys/values from there, no
    causal mask, no rope on cross keys beyond their own positions).
    q_valid: optional (B, S) bool — ragged batched cache extension.  Rows
    where it is False are right-padding of a shorter chunk: their KV is NOT
    written into the ring (the scatter writes back what the ring already
    holds at those slots, so a lane's padding can never clobber live slots
    even when its phantom positions wrap the ring capacity).  Their
    attention outputs are still computed (garbage) — callers discard them.
    """
    B, S, _ = x.shape
    hd = cfg.head_dim_
    cross = is_cross or kv_x is not None
    q = apply_dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)

    if cross and cache is not None and kv_x is None:
        # decode against a static (encoder) cross cache: no writes, no mask
        q = constrain(q, "b", None, "tp", None)
        out = flash_attention(q, cache.k, cache.v, positions,
                              cache.positions, causal=False, window=0,
                              chunk=cfg.attn_chunk)
        y = apply_dense(p["wo"], out.reshape(B, S, cfg.n_heads * hd))
        return y, cache

    src = kv_x if kv_x is not None else x
    Skv = src.shape[1]
    k = apply_dense(p["wk"], src).reshape(B, Skv, cfg.n_kv_heads, hd)
    v = apply_dense(p["wv"], src).reshape(B, Skv, cfg.n_kv_heads, hd)

    if not cross:
        k = apply_rope(k, positions, cfg.rope_theta)
        kv_pos = positions
    else:
        kv_pos = jnp.arange(Skv, dtype=jnp.int32)
        k = apply_rope(k, kv_pos, cfg.rope_theta)

    # Shard attention across the model axis (DESIGN.md §5 / pspec.py):
    # "kv" shards kv heads; "repeat" duplicates kv to q-heads so the head
    # axis shards evenly (zero attention collectives at a small kv cost).
    scheme = head_scheme(cfg.n_kv_heads, cfg.n_heads)
    q = constrain(q, "b", None, "tp", None)

    def _spread(kk, vv):
        if scheme == "repeat":
            g = cfg.n_heads // max(cfg.n_kv_heads, 1)
            if g > 1:
                kk = jnp.repeat(kk, g, axis=2)
                vv = jnp.repeat(vv, g, axis=2)
        kk = constrain(kk, "b", None, "tp", None)
        vv = constrain(vv, "b", None, "tp", None)
        return kk, vv

    new_cache = None
    if cache is not None and not cross:
        # decode: write new kv into per-sequence ring slots, attend against
        # the whole cache.  positions may be (S,) shared or (B, S) per-slot
        # (serving pools where sequences sit at different depths).  S > 1
        # with a cache is the chunked-prefill extension path: prompt chunks
        # appended to existing rings at arbitrary per-sequence offsets —
        # batched, each row masked against its own positions.
        window = cfg.window if cfg.attn_type == "swa" else 0
        C = cache.k.shape[1]
        if S > C:
            # consecutive positions are only slot-distinct modulo the ring
            # capacity: a wider chunk would make two rows of the same
            # sequence scatter into one slot (nondeterministic winner)
            raise ValueError(
                f"cache extension chunk ({S} tokens) exceeds the KV ring "
                f"capacity ({C}): in-chunk positions would alias ring slots")
        pos_b = positions if positions.ndim == 2 \
            else jnp.broadcast_to(positions[None], (B, S))
        slots = pos_b % C                                   # (B, S)
        bidx = jnp.arange(B)[:, None]
        if q_valid is not None:
            # ragged rows: pad entries re-write the ring's current contents
            # (slots within a row are distinct — S <= C enforced above and
            # positions are consecutive — so the masked scatter is
            # deterministic)
            kw = jnp.where(q_valid[..., None, None], k,
                           cache.k[bidx, slots])
            vw = jnp.where(q_valid[..., None, None], v,
                           cache.v[bidx, slots])
            pw = jnp.where(q_valid, pos_b, cache.positions[bidx, slots])
        else:
            kw, vw, pw = k, v, pos_b
        kc = cache.k.at[bidx, slots].set(kw)
        vc = cache.v.at[bidx, slots].set(vw)
        pc = cache.positions.at[bidx, slots].set(pw)
        new_cache = KVCache(k=kc, v=vc, positions=pc)
        if S > 1 and window:
            # SWA carry-window extension: a chunk landing at offset o
            # recycles ring slots (capacity = window) that still hold
            # in-window keys needed by the chunk's own earliest queries —
            # attending against the POST-write ring would silently drop
            # them.  Attend instead against the PRE-write ring CARRIED
            # alongside the chunk's own keys: the ring holds positions
            # o-C..o-1 (a superset of every in-window key the chunk can
            # see), the chunk contributes o..o+S-1, and the two position
            # sets are disjoint, so the window mask selects exactly the
            # right keys.  Pad rows' chunk keys are masked out (-1) so a
            # short row can only see its own live ring.  The RING is still
            # written through the masked scatter above — eviction there is
            # correct (decode never looks back past the window).
            kp_chunk = pos_b if q_valid is None \
                else jnp.where(q_valid, pos_b, -1)
            ka = jnp.concatenate([cache.k, k], axis=1)
            va = jnp.concatenate([cache.v, v], axis=1)
            pa = jnp.concatenate([cache.positions, kp_chunk], axis=1)
        else:
            ka, va, pa = kc, vc, pc
        # decode: the cache is sequence-sharded (context parallelism); keep
        # that layout — repeating kv heads is fine, but constraining heads
        # onto the model axis here would force a full cache reshard.
        if scheme == "repeat":
            g = cfg.n_heads // max(cfg.n_kv_heads, 1)
            if g > 1:
                ka = jnp.repeat(ka, g, axis=2)
                va = jnp.repeat(va, g, axis=2)
        ka = constrain(ka, "b", "tp", None, None)
        va = constrain(va, "b", "tp", None, None)
        out = flash_attention(q, ka, va, pos_b, pa, causal=causal,
                              window=window, chunk=cfg.attn_chunk)
    else:
        window = cfg.window if (cfg.attn_type == "swa" and not cross) else 0
        ka, va = _spread(k, v)
        if window and S > 1:
            out = swa_attention(q, ka, va, positions, kv_pos, window=window,
                                q_chunk=cfg.attn_chunk)
        elif causal and not cross and S > 2 * cfg.attn_chunk:
            out = chunked_causal_attention(q, ka, va, positions, kv_pos,
                                           chunk=cfg.attn_chunk)
        else:
            out = flash_attention(q, ka, va, positions, kv_pos,
                                  causal=causal and not cross, window=0,
                                  chunk=cfg.attn_chunk)
        if return_cache:
            # Build the ring cache from the last kept positions (slot =
            # pos % C; scatter keeps the ring invariant for any C).  The ring
            # is sized for the TARGET sequence length (cache_len), not the
            # prompt, so subsequent decode steps never clobber live slots.
            C = Skv if cross else cache_capacity(cfg, cache_len or int(Skv))
            if q_valid is not None and not cross:
                # Ragged stacked prefill: the last C COLUMNS of a padded
                # batch are pads for a short row — slicing them (below)
                # would evict that row's real in-window keys.  Build each
                # row's ring by a per-(row, slot) GATHER of its last
                # min(C, L) VALID positions instead: slot s's owner is the
                # largest valid position congruent to s mod C.
                lengths = jnp.sum(q_valid.astype(jnp.int32), axis=1)  # (B,)
                s_idx = jnp.arange(C, dtype=jnp.int32)[None]          # (1,C)
                last = lengths[:, None] - 1                           # (B,1)
                owner = last - ((last - s_idx) % C)                   # (B,C)
                valid = (owner >= 0) & (lengths[:, None] > 0)
                col = jnp.clip(owner, 0, Skv - 1)[..., None, None]
                kb = jnp.take_along_axis(k, col, axis=1)
                vb = jnp.take_along_axis(v, col, axis=1)
                new_cache = KVCache(
                    k=jnp.where(valid[..., None, None], kb, 0),
                    v=jnp.where(valid[..., None, None], vb, 0),
                    positions=jnp.where(valid, owner, -1))
            else:
                n_keep = min(C, Skv)
                keep = slice(Skv - n_keep, Skv)
                kept_pos = kv_pos[keep].astype(jnp.int32)
                slots = kept_pos % C
                zk = jnp.zeros((B, C) + k.shape[2:], k.dtype)
                pos0 = jnp.full((C,), -1, jnp.int32).at[slots].set(kept_pos)
                new_cache = KVCache(
                    k=zk.at[:, slots].set(k[:, keep]),
                    v=zk.at[:, slots].set(v[:, keep]),
                    positions=jnp.broadcast_to(pos0[None], (B, C)))

    out = constrain(out, "b", None, "tp", None)
    y = apply_dense(p["wo"], out.reshape(B, S, cfg.n_heads * hd))
    return y, new_cache
