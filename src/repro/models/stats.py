"""Lightweight side-channel for per-forward statistics (early-termination
rates etc.).  Pure-functional JAX cannot thread auxiliary outputs through
every layer without invasive plumbing; instead layers ``record`` named scalars
into a context that callers open around a forward pass.  Inside ``jit`` the
recorded values are traced arrays; the collector is only used by stats-mode
entry points (serving engine, benchmarks), never by ``train_step``.
"""

from __future__ import annotations

import contextlib
from typing import Any

_ACTIVE: list[dict[str, list[Any]]] = []


@contextlib.contextmanager
def collect():
    sink: dict[str, list[Any]] = {}
    _ACTIVE.append(sink)
    try:
        yield sink
    finally:
        _ACTIVE.pop()


def record(name: str, value) -> None:
    if _ACTIVE:
        _ACTIVE[-1].setdefault(name, []).append(value)
