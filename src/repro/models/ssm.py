"""Mamba2 (SSD — state-space duality) mixer block [arXiv:2405.21060].

Recurrence per head (state N, head dim P):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * (B_t ⊗ x_t)
    y_t = C_t · h_t + D_skip * x_t

Training/prefill uses the chunked SSD form: intra-chunk contributions via the
masked decay matrix L = exp(segsum(a)) (quadratic only within a chunk), chunk
states propagated with a sequential scan over chunks — O(S * Q) compute and
memory, sub-quadratic in S (this is why mamba2 runs the ``long_500k`` cell).
Decode is the O(1)-per-token recurrence on a persistent (H, P, N) state.

Chunked and sequential paths are tested equal to ~1e-4 (float accumulation).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import Params


class SSMState(NamedTuple):
    conv: jax.Array    # (B, k-1, conv_channels) — causal conv tail
    ssm: jax.Array     # (B, H, P, N) — recurrent state


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_headdim
    H = d_inner // P
    N = cfg.ssm_state
    G = 1
    return d_inner, H, P, N, G


def init_ssm(cfg, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    d_inner, H, P, N, G = _dims(cfg)
    conv_ch = d_inner + 2 * G * N
    in_dim = 2 * d_inner + 2 * G * N + H
    ks = jax.random.split(key, 4)
    return {
        "w_in": (jax.random.normal(ks[0], (cfg.d_model, in_dim), jnp.float32)
                 * cfg.d_model ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch),
                                     jnp.float32) * 0.2).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) = -1
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "w_out": (jax.random.normal(ks[2], (d_inner, cfg.d_model), jnp.float32)
                  * d_inner ** -0.5).astype(dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array | None, lengths: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  x: (B,S,C), w: (k,C).  Returns (y, new_tail).

    ``lengths`` (B,) marks ragged rows right-padded to S: the returned tail
    is then each row's last k-1 VALID inputs (a per-row gather into
    ``concat([tail, x])``) instead of the last k-1 columns — a short row's
    pad columns must never enter its carried conv window.  A length-0 row's
    tail is its incoming tail, unchanged.
    """
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)             # (B, S+k-1, C)
    idx = jnp.arange(x.shape[1])[:, None] + jnp.arange(k)[None, :]
    windows = xp[:, idx]                                # (B, S, k, C)
    y = jnp.einsum("bskc,kc->bsc", windows, w) + b
    if lengths is None:
        new_tail = xp[:, xp.shape[1] - (k - 1):]
    else:
        # xp index of position t is t + (k-1); the k-1 window ending at a
        # row's last valid input starts at index L (identity when L == 0)
        tidx = lengths[:, None].astype(jnp.int32) \
            + jnp.arange(k - 1, dtype=jnp.int32)[None]
        new_tail = jnp.take_along_axis(xp, tidx[..., None], axis=1)
    return jax.nn.silu(y), new_tail


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., Q) log decays -> (..., Q, Q) with S[i,j]=sum_{j<m<=i} a_m,
    -inf above the diagonal."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    S = cs[..., :, None] - cs[..., None, :]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(tri, S, -jnp.inf)


def ssd_chunked(x, dtv, A, B, C, chunk: int, init_state=None):
    """Chunked SSD.  x:(b,s,h,p) dtv:(b,s,h) A:(h,) B,C:(b,s,n) [g=1].
    Returns y:(b,s,h,p), final_state:(b,h,p,n)."""
    b, s, h, pdim = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    a = (dtv * A[None, None, :]).astype(jnp.float32)    # (b, s', h) log decay

    xc = x.reshape(b, nc, q, h, pdim).astype(jnp.float32)
    dc = dtv.reshape(b, nc, q, h).astype(jnp.float32)
    ac = jnp.moveaxis(a.reshape(b, nc, q, h), -1, 2)    # (b, nc, h, q)
    Bc = B.reshape(b, nc, q, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, q, n).astype(jnp.float32)

    cs = jnp.cumsum(ac, axis=-1)                        # (b, nc, h, q) inclusive
    L = jnp.exp(_segsum(ac))                            # (b, nc, h, q, q)

    # intra-chunk: y_i += sum_{j<=i} C_i·B_j L[i,j] dt_j x_j
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)      # (b, nc, q, q)
    w = scores[:, :, None] * L                          # (b, nc, h, q, q)
    y_intra = jnp.einsum("bchij,bcjh,bcjhp->bcihp", w, dc, xc)

    # chunk states: sum_j decay_to_end[j] dt_j B_j x_j  -> (b, nc, h, p, n)
    decay_end = jnp.exp(cs[..., -1:] - cs)              # (b, nc, h, q)
    states = jnp.einsum("bchj,bcjh,bcjhp,bcjn->bchpn",
                        decay_end, dc, xc, Bc)

    # inter-chunk recurrence over nc
    T = jnp.exp(cs[..., -1])                            # (b, nc, h) total decay
    h0 = (jnp.zeros((b, h, pdim, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def body(hprev, inp):
        Tc, sc = inp
        hnew = Tc[..., None, None] * hprev + sc
        return hnew, hprev

    (hfin, hprevs) = jax.lax.scan(
        body, h0, (jnp.moveaxis(T, 1, 0), jnp.moveaxis(states, 1, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 1)                 # (b, nc, h, p, n)

    # inter-chunk output: y_i += C_i · decay_in[i] · h_prev
    decay_in = jnp.exp(cs)                              # includes a_i
    y_inter = jnp.einsum("bcin,bchi,bchpn->bcihp", Cc, decay_in, hprevs)

    y = (y_intra + y_inter).reshape(b, nc * q, h, pdim)[:, :s]
    return y, hfin


def ssd_sequential(x, dtv, A, B, C, init_state=None):
    """Naive O(S) sequential recurrence — oracle for tests and decode."""
    b, s, h, pdim = x.shape
    n = B.shape[-1]
    h0 = (jnp.zeros((b, h, pdim, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(hprev, inp):
        xt, dt_t, Bt, Ct = inp
        at = jnp.exp(dt_t * A)                          # (b, h)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt_t, xt, Bt)
        hnew = at[..., None, None] * hprev + upd
        yt = jnp.einsum("bn,bhpn->bhp", Ct, hnew)
        return hnew, yt

    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dtv.astype(jnp.float32), 1, 0),
          jnp.moveaxis(B.astype(jnp.float32), 1, 0),
          jnp.moveaxis(C.astype(jnp.float32), 1, 0))
    hfin, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hfin


def apply_ssm(p: Params, x: jax.Array, cfg, state: SSMState | None = None,
              return_state: bool = False, sequential: bool = False,
              q_valid: jax.Array | None = None
              ) -> tuple[jax.Array, SSMState | None]:
    """Full mamba2 mixer.  x: (B, S, d_model).

    ``q_valid`` (B, S) bool marks ragged rows right-padded to S.  Pad
    positions are exact IDENTITY steps of the recurrence — ``dt = 0`` gives
    decay ``exp(0) = 1`` and a zero state update in both the sequential and
    chunked SSD paths — and the conv tail gathers each row's last valid
    inputs, so carried state only ever advances past real tokens (pad rows'
    emitted outputs are garbage; callers discard them).
    """
    B_, S, _ = x.shape
    d_inner, H, P, N, G = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"], preferred_element_type=x.dtype)
    z, xBC, dt_raw = jnp.split(
        zxbcdt, [d_inner, d_inner + d_inner + 2 * G * N], axis=-1)

    lengths = None if q_valid is None \
        else jnp.sum(q_valid.astype(jnp.int32), axis=1)
    conv_tail = state.conv if state is not None else None
    xBC, new_tail = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_tail,
                                 lengths=lengths)
    x_ssm, Bmat, Cmat = jnp.split(xBC, [d_inner, d_inner + G * N], axis=-1)

    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    if q_valid is not None:
        dtv = jnp.where(q_valid[..., None], dtv, 0.0)
    A = -jnp.exp(p["A_log"])
    xh = x_ssm.reshape(B_, S, H, P)

    init = state.ssm if state is not None else None
    if sequential or S == 1:
        y, hfin = ssd_sequential(xh, dtv, A, Bmat, Cmat, init_state=init)
    else:
        y, hfin = ssd_chunked(xh, dtv, A, Bmat, Cmat, cfg.ssm_chunk,
                              init_state=init)
    y = y + p["D_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, S, d_inner)

    # gated RMSNorm (mamba2): norm(y * silu(z)) * scale
    g = y * jax.nn.silu(z.astype(jnp.float32))
    r = jax.lax.rsqrt(jnp.mean(g * g, axis=-1, keepdims=True) + 1e-6)
    g = (g * r * p["norm_scale"]).astype(x.dtype)

    out = jnp.einsum("bse,ed->bsd", g, p["w_out"], preferred_element_type=g.dtype)
    new_state = SSMState(conv=new_tail, ssm=hfin) if return_state else None
    return out, new_state
