"""MLP blocks (SwiGLU / GeGLU / ReLU), with the DSLOT digit-serial execution
mode for inference (the paper's technique as a first-class execution option).

When ``cfg.dslot.enabled`` and the activation is ReLU (the only case where the
early-negative-termination contract holds — DESIGN.md §6), the up-projection
matmul runs through the unified ``repro.layers.DslotDense`` API with fused
ReLU and per-tile early termination.  ``prepare_mlp_dslot`` attaches the
one-time weight-stationary lowering (``kernels.ops.dslot_prepare``) to every
up-projection in a params tree — scan-stacked groups included — so serving
executes against cached termination tables and block geometry (digit planes
themselves are derived in-kernel per call, never cached or materialized);
unprepared params fall back to trace-time lowering.  The runtime precision comes from the active
``repro.runtime`` precision scope (per-request budgets in serving), and
termination statistics are surfaced through ``repro.models.stats``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Params, apply_dense, init_dense
from .pspec import constrain

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": lambda x: jnp.maximum(x, 0.0),
}


def init_mlp(cfg, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    p = {"up": init_dense(ks[0], cfg.d_model, cfg.d_ff, dt),
         "down": init_dense(ks[1], cfg.d_ff, cfg.d_model, dt)}
    if cfg.glu:
        p["gate"] = init_dense(ks[2], cfg.d_model, cfg.d_ff, dt)
    return p


def apply_mlp(p: Params, x: jax.Array, cfg) -> jax.Array:
    act = _ACTS[cfg.act]
    if cfg.dslot.enabled and cfg.act == "relu" and not cfg.glu:
        return _apply_mlp_dslot(p, x, cfg)
    up = constrain(apply_dense(p["up"], x), "b", None, "tp")
    if cfg.glu:
        h = act(constrain(apply_dense(p["gate"], x), "b", None, "tp")) * up
    else:
        h = act(up)
    return apply_dense(p["down"], h)


def _dslot_up_layer(cfg):
    from repro.layers import DslotDense

    d = cfg.dslot
    return DslotDense(
        d_in=cfg.d_model, d_out=cfg.d_ff, name="mlp_up_dslot",
        n_bits=d.n_bits, n_planes=d.n_planes, relu=True, signed=True,
        sort_columns=d.sort_columns, block_m=d.block_m, block_n=d.block_n,
        block_k=d.block_k, use_pallas=d.use_pallas)


def _apply_mlp_dslot(p: Params, x: jax.Array, cfg) -> jax.Array:
    """Digit-serial inference path: fused up-proj + ReLU with early
    termination of provably-negative output tiles (paper Algorithm 1,
    tile-granular TPU adaptation), routed through the unified
    ``repro.layers.DslotDense`` layer API.  Uses the prepared state in
    ``p["up"]["dslot"]`` when ``prepare_mlp_dslot`` has run; the runtime
    precision scope (per-request plane budgets) overrides ``cfg.dslot``."""
    from . import stats

    layer = _dslot_up_layer(cfg)
    h, st = layer.apply(p["up"], x.astype(jnp.float32))
    stats.record("mlp_dslot_skipped_frac", st.skipped_frac)
    stats.record("mlp_dslot_planes_used",
                 jnp.mean(st.planes_used.astype(jnp.float32)))
    return apply_dense(p["down"], h.astype(x.dtype))


def mlp_uses_dslot(cfg) -> bool:
    """The digit-serial path applies: ReLU (termination contract), no GLU."""
    return bool(cfg.dslot.enabled and cfg.act == "relu" and not cfg.glu)


def prepare_mlp_dslot(params, cfg, mesh=None, tp_axis="model"):
    """Attach the one-time DSLOT lowering to every MLP up-projection in a
    model params tree.

    Walks the (nested dict/list/tuple) tree for MLP-shaped subtrees — a dict
    with ``up``/``down`` dense-param dicts — and stores a prepared
    ``DslotWeights`` under ``[...]["up"]["dslot"]``.  Scan-stacked weights
    (leading group axis, ndim 3) are prepared per-layer via ``vmap``, so the
    prepared tables slice correctly inside ``lax.scan`` over layers.
    Returns the params unchanged when the dslot path does not apply.

    ``mesh``/``tp_axis`` bake tensor parallelism into the prepared state:
    every digit-serial up-projection then executes N-sharded over the mesh
    (``kernels/ops.py`` module docs) — bit-identical outputs, one
    ``shard_map`` per layer inside whatever jit the caller wraps.
    """
    if not mlp_uses_dslot(cfg):
        return params
    from repro.kernels.ops import dslot_prepare

    d = cfg.dslot
    x_scale = None if d.act_scale is None else jnp.float32(d.act_scale)

    def prep_one(w):
        return dslot_prepare(
            w.astype(jnp.float32), n_bits=d.n_bits, relu=True, signed=True,
            sort_columns=d.sort_columns, block_m=d.block_m, block_n=d.block_n,
            block_k=d.block_k,
            backend="pallas" if d.use_pallas else "jnp", x_scale=x_scale,
            mesh=mesh, tp_axis=tp_axis)

    def walk(node):
        if isinstance(node, dict):
            if ("up" in node and "down" in node
                    and isinstance(node["up"], dict) and "w" in node["up"]
                    and "gate" not in node):
                w = node["up"]["w"]
                prepared = (jax.vmap(prep_one)(w) if w.ndim == 3
                            else prep_one(w))
                return {**node, "up": {**node["up"], "dslot": prepared}}
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        if isinstance(node, tuple):
            return tuple(walk(v) for v in node)
        return node

    return walk(params)
