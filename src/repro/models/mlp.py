"""MLP blocks (SwiGLU / GeGLU / ReLU), with the DSLOT digit-serial execution
mode for inference (the paper's technique as a first-class execution option).

When ``cfg.dslot.enabled`` and the activation is ReLU (the only case where the
early-negative-termination contract holds — DESIGN.md §6), the up-projection
matmul runs through ``repro.kernels.ops.dslot_matmul`` with fused ReLU and
per-tile early termination; termination statistics are surfaced through
``repro.models.stats`` for the serving engine to report.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Params, apply_dense, init_dense
from .pspec import constrain

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": lambda x: jnp.maximum(x, 0.0),
}


def init_mlp(cfg, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    p = {"up": init_dense(ks[0], cfg.d_model, cfg.d_ff, dt),
         "down": init_dense(ks[1], cfg.d_ff, cfg.d_model, dt)}
    if cfg.glu:
        p["gate"] = init_dense(ks[2], cfg.d_model, cfg.d_ff, dt)
    return p


def apply_mlp(p: Params, x: jax.Array, cfg) -> jax.Array:
    act = _ACTS[cfg.act]
    if cfg.dslot.enabled and cfg.act == "relu" and not cfg.glu:
        return _apply_mlp_dslot(p, x, cfg)
    up = constrain(apply_dense(p["up"], x), "b", None, "tp")
    if cfg.glu:
        h = act(constrain(apply_dense(p["gate"], x), "b", None, "tp")) * up
    else:
        h = act(up)
    return apply_dense(p["down"], h)


def _apply_mlp_dslot(p: Params, x: jax.Array, cfg) -> jax.Array:
    """Digit-serial inference path: fused up-proj + ReLU with early
    termination of provably-negative output tiles (paper Algorithm 1,
    tile-granular TPU adaptation), routed through the unified
    ``repro.layers.DslotDense`` layer API."""
    from repro.layers import DslotDense
    from . import stats

    d = cfg.dslot
    layer = DslotDense(
        d_in=cfg.d_model, d_out=cfg.d_ff, name="mlp_up_dslot",
        n_bits=d.n_bits, n_planes=d.n_planes, relu=True, signed=True,
        sort_columns=d.sort_columns, block_m=d.block_m, block_n=d.block_n,
        block_k=d.block_k, use_pallas=d.use_pallas)
    h, st = layer.apply(p["up"], x.astype(jnp.float32))
    stats.record("mlp_dslot_skipped_frac", st.skipped_frac)
    stats.record("mlp_dslot_planes_used",
                 jnp.mean(st.planes_used.astype(jnp.float32)))
    return apply_dense(p["down"], h.astype(x.dtype))
