"""Shared model layers: norms, rotary embeddings, token embedding, heads.

Parameters are plain nested dicts of jnp arrays (pytree-native — pjit shards
them via path-pattern rules in ``repro.train.sharding``).  Initializers take
explicit PRNG keys; every layer has a pure ``apply`` function.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Params = dict


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------- norms

def init_norm(cfg, key=None) -> Params:
    if cfg.norm == "nonparam_ln":
        return {}                       # OLMo: no scale / bias
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), jnp.float32),
                "bias": jnp.zeros((cfg.d_model,), jnp.float32)}
    return {"scale": jnp.ones((cfg.d_model,), jnp.float32)}


def apply_norm(p: Params, x: jax.Array, cfg) -> jax.Array:
    """Statistics in f32, elementwise normalize in the residual dtype — the
    f32 copy of the whole (B, S, D) stream is never materialized (matters:
    saved-carry stacks in the layer scan stay bf16, DESIGN.md §5)."""
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        return x * (r.astype(x.dtype)) * p["scale"].astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + 1e-6)
    out = (x - mu.astype(x.dtype)) * r.astype(x.dtype)
    if cfg.norm == "layernorm":
        out = out * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)
    return out


# ---------------------------------------------------------------- rotary

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) with positions (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                            # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- embeddings

def init_embedding(cfg, key) -> Params:
    scale = cfg.d_model ** -0.5
    emb = jax.random.normal(key, (cfg.vocab_size, cfg.d_model),
                            jnp.float32) * scale
    return {"embedding": emb.astype(_dtype(cfg))}


def embed_tokens(p: Params, tokens: jax.Array, cfg) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


def init_lm_head(cfg, key) -> Params:
    if cfg.tie_embeddings:
        return {}
    w = jax.random.normal(key, (cfg.d_model, cfg.vocab_size),
                          jnp.float32) * cfg.d_model ** -0.5
    return {"w": w.astype(_dtype(cfg))}


def lm_logits(head: Params, embed: Params, x: jax.Array, cfg) -> jax.Array:
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", x, embed["embedding"],
                          preferred_element_type=x.dtype)
    return jnp.einsum("...d,dv->...v", x, head["w"],
                      preferred_element_type=x.dtype)


# ---------------------------------------------------------------- dense

def init_dense(key, d_in: int, d_out: int, dtype, bias: bool = False) -> Params:
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * d_in ** -0.5
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def apply_dense(p: Params, x: jax.Array) -> jax.Array:
    # preferred_element_type pins the dot OUTPUT to the weight dtype: the MXU
    # still accumulates in f32 internally, but row-parallel partial sums then
    # cross the all-reduce in bf16 (half the TP collective bytes and no f32
    # copies of the residual stream — measured 2 GiB/layer on deepseek-67b).
    y = jnp.einsum("...d,df->...f", x, p["w"],
                   preferred_element_type=p["w"].dtype)
    if "b" in p:
        y = y + p["b"]
    return y
