"""Activation sharding constraints (mesh-aware, no-op without a mesh).

GSPMD propagation from weight shardings alone lets attention replicate across
the model axis (verified on the olmo dry-run: 4.5x FLOPs, all-gathered heads).
Launchers register the mesh here; model code calls ``constrain`` with logical
axes:

    b   -> the batch axes ("pod","data")
    tp  -> the tensor-parallel axis ("model")
    None-> replicated

``head_scheme`` picks how attention shards across tp given GQA geometry:
    "kv"     — tp | n_kv_heads: shard the kv-head axis (canonical Megatron)
    "group"  — tp | q-groups:   shard q's group axis, replicate kv (MQA-ish)
    "repeat" — otherwise:       repeat kv to n_heads and shard q-heads
               (trades a small kv duplication for zero attention collectives)
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Mesh | None = None
_FSDP: tuple = ()
_TP: str | None = None


def set_mesh(mesh: Mesh | None) -> None:
    global _MESH, _FSDP, _TP
    _MESH = mesh
    if mesh is None:
        _FSDP, _TP = (), None
        return
    names = mesh.axis_names
    _FSDP = tuple(a for a in ("pod", "data") if a in names)
    _TP = "model" if "model" in names else None


def tp_size() -> int:
    if _MESH is None or _TP is None:
        return 1
    return _MESH.shape[_TP]


def fsdp_size() -> int:
    if _MESH is None:
        return 1
    n = 1
    for a in _FSDP:
        n *= _MESH.shape[a]
    return n


def constrain(x: jax.Array, *axes) -> jax.Array:
    """axes entries: "b" (batch axes), "tp", or None; trailing dims None."""
    if _MESH is None:
        return x
    spec = []
    for i, a in enumerate(axes):
        if a == "b":
            ok = x.shape[i] % max(fsdp_size(), 1) == 0
            spec.append(_FSDP if (_FSDP and ok) else None)
        elif a == "tp":
            ok = _TP is not None and x.shape[i] % _MESH.shape[_TP] == 0
            spec.append(_TP if ok else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*spec)))


def head_scheme(n_kv: int, n_heads: int) -> str:
    t = tp_size()
    if t == 1:
        return "kv"
    if n_kv % t == 0:
        return "kv"
    if (n_heads // n_kv) % t == 0:
        return "group"
    return "repeat"
