"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

    r_t = sigmoid(W_a u_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x u_t + b_x)          (input gate)
    a_t = exp(-c * softplus(Λ) * r_t)     (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t²) · (i_t ⊙ u_t)

The full block is Griffin's recurrent temporal-mixing block: linear in →
causal conv1d (k=4) → RG-LRU → (⊙ GeLU gate branch) → linear out.

The linear recurrence is evaluated with ``jax.lax.associative_scan``
(log-depth, O(S) work) — sequence-parallel and the reason the hybrid arch
qualifies for the long_500k cell.  Decode is the O(1) per-token update.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import Params
from .ssm import _causal_conv

_C = 8.0


class RGLRUState(NamedTuple):
    conv: jax.Array    # (B, k-1, d_rnn)
    h: jax.Array       # (B, d_rnn)


def _width(cfg) -> int:
    return cfg.rnn_width or cfg.d_model


def init_rglru(cfg, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    d, w = cfg.d_model, _width(cfg)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "w_in": (jax.random.normal(ks[0], (d, w), jnp.float32) * s).astype(dt),
        "w_gate": (jax.random.normal(ks[1], (d, w), jnp.float32) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[2], (4, w), jnp.float32) * 0.2
                   ).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "wa": (jax.random.normal(ks[3], (w, w), jnp.float32) * w ** -0.5
               ).astype(dt),
        "wx": (jax.random.normal(ks[4], (w, w), jnp.float32) * w ** -0.5
               ).astype(dt),
        "ba": jnp.zeros((w,), jnp.float32),
        "bx": jnp.zeros((w,), jnp.float32),
        # Λ init so that a ~ U(0.9, 0.999)^c-ish (Griffin appendix)
        "lam": jnp.full((w,), 0.5, jnp.float32),
        "w_out": (jax.random.normal(ks[5], (w, d), jnp.float32) * w ** -0.5
                  ).astype(dt),
    }


def _gates(p: Params, u: jax.Array):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", uf, p["wa"].astype(jnp.float32))
                       + p["ba"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", uf, p["wx"].astype(jnp.float32))
                       + p["bx"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r          # (B,S,W)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    return a, b


def apply_rglru(p: Params, x: jax.Array, cfg,
                state: RGLRUState | None = None,
                return_state: bool = False,
                q_valid: jax.Array | None = None
                ) -> tuple[jax.Array, RGLRUState | None]:
    """x: (B, S, d_model) -> (B, S, d_model).

    ``q_valid`` (B, S) bool marks ragged rows right-padded to S.  Pad
    positions become exact IDENTITY elements of the linear recurrence —
    ``(a, b) = (1, 0)`` composes as a no-op under the associative scan, so
    carried state passes through them unchanged.  Masking the gates
    directly is load-bearing: zeroing the recurrence gate ``r`` alone would
    give ``a = 1`` but ``b = sqrt(max(1 - a², 1e-12)) · (i ⊙ u) ≠ 0``.  The
    conv tail gathers each row's last valid inputs.  Pad rows' emitted
    outputs are garbage; callers discard them.
    """
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"], preferred_element_type=x.dtype))
    u = jnp.einsum("bsd,dw->bsw", x, p["w_in"], preferred_element_type=x.dtype)
    lengths = None if q_valid is None \
        else jnp.sum(q_valid.astype(jnp.int32), axis=1)
    u, new_tail = _causal_conv(u, p["conv_w"], p["conv_b"],
                               state.conv if state is not None else None,
                               lengths=lengths)
    a, b = _gates(p, u)
    if q_valid is not None:
        valid = q_valid[..., None]
        a = jnp.where(valid, a, 1.0)
        b = jnp.where(valid, b, 0.0)

    if x.shape[1] == 1 and state is not None:
        h = a[:, 0] * state.h + b[:, 0]
        hs = h[:, None]
    else:
        h0 = state.h if state is not None else None
        if h0 is not None:
            # fold initial state into the first step: h_1 = a_1 h_0 + b_1
            b = b.at[:, 0].add(a[:, 0] * h0)
        # associative linear recurrence: (a, b) pairs compose as
        # (a2, b2) ∘ (a1, b1) = (a1*a2, a2*b1 + b2)
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br
        _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = hs[:, -1]

    y = hs * gate.astype(hs.dtype)
    out = jnp.einsum("bsw,wd->bsd", y.astype(x.dtype), p["w_out"], preferred_element_type=x.dtype)
    new_state = RGLRUState(conv=new_tail, h=h) if return_state else None
    return out, new_state
