"""Model builder: ModelConfig -> runnable model (train / prefill / decode).

One ``Model`` class covers all 10 assigned architectures:

* decoder-only LMs (dense / MoE / SSM / hybrid) — ``block_pattern`` drives the
  layer mix;
* enc-dec (seamless-m4t): an encoder ``Stack`` (non-causal) + decoder stack
  with cross-attention;
* [audio]/[vlm] frontends are STUBS per the assignment: ``input_specs`` (and
  the data pipeline) provide precomputed frame/patch embeddings, which are
  prepended to the token embeddings.

Batch dicts:
    LM      : {"tokens": (B, S) i32, "labels": (B, S) i32}
    +frontend: {"frontend": (B, F, d_model)} and tokens/labels are (B, S-F)
    enc-dec : {"src_embeds": (B, F, d_model), "tokens": (B, S), "labels": ...}
Decode state: {"caches": ..., "enc": enc-dec encoder caches or None}
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .layers import (Params, apply_norm, embed_tokens, init_embedding,
                     init_lm_head, init_norm, lm_logits)
from .pspec import constrain
from .transformer import Stack


class Model:
    def __init__(self, cfg):
        self.cfg = cfg
        pattern = cfg.pattern_for_layers()[: len(cfg.block_pattern)]
        if cfg.family == "encdec":
            dec_pattern = ("attn_cross",)
            self.encoder = Stack(cfg, ("attn",), cfg.encoder_layers,
                                 causal=False)
            self.decoder = Stack(cfg, dec_pattern, cfg.n_layers, causal=True)
        else:
            self.encoder = None
            self.decoder = Stack(cfg, cfg.block_pattern, cfg.n_layers,
                                 causal=True)

    # ------------------------------------------------------------- params

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 5)
        p: Params = {
            "embed": init_embedding(cfg, ks[0]),
            "decoder": self.decoder.init(ks[1]),
            "final_norm": init_norm(cfg),
            "head": init_lm_head(cfg, ks[2]),
        }
        if self.encoder is not None:
            p["encoder"] = self.encoder.init(ks[3])
            p["enc_norm"] = init_norm(cfg)
        return p

    def param_count(self, params) -> int:
        return sum(x.size for x in jax.tree.leaves(params))

    def prepare_dslot(self, params, mesh=None, tp_axis="model") -> Params:
        """One-time DSLOT weight lowering for serving (no-op unless the
        config's digit-serial MLP path applies).  Returns params with
        prepared ``DslotWeights`` attached to every MLP up-projection, so
        per-request execution never re-encodes weight tables.

        ``mesh``/``tp_axis`` make every prepared layer tensor-parallel:
        N-axis weight/termination-table shards under ``shard_map``, with
        the dense (non-digit-serial) projections constrained through
        ``models/pspec.py`` when the caller installs the same mesh via
        ``pspec.set_mesh`` (the serving engine does both from
        ``ServeConfig.mesh``)."""
        from .mlp import prepare_mlp_dslot
        return prepare_mlp_dslot(params, self.cfg, mesh=mesh,
                                 tp_axis=tp_axis)

    # ------------------------------------------------------------- helpers

    def _embed_inputs(self, params, batch) -> jax.Array:
        cfg = self.cfg
        tok = embed_tokens(params["embed"], batch["tokens"], cfg)
        if cfg.frontend and "frontend" in batch:
            front = batch["frontend"].astype(tok.dtype)
            tok = jnp.concatenate([front, tok], axis=1)
        return tok

    def _encode(self, params, batch):
        cfg = self.cfg
        src = batch["src_embeds"].astype(jnp.dtype(cfg.dtype))
        pos = jnp.arange(src.shape[1], dtype=jnp.int32)
        enc, _, _ = self.encoder.apply(params["encoder"], src, positions=pos,
                                       mode="train")
        return apply_norm(params["enc_norm"], enc, cfg)

    # ------------------------------------------------------------- forward

    def forward(self, params, batch, mode: str = "train",
                cache_len: int | None = None,
                lengths: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array, Any]:
        """Full-sequence pass.  Returns (logits, aux_loss, caches|None).

        ``lengths`` (prefill mode only): per-sequence (B,) valid token
        counts for a RAGGED stacked batch — rows are right-padded to the
        common S, pad positions are masked out of every layer's carried
        state (KV-ring writes skipped, recurrent scans treat them as
        identity steps via ``q_valid``), and the prefill logits are taken
        at each row's last VALID position instead of column S-1.  With a
        frontend, ``lengths`` counts TOKENS; the prepended frontend frames
        are always valid.
        """
        cfg = self.cfg
        enc_out = self._encode(params, batch) if self.encoder is not None \
            else None
        x = constrain(self._embed_inputs(params, batch), "b", None, None)
        S = x.shape[1]
        pos = jnp.arange(S, dtype=jnp.int32)
        q_valid = None
        if lengths is not None and mode == "prefill":
            F = S - batch["tokens"].shape[1]    # frontend frames, if any
            valid_to = jnp.asarray(lengths, jnp.int32) + F
            q_valid = jnp.arange(S, dtype=jnp.int32)[None] < valid_to[:, None]
        x, caches, aux = self.decoder.apply(
            params["decoder"], x, positions=pos, enc_out=enc_out, mode=mode,
            cache_len=cache_len, q_valid=q_valid)
        x = apply_norm(params["final_norm"], x, cfg)
        if cfg.frontend:
            x = x[:, S - batch["tokens"].shape[1]:]
        if mode == "prefill":
            # serving only needs the next-token distribution: computing the
            # (B, S, V) logits for a 32k prompt is pure waste (multi-GB)
            if lengths is not None:
                idx = jnp.clip(lengths.astype(jnp.int32) - 1, 0,
                               x.shape[1] - 1)
                x = jnp.take_along_axis(x, idx[:, None, None], axis=1)
            else:
                x = x[:, -1:]
        logits = lm_logits(params["head"], params["embed"], x, cfg)
        return logits, aux, caches if mode == "prefill" else None

    # ------------------------------------------------------------- serving

    def prefill(self, params, batch, max_len: int | None = None,
                lengths: jax.Array | None = None
                ) -> tuple[jax.Array, dict]:
        """One-shot prompt ingestion.  ``lengths``: optional per-sequence
        (B,) valid token counts — stacked RAGGED prompts, right-padded to a
        common width, each row's logits and decode position taken at its own
        length (see ``forward``).  Every stack kind accepts ragged batches:
        pad positions skip KV-ring writes and pass through recurrent scans
        as exact identity steps."""
        logits, _, caches = self.forward(params, batch, mode="prefill",
                                         cache_len=max_len, lengths=lengths)
        B = batch["tokens"].shape[0]
        if lengths is not None:
            F = self._full_len(batch) - batch["tokens"].shape[1]
            pos = jnp.asarray(lengths, jnp.int32) + F
        else:
            pos = jnp.full((B,), self._full_len(batch), jnp.int32)
        return logits[:, -1], {"caches": caches, "pos": pos}

    def _full_len(self, batch) -> int:
        S = batch["tokens"].shape[1]
        if self.cfg.frontend and "frontend" in batch:
            S += batch["frontend"].shape[1]
        return S

    def decode_step(self, params, state: dict, tokens: jax.Array
                    ) -> tuple[jax.Array, dict]:
        """One token for every sequence.  tokens: (B, 1) int32.

        ``state["pos"]`` is a per-sequence (B,) vector — a serving pool's
        slots may sit at different decode depths (staggered admissions); a
        legacy scalar still works and means "all sequences at this depth".
        """
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg)
        pos = state["pos"].astype(jnp.int32)
        pos2d = pos[:, None] if pos.ndim == 1 else pos[None]   # (B,1)|(1,1)
        x, caches, _ = self.decoder.apply(
            params["decoder"], x, positions=pos2d, caches=state["caches"],
            mode="decode")
        x = apply_norm(params["final_norm"], x, cfg)
        logits = lm_logits(params["head"], params["embed"], x, cfg)
        return logits[:, 0], {"caches": caches, "pos": state["pos"] + 1}

    def extend(self, params, state: dict, tokens: jax.Array,
               lengths: jax.Array | None = None
               ) -> tuple[jax.Array, dict]:
        """Append multi-token prompt chunks to an existing decode state.

        The chunked-prefill primitive: runs the decode path with S > 1
        tokens per sequence at positions ``state["pos"][b] ..
        state["pos"][b] + S - 1``, writing KV into each sequence's cache
        ring at those per-sequence offsets (recurrent mixers advance from
        their carried state).  Returns each row's last position's logits and
        the extended state — so stacked prompts can be fed through their
        caches one fixed-size chunk at a time, at ragged offsets, and the
        final chunk's logits seed decoding exactly like a one-shot
        ``prefill``.

        tokens: (B, 1..S) int32 — any batch size; ``state["pos"]`` is the
        per-sequence (B,) offset vector, so stacked requests may sit at
        different depths.

        lengths: optional per-sequence (B,) valid token counts for RAGGED
        chunks right-padded to the common S.  Pad rows write nothing into
        the KV rings, pass through the recurrent scans as exact identity
        steps, and do not advance ``pos``; each row's logits come from its
        last VALID position (rows with length 0 ride along untouched —
        their logits are garbage, callers ignore them).  Every stack kind
        accepts ragged chunks.
        """
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg)
        B, S = tokens.shape
        pos0 = state["pos"].astype(jnp.int32)
        pos = pos0[:, None] + jnp.arange(S, dtype=jnp.int32)[None]   # (B, S)
        q_valid = None
        if lengths is not None:
            lengths = jnp.asarray(lengths, jnp.int32)
            q_valid = jnp.arange(S, dtype=jnp.int32)[None] < lengths[:, None]
        x, caches, _ = self.decoder.apply(
            params["decoder"], x, positions=pos, caches=state["caches"],
            mode="decode", q_valid=q_valid)
        x = apply_norm(params["final_norm"], x, cfg)
        if lengths is None:
            last = x[:, -1:]
            new_pos = pos0 + S
        else:
            idx = jnp.clip(lengths - 1, 0, S - 1)
            last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
            new_pos = pos0 + lengths
        logits = lm_logits(params["head"], params["embed"], last, cfg)
        return logits[:, 0], {"caches": caches, "pos": new_pos}

    def init_decode_state(self, batch_size: int, seq_len: int,
                          enc_len: int = 0) -> dict:
        dtype = jnp.dtype(self.cfg.dtype)
        caches = self.decoder.init_cache(batch_size, seq_len, enc_len, dtype)
        return {"caches": caches, "pos": jnp.zeros((batch_size,), jnp.int32)}


def build_model(cfg) -> Model:
    return Model(cfg)


def loss_fn(model: Model, params, batch, aux_weight: float = 0.01):
    """Next-token cross-entropy (+ MoE load-balance aux)."""
    logits, aux, _ = model.forward(params, batch, mode="train")
    labels = batch["labels"]
    # CE without materializing a f32 (B, S, V) tensor: keep probabilities in
    # the logits dtype (max-subtracted, safe) and accumulate reductions in
    # f32 — with vocab-parallel logits the reductions become the only
    # cross-model-axis traffic.
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    sumexp = jnp.sum(jnp.exp(shifted).astype(jnp.float32), axis=-1)
    lse = jnp.log(sumexp) + m[..., 0].astype(jnp.float32)
    tgt = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ll = tgt.astype(jnp.float32) - lse
    mask = (labels >= 0).astype(jnp.float32)
    loss = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux_weight * aux, (loss, aux)
