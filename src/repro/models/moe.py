"""Mixture-of-Experts layer: top-k router + capacity-based dispatch.

Dispatch is scatter/gather based (O(T*D) data movement, no one-hot einsum
blow-up): each (token, choice) is assigned a slot ``expert * C + rank`` where
``rank`` is the token's arrival order within the expert (cumsum over the
token axis) and ``C`` the per-expert capacity.  Overflowing tokens are dropped
for that expert (standard GShard/Switch semantics, capacity_factor controls
the drop rate); their combine weight is zero so the residual path carries them.

Expert FFN compute is a single batched einsum over (E, C, D) — per-expert
FLOPs proportional to *active* tokens only, which keeps the roofline's
MODEL_FLOPS/HLO_FLOPs ratio honest (DESIGN.md §5).

Expert-parallel (all_to_all) execution is provided separately in
``repro.distributed.expert_parallel`` via shard_map; this module's dense
einsum form is the pjit/GSPMD path (experts sharded over the model axis).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Params
from .mlp import _ACTS
from .pspec import constrain


def init_moe(cfg, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    s = D ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (D, E), jnp.float32) * s
                   ).astype(jnp.float32),
        "up": (jax.random.normal(ks[1], (E, D, F), jnp.float32) * s
               ).astype(dt),
        "down": (jax.random.normal(ks[2], (E, F, D), jnp.float32) * F ** -0.5
                 ).astype(dt),
    }
    if cfg.glu:
        p["gate"] = (jax.random.normal(ks[3], (E, D, F), jnp.float32) * s
                     ).astype(dt)
    return p


def moe_capacity(cfg, n_tokens: int) -> int:
    per = n_tokens * cfg.top_k / cfg.n_experts
    cap = int(per * cfg.capacity_factor) + 1
    cap = max(cap, cfg.top_k)
    return -(-cap // 128) * 128   # 128-aligned so capacity slots shard evenly


def apply_moe(p: Params, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss).  aux = load-balancing loss (Switch).

    Decode steps (S == 1) use capacity = T*K: dropless by construction (the
    worst case — every token picking the same expert — still fits).  At decode
    the expert GEMMs are weight-memory-bound, so the nominal compute inflation
    of generous capacity is invisible on the roofline (DESIGN.md §6)."""
    from .pspec import fsdp_size
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k

    # Dispatch is computed per DATA SHARD (local capacity, as production MoE
    # systems do): a token-indexed scatter into a global buffer cannot be
    # partitioned by GSPMD (it replicates a multi-GB buffer and all-reduces
    # it — measured 60 GiB/device on mixtral prefill).  With a leading shard
    # axis everything — cumsum ranks, scatter, expert einsum, gather — stays
    # batched over that axis and shards cleanly.  Without a mesh G == 1 and
    # semantics are identical to global dispatch.
    G = fsdp_size() if B % max(fsdp_size(), 1) == 0 else 1
    Tl = T // G
    flat = x.reshape(G, Tl, D)

    # Long sequences stream through the experts in token BLOCKS (flash-style):
    # dispatch buffers scale with the block, not the sequence — a 1M-token
    # prefill would otherwise need ~4 GiB/device of (E*C, D) buffers.
    tb = min(Tl, 4096)
    nb = Tl // tb
    if nb > 1 and Tl % tb == 0 and S > 1:
        blocks = jnp.moveaxis(flat.reshape(G, nb, tb, D), 1, 0)

        def one(block):
            return _moe_block(p, block, cfg, S)

        ys, auxs = jax.lax.map(one, blocks)
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D)
        return y, jnp.mean(auxs)
    y, aux = _moe_block(p, flat, cfg, S)
    return y.reshape(B, S, D), aux


def _moe_block(p: Params, flat: jax.Array, cfg, S: int
               ) -> tuple[jax.Array, jax.Array]:
    """One token block through the experts.  flat: (G, Tl, D) — G data
    shards, local capacity per shard."""
    G, Tl, D = flat.shape
    E, K = cfg.n_experts, cfg.top_k
    act = _ACTS[cfg.act]
    C = Tl * K if S == 1 else moe_capacity(cfg, Tl)
    logits = jnp.einsum("gtd,de->gte", flat.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                   # (G, Tl, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)           # (G, Tl, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ----- load-balancing auxiliary loss (Switch eq. 4), global means
    me = jnp.mean(probs, axis=(0, 1))                         # (E,)
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (G, Tl, K, E)
    ce = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))
    aux = E * jnp.sum(me * ce) / K

    # ----- per-shard capacity ranks (arrival order within expert)
    flat_choice = onehot.reshape(G, Tl * K, E)
    ranks = jnp.cumsum(flat_choice, axis=1) - flat_choice
    rank = jnp.sum(ranks * flat_choice, axis=-1).reshape(G, Tl, K)
    keep = rank < C
    slot = expert_idx * C + jnp.minimum(rank, C - 1).astype(jnp.int32)

    # ----- dispatch: per-shard scatter into (G, E*C, D)
    contrib = jnp.where(keep[..., None], 1.0, 0.0).astype(flat.dtype)
    src = (flat[:, :, None, :] * contrib).reshape(G, Tl * K, D)
    buf = jnp.zeros((G, E * C, D), flat.dtype)
    buf = jax.vmap(lambda b, s, u: b.at[s].add(u))(
        buf, slot.reshape(G, Tl * K), src)
    xb = constrain(buf.reshape(G, E, C, D), "b", None, None, None)

    # ----- expert FFN (batched over shards and experts; d_ff over "model")
    up = constrain(jnp.einsum("gecd,edf->gecf", xb, p["up"], preferred_element_type=xb.dtype),
                   "b", None, None, "tp")
    if cfg.glu:
        h = act(constrain(jnp.einsum("gecd,edf->gecf", xb, p["gate"], preferred_element_type=xb.dtype),
                          "b", None, None, "tp")) * up
    else:
        h = act(up)
    yb = constrain(jnp.einsum("gecf,efd->gecd", h, p["down"], preferred_element_type=h.dtype),
                   "b", None, None, None).reshape(G, E * C, D)

    # ----- combine: per-shard gather, weight by gate
    gathered = jax.vmap(lambda y_, s: y_[s])(
        yb, slot.reshape(G, Tl * K)).reshape(G, Tl, K, D)
    w = (gate_vals * keep).astype(gathered.dtype)
    y = jnp.einsum("gtkd,gtk->gtd", gathered, w)
    return y, aux.astype(jnp.float32)
