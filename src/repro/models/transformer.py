"""Block assembly: pattern-driven layer stacks with scan-over-layers + remat.

A model is a sequence of blocks drawn from the config's ``block_pattern``
(tiled to ``n_layers``): "attn" (self-attention + MLP), "attn_cross" (adds
cross-attention, enc-dec decoder), "moe" (attention + MoE-FFN), "ssm"
(mamba2 mixer), "rglru" (RG-LRU mixing + MLP).

Layers are stacked per pattern position and iterated with ``jax.lax.scan``
(+ ``jax.checkpoint`` rematerialization), so the lowered HLO is O(pattern)
regardless of depth — a 95-layer model compiles as one scanned block.  The
pattern remainder (e.g. recurrentgemma's 26 = 3*8 + 2) runs unscanned.

Caches are pytrees mirroring the parameter stacking, so decode steps scan
with the same structure.  ``mode="decode"`` accepts multi-token inputs too:
attention writes each chunk's KV at its positions into the per-sequence
rings — batched, at ragged per-sequence offsets, with ``q_valid`` masking
the ring writes of right-padded rows — and recurrent mixers advance their
carried state through masked scans where pad positions are exact identity
steps (``apply_ssm`` / ``apply_rglru``).  Every layer kind accepts ragged
``q_valid`` batches.  This is the ``Model.extend`` path that batched
chunked prefill (``docs/serving.md``) is built on.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import stats as model_stats
from .attention import (KVCache, attention_forward, init_attention,
                        init_kv_cache)
from .layers import Params, apply_norm, init_norm
from .mlp import apply_mlp, init_mlp
from .moe import apply_moe, init_moe
from .rglru import RGLRUState, apply_rglru, init_rglru
from .ssm import SSMState, apply_ssm, init_ssm


# ------------------------------------------------------------- single layer

def init_layer(cfg, key, kind: str) -> Params:
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        return {"norm": init_norm(cfg), "mixer": init_ssm(cfg, ks[0])}
    if kind == "rglru":
        return {"norm1": init_norm(cfg), "mixer": init_rglru(cfg, ks[0]),
                "norm2": init_norm(cfg), "mlp": init_mlp(cfg, ks[1])}
    if kind == "moe":
        return {"norm1": init_norm(cfg), "attn": init_attention(cfg, ks[0]),
                "norm2": init_norm(cfg), "moe": init_moe(cfg, ks[1])}
    if kind == "attn_cross":
        return {"norm1": init_norm(cfg), "attn": init_attention(cfg, ks[0]),
                "normx": init_norm(cfg),
                "cross": init_attention(cfg, ks[1], cross=True),
                "norm2": init_norm(cfg), "mlp": init_mlp(cfg, ks[2])}
    # "attn"
    return {"norm1": init_norm(cfg), "attn": init_attention(cfg, ks[0]),
            "norm2": init_norm(cfg), "mlp": init_mlp(cfg, ks[1])}


def init_layer_cache(cfg, kind: str, batch: int, seq_len: int,
                     enc_len: int, dtype) -> Any:
    d_inner = cfg.ssm_expand * cfg.d_model
    if kind == "ssm":
        from .ssm import _dims
        _, H, P, N, G = _dims(cfg)
        conv_ch = d_inner + 2 * G * N
        return SSMState(
            conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
            ssm=jnp.zeros((batch, H, P, N), jnp.float32))
    if kind == "rglru":
        w = cfg.rnn_width or cfg.d_model
        return RGLRUState(conv=jnp.zeros((batch, 3, w), dtype),
                          h=jnp.zeros((batch, w), jnp.float32))
    self_cache = init_kv_cache(cfg, batch, seq_len, dtype)
    if kind == "attn_cross":
        hd = cfg.head_dim_
        cross = KVCache(
            k=jnp.zeros((batch, enc_len, cfg.n_kv_heads, hd), dtype),
            v=jnp.zeros((batch, enc_len, cfg.n_kv_heads, hd), dtype),
            positions=jnp.broadcast_to(
                jnp.arange(enc_len, dtype=jnp.int32)[None],
                (batch, enc_len)))
        return (self_cache, cross)
    return self_cache


def apply_layer(p: Params, x: jax.Array, cfg, kind: str, *,
                positions: jax.Array, cache: Any = None,
                enc_out: jax.Array | None = None, mode: str = "train",
                causal: bool = True, cache_len: int | None = None,
                q_valid: jax.Array | None = None
                ) -> tuple[jax.Array, Any, jax.Array]:
    """Returns (x, new_cache, aux_loss).

    ``q_valid``: (B, S) bool for ragged batched forwards — pad rows skip
    the KV-ring write in attention kinds (see ``attention_forward``) and
    are exact identity steps in the recurrent mixers (``apply_ssm`` /
    ``apply_rglru``), so carried state only ever advances past real tokens.
    """
    aux = jnp.zeros((), jnp.float32)
    return_cache = mode == "prefill"
    use_cache = mode == "decode"

    if kind == "ssm":
        h, new_state = apply_ssm(p["mixer"], apply_norm(p["norm"], x, cfg),
                                 cfg, state=cache if use_cache else None,
                                 return_state=return_cache or use_cache,
                                 q_valid=q_valid)
        return x + h, new_state, aux

    if kind == "rglru":
        h, new_state = apply_rglru(p["mixer"], apply_norm(p["norm1"], x, cfg),
                                   cfg, state=cache if use_cache else None,
                                   return_state=return_cache or use_cache,
                                   q_valid=q_valid)
        x = x + h
        x = x + apply_mlp(p["mlp"], apply_norm(p["norm2"], x, cfg), cfg)
        return x, new_state, aux

    if kind == "attn_cross":
        self_cache, cross_cache = cache if cache is not None else (None, None)
        h, new_self = attention_forward(
            p["attn"], apply_norm(p["norm1"], x, cfg), cfg,
            positions=positions, cache=self_cache if use_cache else None,
            causal=causal, return_cache=return_cache, cache_len=cache_len,
            q_valid=q_valid)
        x = x + h
        if use_cache:
            # decode: static cross cache built at prefill
            h, cross_cache = attention_forward(
                p["cross"], apply_norm(p["normx"], x, cfg), cfg,
                positions=positions, cache=cross_cache, is_cross=True,
                causal=False)
        else:
            h, cross_cache = attention_forward(
                p["cross"], apply_norm(p["normx"], x, cfg), cfg,
                positions=positions, kv_x=enc_out, causal=False,
                return_cache=return_cache)
        x = x + h
        x = x + apply_mlp(p["mlp"], apply_norm(p["norm2"], x, cfg), cfg)
        return x, (new_self, cross_cache), aux

    # attn / moe
    h, new_cache = attention_forward(
        p["attn"], apply_norm(p["norm1"], x, cfg), cfg, positions=positions,
        cache=cache if use_cache else None, causal=causal,
        return_cache=return_cache, cache_len=cache_len, q_valid=q_valid)
    x = x + h
    if kind == "moe":
        h, aux = apply_moe(p["moe"], apply_norm(p["norm2"], x, cfg), cfg)
    else:
        h = apply_mlp(p["mlp"], apply_norm(p["norm2"], x, cfg), cfg)
    return x + h, new_cache, aux


# ------------------------------------------------------------- layer stacks

class Stack:
    """Pattern-tiled stack of layers with scan-over-groups execution."""

    def __init__(self, cfg, pattern: tuple[str, ...], n_layers: int,
                 causal: bool = True):
        self.cfg = cfg
        self.n_layers = n_layers
        self.causal = causal
        # one scan step covers `scan_unroll` pattern periods (fewer saved
        # carries under full remat; recompute cost is unchanged)
        unroll = max(1, cfg.scan_unroll)
        self.pattern = tuple(pattern) * unroll
        self.period = len(self.pattern)
        if cfg.scan_layers and n_layers >= 2 * self.period:
            self.n_groups = n_layers // self.period
            self.n_rest = n_layers % self.period
        else:
            self.n_groups = 0
            self.n_rest = n_layers

    @property
    def rest_kinds(self) -> tuple[str, ...]:
        full = (self.pattern * (-(-self.n_layers // self.period)))
        return full[self.n_groups * self.period: self.n_layers]

    def init(self, key) -> Params:
        p: Params = {"groups": [], "rest": []}
        keys = jax.random.split(key, self.n_layers)
        ki = 0
        for pos in range(self.period if self.n_groups else 0):
            kind = self.pattern[pos]
            layers = []
            for g in range(self.n_groups):
                layers.append(init_layer(self.cfg, keys[ki], kind))
                ki += 1
            p["groups"].append(jax.tree.map(
                lambda *xs: jnp.stack(xs), *layers))
        for kind in self.rest_kinds:
            p["rest"].append(init_layer(self.cfg, keys[ki], kind))
            ki += 1
        return p

    def init_cache(self, batch: int, seq_len: int, enc_len: int, dtype):
        c = {"groups": [], "rest": []}
        for pos in range(self.period if self.n_groups else 0):
            kind = self.pattern[pos]
            per = [init_layer_cache(self.cfg, kind, batch, seq_len, enc_len,
                                    dtype) for _ in range(self.n_groups)]
            c["groups"].append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
        for kind in self.rest_kinds:
            c["rest"].append(init_layer_cache(self.cfg, kind, batch, seq_len,
                                              enc_len, dtype))
        return c

    def apply(self, p: Params, x: jax.Array, *, positions, caches=None,
              enc_out=None, mode: str = "train", cache_len: int | None = None,
              q_valid: jax.Array | None = None):
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = {"groups": [], "rest": []}

        if self.n_groups:
            def group_body(x, layer_inputs):
                params_g, caches_g = layer_inputs
                aux_g = jnp.zeros((), jnp.float32)
                new_cs = []
                # Layer statistics recorded inside a scanned body would be
                # scan-local tracers; capture them here and thread them out
                # as scan outputs, re-recording the stacked values after the
                # scan — makes the stats side channel scan-safe.
                with model_stats.collect() as sink:
                    for pos, kind in enumerate(self.pattern):
                        c = None if caches_g is None else caches_g[pos]
                        x, nc, aux = apply_layer(
                            params_g[pos], x, cfg, kind, positions=positions,
                            cache=c, enc_out=enc_out, mode=mode,
                            causal=self.causal, cache_len=cache_len,
                            q_valid=q_valid)
                        new_cs.append(nc)
                        aux_g = aux_g + aux
                recs = {k: tuple(v) for k, v in sink.items()}
                return x, (tuple(new_cs), aux_g, recs)

            body = group_body
            if cfg.remat and mode == "train":
                body = jax.checkpoint(
                    group_body,
                    policy=jax.checkpoint_policies.nothing_saveable)

            caches_g = None
            if caches is not None:
                caches_g = tuple(caches["groups"])
            xs = (tuple(p["groups"]), caches_g)
            if caches_g is None:
                xs = (tuple(p["groups"]), None)

            def scan_body(x, inp):
                return body(x, inp)

            if caches_g is None:
                # scan only over params
                def scan_body_np(x, params_g):
                    return body(x, (params_g, None))
                x, (ncs, auxs, recs) = jax.lax.scan(scan_body_np, x,
                                                    tuple(p["groups"]))
                new_caches["groups"] = list(ncs) if mode == "prefill" else []
            else:
                x, (ncs, auxs, recs) = jax.lax.scan(scan_body, x, xs)
                new_caches["groups"] = list(ncs)
            aux_total = aux_total + jnp.sum(auxs)
            for k, vals in recs.items():
                for v in vals:       # leading axis = n_groups (scan steps)
                    model_stats.record(k, v)

        for i, kind in enumerate(self.rest_kinds):
            c = None if caches is None else caches["rest"][i]
            x, nc, aux = apply_layer(p["rest"][i], x, cfg, kind,
                                     positions=positions, cache=c,
                                     enc_out=enc_out, mode=mode,
                                     causal=self.causal, cache_len=cache_len,
                                     q_valid=q_valid)
            new_caches["rest"].append(nc)
            aux_total = aux_total + aux

        return x, new_caches, aux_total
