"""models subpackage of the DSLOT-NN reproduction."""
