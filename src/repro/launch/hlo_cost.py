"""Trip-count-corrected cost analysis over compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE (verified in
tests/test_tools_serve.py), which under-counts scanned-layer and
grad-accumulation programs by orders of magnitude.  XLA leaves
``backend_config={"known_trip_count":{"n":...}}`` on while ops, so this module
parses the HLO module, builds the call graph (while bodies, fusions, calls,
conditionals) and walks it from ENTRY with multiplicative trip counts,
accumulating:

* ``dot_flops``      — 2 * prod(output) * prod(contracting dims) per dot /
                       convolution (MXU roofline numerator),
* ``vector_flops``   — elementwise arithmetic numel (VPU, reported separately),
* ``hbm_bytes``      — COMPULSORY traffic: operands+outputs of dots/convs
                       (weights re-streamed every loop iteration — real),
                       collectives, scatter/gather/dynamic-update-slice and
                       reduces.  Elementwise fusions/copies/converts are
                       excluded: on TPU they fuse into their consumers.
* ``hbm_bytes_upper``— the loose fusion-boundary model (every top-level op
                       reads operands and writes its output once x trips);
                       true HBM traffic lies between the two,
* ``collective_bytes`` — per-kind bytes and op counts (inside loops these
                       multiply by trip count — a collective in the
                       grad-accumulation scan really does run M times).

All quantities are per-device (the SPMD module is the per-device program).
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_TO_RE = re.compile(r"to_apply=(%[\w.\-]+)|to=(%[\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"(%[\w.\-]+)")

_VECTOR_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs",
    "logistic", "cosine", "sine", "expm1", "log1p", "select", "compare",
    "and", "or", "xor", "not", "clamp", "floor", "ceil", "round",
}
_VIEW_OPS = {
    "parameter", "bitcast", "tuple", "get-tuple-element", "constant",
    "iota", "after-all", "partition-id", "replica-id",
}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _shape_elems_bytes(text: str) -> tuple[int, int]:
    elems = 0
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for x in dims.split(","):
            if x:
                n *= int(x)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


@dataclass
class Op:
    name: str
    shape_text: str
    opcode: str
    rest: str
    out_elems: int = 0
    out_bytes: int = 0


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    symtab: dict = field(default_factory=dict)   # %name -> shape_text


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, Computation] = {}
        self.entry: str | None = None
        self._parse(hlo_text)
        self._cache: dict[str, dict] = {}

    # ------------------------------------------------------------- parsing

    def _parse(self, text: str) -> None:
        cur: Computation | None = None
        for line in text.splitlines():
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(name=m.group(2))
                self.comps[cur.name] = cur
                if m.group(1):
                    self.entry = cur.name
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            om = _OP_RE.match(line)
            if om:
                op = Op(name=om.group(1), shape_text=om.group(2),
                        opcode=om.group(3), rest=om.group(4))
                op.out_elems, op.out_bytes = _shape_elems_bytes(op.shape_text)
                cur.ops.append(op)
                cur.symtab[op.name] = op.shape_text

    # ------------------------------------------------------------- costing

    def _dot_flops(self, comp: Computation, op: Op) -> float:
        cd = _CDIMS_RE.search(op.rest)
        contract = 1
        if cd:
            lhs_name_m = _OPERAND_RE.search(op.rest)
            lhs_shape = comp.symtab.get(lhs_name_m.group(1), "") \
                if lhs_name_m else ""
            dims_m = _SHAPE_RE.search(lhs_shape)
            if dims_m:
                dims = [int(x) for x in dims_m.group(2).split(",") if x]
                for i in cd.group(1).split(","):
                    if i and int(i) < len(dims):
                        contract *= dims[int(i)]
        return 2.0 * op.out_elems * contract

    def _conv_flops(self, comp: Computation, op: Op) -> float:
        # 2 * out_elems * (kernel spatial * in_channels): approximate from
        # rhs (kernel) shape product / out_channels.
        names = _OPERAND_RE.findall(op.rest)
        if len(names) >= 2:
            k_elems, _ = _shape_elems_bytes(comp.symtab.get(names[1], ""))
            dims_m = _SHAPE_RE.search(op.shape_text)
            if dims_m and k_elems:
                out_dims = [int(x) for x in dims_m.group(2).split(",") if x]
                oc = out_dims[-1] if out_dims else 1
                return 2.0 * op.out_elems * max(k_elems // max(oc, 1), 1)
        return 2.0 * op.out_elems

    def _analyze_comp(self, name: str) -> dict:
        if name in self._cache:
            return self._cache[name]
        comp = self.comps.get(name)
        acc = {"dot_flops": 0.0, "vector_flops": 0.0, "hbm_bytes": 0.0,
               "hbm_bytes_upper": 0.0,
               "coll_bytes": {k: 0.0 for k in _COLLECTIVES},
               "coll_counts": {k: 0.0 for k in _COLLECTIVES},
               "unknown_trip_whiles": 0}
        if comp is None:
            return acc
        self._cache[name] = acc      # break cycles defensively
        for op in comp.ops:
            code = op.opcode
            if code == "while":
                body = _BODY_RE.search(op.rest)
                trip_m = _TRIP_RE.search(op.rest)
                trip = int(trip_m.group(1)) if trip_m else 1
                if not trip_m:
                    acc["unknown_trip_whiles"] += 1
                if body:
                    sub = self._analyze_comp(body.group(1))
                    _merge(acc, sub, trip)
                continue
            if code == "fusion":
                calls = _CALLS_RE.search(op.rest)
                if calls:
                    sub = self._analyze_comp(calls.group(1))
                    # only compute (dots) escapes the fusion boundary;
                    # traffic is operands+output of the fusion itself
                    acc["dot_flops"] += sub["dot_flops"]
                    acc["vector_flops"] += sub["vector_flops"]
                    # dots inside the fusion do stream their operands
                    if sub["dot_flops"]:
                        acc["hbm_bytes"] += self._op_traffic(comp, op)
                acc["hbm_bytes_upper"] += self._op_traffic(comp, op)
                continue
            if code in ("call", "custom-call", "reduce", "sort", "scatter",
                        "gather", "map", "reduce-window", "select-and-scatter"):
                to = _TO_RE.search(op.rest)
                if to:
                    sub = self._analyze_comp(to.group(1) or to.group(2))
                    _merge(acc, sub, 1)
                t = self._op_traffic(comp, op)
                acc["hbm_bytes"] += t
                acc["hbm_bytes_upper"] += t
                continue
            if code == "conditional":
                br = _BRANCH_RE.search(op.rest)
                if br:
                    subs = [self._analyze_comp(b.strip())
                            for b in br.group(1).split(",")]
                    if subs:
                        best = max(subs, key=lambda s: s["dot_flops"]
                                   + s["hbm_bytes"])
                        _merge(acc, best, 1)
                acc["hbm_bytes"] += self._op_traffic(comp, op)
                continue
            if code in _COLLECTIVES or (code.endswith("-start") and
                                        code[:-6] in _COLLECTIVES):
                kind = code[:-6] if code.endswith("-start") else code
                acc["coll_bytes"][kind] += op.out_bytes
                acc["coll_counts"][kind] += 1
                t = self._op_traffic(comp, op)
                acc["hbm_bytes"] += t
                acc["hbm_bytes_upper"] += t
                continue
            if code == "dot":
                acc["dot_flops"] += self._dot_flops(comp, op)
                t = self._op_traffic(comp, op)
                acc["hbm_bytes"] += t
                acc["hbm_bytes_upper"] += t
                continue
            if code == "convolution":
                acc["dot_flops"] += self._conv_flops(comp, op)
                t = self._op_traffic(comp, op)
                acc["hbm_bytes"] += t
                acc["hbm_bytes_upper"] += t
                continue
            if code in ("dynamic-update-slice", "dynamic-slice"):
                t = self._op_traffic(comp, op)
                acc["hbm_bytes"] += t
                acc["hbm_bytes_upper"] += t
                continue
            if code in _VIEW_OPS:
                continue
            if code in _VECTOR_OPS:
                acc["vector_flops"] += op.out_elems
            acc["hbm_bytes_upper"] += self._op_traffic(comp, op)
        self._cache[name] = acc
        return acc

    def _op_traffic(self, comp: Computation, op: Op) -> float:
        read = 0
        for nm in _OPERAND_RE.findall(op.rest.split(")")[0]):
            _, b = _shape_elems_bytes(comp.symtab.get(nm, ""))
            read += b
        return float(read + op.out_bytes)

    def totals(self) -> dict:
        if self.entry is None:
            raise ValueError("no ENTRY computation found")
        t = self._analyze_comp(self.entry)
        out = dict(t)
        out["coll_total_bytes"] = sum(t["coll_bytes"].values())
        return out


def _merge(acc: dict, sub: dict, mult: float) -> None:
    acc["dot_flops"] += mult * sub["dot_flops"]
    acc["vector_flops"] += mult * sub["vector_flops"]
    acc["hbm_bytes"] += mult * sub["hbm_bytes"]
    acc["hbm_bytes_upper"] += mult * sub.get("hbm_bytes_upper", 0.0)
    acc["unknown_trip_whiles"] += sub["unknown_trip_whiles"]
    for k in acc["coll_bytes"]:
        acc["coll_bytes"][k] += mult * sub["coll_bytes"][k]
        acc["coll_counts"][k] += mult * sub["coll_counts"][k]


def analyze_hlo(hlo_text: str) -> dict:
    return HloCostModel(hlo_text).totals()
