"""Production meshes.  Functions, not module constants — importing this
module never touches jax device state (dry-run sets
``xla_force_host_platform_device_count`` before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None, model: int = 2):
    """Small (data, model) mesh for in-process tests (requires the
    host-device override, e.g.
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

    Raises ``ValueError`` instead of silently building a zero-extent mesh
    when fewer than ``model`` devices are available.
    """
    n = n_devices or len(jax.devices())
    if model < 1 or n // model < 1:
        raise ValueError(
            f"make_test_mesh needs at least model={model} devices, have "
            f"{n}; run under XLA_FLAGS=--xla_force_host_platform_device_"
            f"count=N (before jax initializes) or lower `model`")
    return jax.make_mesh((n // model, model), ("data", "model"))
