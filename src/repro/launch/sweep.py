"""Dry-run sweep driver: every live (arch x shape) cell on both meshes.

Each cell runs in a fresh subprocess (clean XLA heap, isolates failures);
existing result JSONs are skipped so the sweep is resumable.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--meshes", default="single,multi")
    args = ap.parse_args()

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", ".."))
    from repro.configs.registry import live_cells

    cells = live_cells()
    meshes = args.meshes.split(",")
    todo = []
    for mesh in meshes:
        for arch, shape in cells:
            fname = f"{arch}__{shape}__{mesh}.json"
            if os.path.exists(os.path.join(args.out, fname)):
                continue
            todo.append((arch, shape, mesh))
    print(f"{len(todo)} cells to run ({len(cells)} live x {meshes})",
          flush=True)

    for i, (arch, shape, mesh) in enumerate(todo):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", args.out]
        if mesh == "multi":
            cmd.append("--multi-pod")
        t0 = time.time()
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            out = r.stdout + r.stderr
            tail = out.strip().splitlines()[-3:]
            fname_path = os.path.join(args.out,
                                      f"{arch}__{shape}__{mesh}.json")
            status = "ok" if r.returncode == 0 and (
                any(l.startswith("OK") for l in out.splitlines())
                and os.path.exists(fname_path)) else "FAIL"
        except subprocess.TimeoutExpired:
            tail, status = ["timeout"], "TIMEOUT"
        dt = time.time() - t0
        print(f"[{i+1}/{len(todo)}] {status} {arch} {shape} {mesh} "
              f"({dt:.0f}s)", flush=True)
        if status != "ok":
            for l in tail:
                print("   ", l[:200], flush=True)


if __name__ == "__main__":
    main()
