"""Render EXPERIMENTS.md §Dry-run table from the sweep artifacts."""

from __future__ import annotations

import glob
import json
import os


def main():
    rows = []
    for f in sorted(glob.glob("experiments/dryrun/*.json")):
        r = json.load(open(f))
        if r.get("tag"):
            continue
        c = r.get("corrected", {})
        peak = (r["memory"].get("temp_size_in_bytes", 0)
                + r["memory"].get("argument_size_in_bytes", 0)) / 2 ** 30
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "mesh": "2x16x16" if r["multi_pod"] else "16x16",
            "compile_s": r["compile_s"],
            "flops": c.get("dot_flops", 0),
            "hbm": c.get("hbm_bytes", 0),
            "coll": c.get("coll_total_bytes", 0),
            "peak": peak,
            "fits": "yes" if peak <= 16.0 else f"NO ({peak:.1f})",
        })
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print("| arch | shape | mesh | compile s | dot FLOPs/dev | HBM B/dev |"
          " coll B/dev | peak GiB | fits 16 GiB |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
              f"{r['compile_s']:.0f} | {r['flops']:.2e} | {r['hbm']:.2e} | "
              f"{r['coll']:.2e} | {r['peak']:.1f} | {r['fits']} |")


if __name__ == "__main__":
    main()
