"""Serving entry point: batched generation with optional DSLOT digit-serial
execution (the paper's engine as a serving-time switch).

    python -m repro.launch.serve --arch seamless-m4t-medium --reduced \
        --batch 4 --max-new 16 [--dslot --n-planes 6]

``--dslot`` turns on digit-plane execution (with early negative termination)
for every ReLU MLP; ``--n-planes`` is the runtime precision knob (named like
the ``generate(..., n_planes=...)`` / ``Request.n_planes`` argument it sets;
``--planes`` is kept as a hidden alias).
"""

import argparse
import dataclasses
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--dslot", action="store_true")
    ap.add_argument("--n-planes", "--planes", type=int, default=8,
                    dest="n_planes")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs.base import DslotConfig
    from repro.configs.registry import get_arch
    from repro.models import stats
    from repro.models.model_zoo import build_model
    from repro.serve.engine import generate

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.dslot:
        cfg = dataclasses.replace(cfg, dslot=DslotConfig(
            enabled=True, n_planes=args.n_planes, block_m=32, block_n=32))
        if cfg.act != "relu" or cfg.glu:
            print(f"note: {cfg.name} has {cfg.act}/glu MLPs — DSLOT early "
                  "termination applies only to ReLU MLPs (DESIGN.md §6); "
                  "running the standard path for those layers.")

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.frontend:
        batch["frontend"] = jax.random.normal(
            key, (args.batch, cfg.frontend_len, cfg.d_model)) * 0.02
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(
            key, (args.batch, 8, cfg.d_model)) * 0.02

    t0 = time.time()
    toks = generate(model, params, batch, args.max_new).tokens
    toks.block_until_ready()
    dt = time.time() - t0
    with stats.collect() as sink:
        if args.dslot:
            model.forward(params, batch)   # eager pass for observable stats
    print(f"arch={cfg.name} generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")
    print("sample:", jax.device_get(toks[0])[:12], "...")
    if sink.get("mlp_dslot_skipped_frac"):
        vals = [float(v) for v in jax.device_get(
            sink["mlp_dslot_skipped_frac"])]
        print(f"DSLOT: {len(vals)} digit-serial MLP calls, mean "
              f"{sum(vals)/len(vals):.1%} MXU passes skipped "
              f"(D={args.n_planes} planes)")


if __name__ == "__main__":
    main()
