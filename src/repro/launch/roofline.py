"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) cell, from the single-pod compiled program:

    compute   = dot_FLOPs / peak_FLOPs          (197 TFLOP/s bf16, v5e)
    memory    = HBM_bytes / HBM_bw              (819 GB/s)
    collective= collective_bytes / link_bw      (~50 GB/s ICI per chip)

All three numerators are per-device, trip-count-corrected (repro.launch.
hlo_cost — `cost_analysis()` counts loop bodies once, see tests).  The
dominant term is the modeled bottleneck; the roofline fraction is
``(MODEL_FLOPS/chips/peak) / dominant`` — the fraction of peak MXU
throughput the step would sustain if it ran exactly at the modeled
bottleneck.  MODEL_FLOPS = 6·N·D for training (2·N·D prefill, 2·N·B decode),
N_active for MoE.

    python -m repro.launch.roofline [--dir experiments/dryrun] [--md out.md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s ICI

_PARAM_CACHE: dict[str, tuple[float, float]] = {}


def param_counts(arch_name: str) -> tuple[float, float]:
    """(N_total, N_active) — active discounts non-routed experts."""
    if arch_name in _PARAM_CACHE:
        return _PARAM_CACHE[arch_name]
    import jax
    import numpy as np
    from repro.configs.registry import get_arch
    from repro.models.model_zoo import build_model

    cfg = get_arch(arch_name)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = active = 0.0
    for path, leaf in flat:
        n = float(np.prod(leaf.shape))
        total += n
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
        if cfg.n_experts and "moe/" in p and any(
                p.endswith(x) for x in ("up", "gate", "down")):
            active += n * cfg.top_k / cfg.n_experts
        else:
            active += n
    _PARAM_CACHE[arch_name] = (total, active)
    return total, active


def model_flops_per_device(arch_name: str, shape_name: str, chips: int
                           ) -> float:
    from repro.configs.registry import get_shape
    shape = get_shape(shape_name)
    _, n_active = param_counts(arch_name)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / chips
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch / chips


def analyze_cell(rec: dict) -> dict:
    c = rec["corrected"]
    chips = 1
    for v in rec["mesh"].values():
        chips *= v
    compute_s = c["dot_flops"] / PEAK_FLOPS
    # compulsory traffic (dot/conv operands incl. per-iteration weight
    # streaming, collectives, scatters); hbm_bytes_upper is the loose
    # fusion-boundary bound — truth lies between (hlo_cost.py docstring)
    memory_s = c["hbm_bytes"] / HBM_BW
    coll_s = c["coll_total_bytes"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], chips)
    useful_s = mf / PEAK_FLOPS
    frac = useful_s / max(terms[dominant], 1e-30)
    peak_gib = (rec["memory"].get("temp_size_in_bytes", 0)
                + rec["memory"].get("argument_size_in_bytes", 0)) / 2 ** 30
    return {
        "arch": rec["arch"], "shape": rec["shape"], "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_upper_s": c.get("hbm_bytes_upper", 0) / HBM_BW,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops_dev": mf, "hlo_flops_dev": c["dot_flops"],
        "useful_ratio": mf / max(c["dot_flops"], 1e-30),
        "roofline_frac": frac, "peak_gib": peak_gib,
        "tag": rec.get("tag", ""),
    }


def predict_tp_scaling(m: int, k: int, n: int, shards: int, *,
                       n_planes: int = 8, bytes_per_el: int = 4,
                       peak_flops: float = PEAK_FLOPS,
                       hbm_bw: float = HBM_BW,
                       link_bw: float = LINK_BW) -> dict:
    """Roofline-model prediction for one N-sharded digit-serial matmul.

    The DSLOT tensor-parallel layout (``kernels/ops.py``) splits the N axis
    ``shards`` ways: compute and weight traffic divide by ``shards``; the
    activations are replicated (free at dispatch), and the only collective
    is the out_specs all-gather of each shard's (M, N/s) output slice —
    each device contributes ``(s-1)/s`` of the (M, N) result over the link.
    Returns the per-term seconds and the predicted speedup vs 1 shard
    (``t1 / ts`` with the same model).  This is a MODEL — measured curves
    land next to it in ``BENCH_distributed.json`` so drift is visible.
    """
    def terms(s: int) -> float:
        flops = 2.0 * m * k * n * n_planes / 8.0 / s   # plane passes ~ D/8
        compute_s = flops / peak_flops
        mem = (k * n / s + m * k) * bytes_per_el
        memory_s = mem / hbm_bw
        # ring all-gather of the (M, N) output: (s-1) hops of M*N/s bytes
        coll_s = (s - 1) * m * (n / s) * bytes_per_el / link_bw
        return compute_s + memory_s + coll_s
    t1, ts = terms(1), terms(shards)
    return {"shards": shards, "t_model_s": ts,
            "predicted_speedup": t1 / max(ts, 1e-30)}


def suggestion(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.5:
            return ("compute-bound with <50% useful FLOPs: cut remat/causal "
                    "waste (pair-scan, smarter checkpoint policy)")
        return "compute-bound near useful peak: quantize (DSLOT int8 planes)"
    if d == "memory":
        return ("memory-bound: fuse/stream weights (bigger microbatch, "
                "int8 weights, DSLOT planes) to raise arithmetic intensity")
    return ("collective-bound: overlap TP gathers (collective matmul), "
            "compress cross-pod grads, or reshard the dominant tensor")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--md", default="experiments/roofline.md")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()

    rows = []
    for f in sorted(glob.glob(os.path.join(args.dir,
                                           f"*__{args.mesh}.json"))):
        rec = json.load(open(f))
        if rec.get("tag"):
            continue
        rows.append(analyze_cell(rec))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))

    lines = ["| arch | shape | compute s | memory s (upper) | collective s |"
             " bottleneck | MODEL/HLO | roofline frac | peak GiB |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} ({r['memory_upper_s']:.1e}) | "
            f"{r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.1%} | {r['peak_gib']:.1f} |")
    table = "\n".join(lines)
    print(table)
    notes = ["", "Per-cell bottleneck notes:"]
    for r in rows:
        notes.append(f"- {r['arch']} x {r['shape']}: {suggestion(r)}")
    out = table + "\n" + "\n".join(notes) + "\n"
    if args.md:
        os.makedirs(os.path.dirname(args.md), exist_ok=True)
        with open(args.md, "w") as fh:
            fh.write(out)
        print(f"\nwritten to {args.md}")


if __name__ == "__main__":
    main()
