"""Production training entry point.

    python -m repro.launch.train --arch olmo-1b [--reduced] --steps 100 \
        --ckpt-dir /tmp/ckpt [--devices 8 --mesh 4x2]

Wires together: config registry -> model zoo -> FSDPxTP shardings -> data
pipeline -> grad-accumulation train step -> resilient loop (async sharded
checkpoints, restore-on-restart, straggler monitor).  On the CPU container
use ``--reduced`` (full configs need the real fleet); on hardware, drop it
and point --mesh at the pod slice.
"""

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--devices", type=int, default=0,
                    help="host-device override (set BEFORE jax init)")
    ap.add_argument("--mesh", default="", help="e.g. 4x2 = data x model")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint.checkpointer import Checkpointer
    from repro.configs.registry import get_arch
    from repro.data.pipeline import TokenPipeline, make_global_batch
    from repro.models import pspec
    from repro.models.model_zoo import build_model
    from repro.optim.adamw import AdamWConfig
    from repro.train.sharding import (make_batch_shardings,
                                      make_param_shardings)
    from repro.train.step import init_train_state, make_train_step

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)

    mesh = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        names = ("data", "model")[-len(shape):]
        mesh = jax.make_mesh(shape, names)
        pspec.set_mesh(mesh)

    state = init_train_state(model, jax.random.PRNGKey(0))
    opt = AdamWConfig(peak_lr=args.lr, warmup_steps=min(100, args.steps // 10),
                      decay_steps=args.steps)
    step_fn = make_train_step(model, opt)

    pipe = TokenPipeline(vocab=cfg.vocab_size, seq_len=args.seq_len,
                         global_batch=args.global_batch,
                         microbatches=args.microbatches)

    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ck and ck.latest_step() is not None:
        state = ck.restore(ck.latest_step(), state)
        print(f"restored from step {int(state.step)}")

    if mesh is not None:
        psh = make_param_shardings(mesh, state.params)
        ssh = type(state)(
            params=psh,
            opt=type(state.opt)(m=make_param_shardings(mesh, state.opt.m),
                                v=make_param_shardings(mesh, state.opt.v),
                                count=NamedSharding(mesh, P())),
            step=NamedSharding(mesh, P()))
        bsh = make_batch_shardings(
            mesh, jax.eval_shape(lambda: jax.tree.map(
                jnp.asarray, pipe.next_host_batch())),
            args.global_batch, batch_axis=1)
        with mesh:
            step_fn = jax.jit(step_fn, in_shardings=(ssh, bsh),
                              donate_argnums=(0,))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    start = int(state.step)
    for s in range(start, args.steps):
        host = pipe.next_host_batch()
        if mesh is not None:
            batch = make_global_batch(mesh, host, bsh)
        else:
            batch = jax.tree.map(jnp.asarray, host)
        state, m = step_fn(state, batch)
        if (s + 1) % args.log_every == 0 or s == start:
            print(f"step {s+1:5d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  "
                  f"lr {float(m['lr']):.2e}", flush=True)
        if ck and (s + 1) % args.ckpt_every == 0:
            ck.save_async(s + 1, state)
    if ck:
        ck.wait()
        ck.save(args.steps, state)
    print("done.")


if __name__ == "__main__":
    main()
