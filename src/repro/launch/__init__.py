"""launch subpackage of the DSLOT-NN reproduction."""
