"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without any real hardware:
  * the sharding config is coherent (GSPMD partitions the whole step),
  * the per-device memory fits a TPU v5e (``compiled.memory_analysis()``),
  * and it extracts the roofline inputs (``cost_analysis`` FLOPs/bytes +
    collective bytes parsed from the optimized HLO).

Usage:
    python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k \
        [--multi-pod] [--out experiments/dryrun]
    python -m repro.launch.dryrun --all [--multi-pod]   # every live cell

Results are appended as JSON, one file per cell, so a driver can run cells in
separate processes (fresh XLA heap each) and accumulate.
"""

# The 512 placeholder devices MUST be configured before jax initializes —
# these two lines are deliberately the first executable statements.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P   # noqa: E402

from repro.configs.base import ModelConfig, ShapeConfig      # noqa: E402
from repro.configs.registry import (ARCHS, cell_is_live, get_arch,  # noqa: E402
                                    get_shape, live_cells)
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.models.model_zoo import build_model                # noqa: E402
from repro.optim.adamw import AdamWConfig, init_opt_state     # noqa: E402
from repro.train.sharding import (make_batch_shardings,       # noqa: E402
                                  make_param_shardings, mesh_axes)
from repro.train.step import TrainState, make_train_step      # noqa: E402

# ----------------------------------------------------------------- specs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def microbatches_for(arch: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Grad-accumulation depth: per-device microbatch of ~1 sample for the
    big models bounds saved activations (DESIGN.md §5)."""
    if shape.kind != "train":
        return 1
    fsdp, _ = mesh_axes(mesh)
    n = 1
    for a in fsdp:
        n *= mesh.shape[a]
    return max(1, min(shape.global_batch // n, shape.microbatches * 2))


def input_specs(arch: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    S, B = shape.seq_len, shape.global_batch
    F = arch.frontend_len if arch.frontend else 0
    enc_len = arch.frontend_len if arch.family == "encdec" else 0
    d = jnp.bfloat16 if arch.dtype == "bfloat16" else jnp.float32

    if shape.kind == "train":
        M = microbatches_for(arch, shape, mesh)
        mb = B // M
        batch = {"tokens": _sds((M, mb, S - F), jnp.int32),
                 "labels": _sds((M, mb, S - F), jnp.int32)}
        if arch.frontend:
            batch["frontend"] = _sds((M, mb, F, arch.d_model), d)
        if arch.family == "encdec":
            batch["src_embeds"] = _sds((M, mb, enc_len, arch.d_model), d)
        return batch

    if shape.kind == "prefill":
        batch = {"tokens": _sds((B, S - F), jnp.int32)}
        if arch.frontend:
            batch["frontend"] = _sds((B, F, arch.d_model), d)
        if arch.family == "encdec":
            batch["src_embeds"] = _sds((B, enc_len, arch.d_model), d)
        return batch

    # decode: one new token against a seq_len-deep cache
    return {"tokens": _sds((B, 1), jnp.int32)}


# ----------------------------------------------------------- cache sharding

def decode_state_shardings(mesh, state_shapes):
    """KV caches shard: batch over (pod,data) when divisible, cache sequence
    axis over "model" (context parallelism); recurrent states shard their
    feature axis over "model"."""
    fsdp, tp = mesh_axes(mesh)
    n_fsdp = 1
    for a in fsdp:
        n_fsdp *= mesh.shape[a]

    tp_n = mesh.shape[tp] if tp else 1

    def one(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        field = pstr.rsplit("/", 1)[-1].lstrip(".")
        nd = leaf.ndim
        if field == "positions" or nd == 0:
            return NamedSharding(mesh, P())

        def spec_for(core: tuple) -> P:
            """Right-align a core spec; leading scan-stack dims replicate,
            and every axis is divisibility-checked on its dimension."""
            lead = nd - len(core)
            if lead < 0:
                core = core[-nd:]
                lead = 0
            full = (None,) * lead + core
            out = []
            for i, a in enumerate(full):
                if a is None:
                    out.append(None)
                    continue
                n = n_fsdp if a == fsdp else tp_n
                out.append(a if leaf.shape[i] % n == 0 else None)
            return P(*out)

        b = fsdp if fsdp else None
        if field in ("k", "v"):          # KV cache (B, C, Hkv, hd)
            # context parallelism: cache sequence axis over "model"
            return NamedSharding(mesh, spec_for((b, tp, None, None)))
        if field == "ssm":               # (B, H, P, N) — heads over model
            return NamedSharding(mesh, spec_for((b, tp, None, None)))
        if field == "conv":              # (B, k-1, C) — channels over model
            return NamedSharding(mesh, spec_for((b, None, tp)))
        if field == "h":                 # rglru state (B, W)
            return NamedSharding(mesh, spec_for((b, tp)))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, state_shapes)


# ----------------------------------------------------------- HLO parsing

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for x in dims.split(","):
            if x:
                n *= int(x)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective kind (output-shape sizes)."""
    out: dict[str, int] = {"all-reduce": 0, "all-gather": 0,
                           "reduce-scatter": 0, "all-to-all": 0,
                           "collective-permute": 0}
    counts: dict[str, int] = {k: 0 for k in out}
    for m in _COLL_RE.finditer(hlo_text):
        shape_text, kind = m.group(2), m.group(3)
        out[kind] += _shape_bytes(shape_text)
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


# ----------------------------------------------------------- lowering


def lower_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
               opt_overrides: dict | None = None):
    """Lower one cell; returns (lowered, mesh, meta)."""
    arch = get_arch(arch_name)
    if opt_overrides:
        import dataclasses
        arch = dataclasses.replace(arch, **opt_overrides)
    shape = get_shape(shape_name)
    ok, why = cell_is_live(arch, shape)
    if not ok:
        raise SystemExit(f"cell skipped by assignment rule: {why}")

    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.models import pspec
    pspec.set_mesh(mesh)
    model = build_model(arch)
    key = jax.random.PRNGKey(0)

    params_shapes = jax.eval_shape(model.init, key)
    param_sh = make_param_shardings(mesh, params_shapes)
    batch = input_specs(arch, shape, mesh)
    meta = {"arch": arch_name, "shape": shape_name,
            "multi_pod": multi_pod, "mesh": dict(mesh.shape)}

    with mesh:
        if shape.kind == "train":
            opt_shapes = jax.eval_shape(init_opt_state, params_shapes)
            state_shapes = TrainState(params=params_shapes, opt=opt_shapes,
                                      step=_sds((), jnp.int32))
            state_sh = TrainState(
                params=param_sh,
                opt=type(opt_shapes)(
                    m=make_param_shardings(mesh, opt_shapes.m),
                    v=make_param_shardings(mesh, opt_shapes.v),
                    count=NamedSharding(mesh, P())),
                step=NamedSharding(mesh, P()))
            batch_sh = make_batch_shardings(mesh, batch, shape.global_batch,
                                            batch_axis=1)
            step_fn = make_train_step(model, AdamWConfig())
            meta["microbatches"] = jax.tree.leaves(batch)[0].shape[0]
            lowered = jax.jit(
                step_fn, in_shardings=(state_sh, batch_sh),
                donate_argnums=(0,)).lower(state_shapes, batch)
        elif shape.kind == "prefill":
            batch_sh = make_batch_shardings(mesh, batch, shape.global_batch)

            def prefill_fn(params, b):
                return model.prefill(params, b, max_len=shape.seq_len)

            # pin the output cache layout (context-parallel: sequence axis
            # over "model") — default GSPMD output shardings can come back
            # badly laid out (multi-GiB replication observed)
            out_shapes = jax.eval_shape(prefill_fn, params_shapes, batch)
            out_sh = (make_batch_shardings(mesh, out_shapes[0],
                                           shape.global_batch),
                      decode_state_shardings(mesh, out_shapes[1]))
            lowered = jax.jit(
                prefill_fn, in_shardings=(param_sh, batch_sh),
                out_shardings=out_sh,
            ).lower(params_shapes, batch)
        else:  # decode
            enc_len = arch.frontend_len if arch.family == "encdec" else 0
            state_shapes = jax.eval_shape(
                lambda: model.init_decode_state(shape.global_batch,
                                                shape.seq_len, enc_len))
            state_sh = decode_state_shardings(mesh, state_shapes)
            batch_sh = make_batch_shardings(mesh, batch, shape.global_batch)

            def decode_fn(params, st, tokens):
                return model.decode_step(params, st, tokens)

            out_shapes = jax.eval_shape(decode_fn, params_shapes,
                                        state_shapes, batch["tokens"])
            out_sh = (make_batch_shardings(mesh, out_shapes[0],
                                           shape.global_batch),
                      decode_state_shardings(mesh, out_shapes[1]))
            lowered = jax.jit(
                decode_fn,
                in_shardings=(param_sh, state_sh, batch_sh["tokens"]),
                out_shardings=out_sh,
                donate_argnums=(1,),
            ).lower(params_shapes, state_shapes, batch["tokens"])
    return lowered, mesh, meta


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             out_dir: str, opt_overrides: dict | None = None,
             tag: str = "") -> dict:
    t0 = time.time()
    lowered, mesh, meta = lower_cell(arch_name, shape_name,
                                     multi_pod=multi_pod,
                                     opt_overrides=opt_overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem[k] = int(getattr(ma, k, 0) or 0)
    except Exception as e:                      # pragma: no cover
        mem["error"] = str(e)

    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        for k, v in ca.items():
            if k in ("flops", "bytes accessed", "transcendentals",
                     "optimal_seconds") or k.startswith("bytes accessed"):
                cost[k] = float(v)
    except Exception as e:                      # pragma: no cover
        cost["error"] = str(e)

    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)          # raw, once-per-program view
    from repro.launch.hlo_cost import analyze_hlo
    corrected = analyze_hlo(hlo_text)          # trip-count-corrected totals

    rec = {**meta, "tag": tag, "lower_s": round(t_lower, 2),
           "compile_s": round(t_compile, 2), "memory": mem, "cost": cost,
           "collectives": coll, "corrected": corrected}
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch_name}__{shape_name}__{'multi' if multi_pod else 'single'}"
    if tag:
        fname += f"__{tag}"
    with open(os.path.join(out_dir, fname + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = live_cells() if args.all else [(args.arch, args.shape)]
    for arch_name, shape_name in cells:
        try:
            rec = run_cell(arch_name, shape_name, multi_pod=args.multi_pod,
                           out_dir=args.out)
            print(f"OK  {arch_name} {shape_name} multi_pod={args.multi_pod} "
                  f"compile={rec['compile_s']}s "
                  f"flops={rec['cost'].get('flops', '?'):.3e} "
                  f"coll={rec['collectives']['total_bytes']/2**20:.1f}MiB")
            print("  memory:", rec["memory"])
        except SystemExit as e:
            print(f"SKIP {arch_name} {shape_name}: {e}")
        except Exception:
            print(f"FAIL {arch_name} {shape_name}")
            traceback.print_exc()


if __name__ == "__main__":
    main()
