"""Model-facing DSLOT layers: the one API every network uses to run a layer
on the digit-plane engine (quantize -> MSDF planes -> kernel -> dequantize,
with per-layer early-termination statistics)."""

from .dslot import DslotConv2d, DslotDense, DslotLayerStats

__all__ = ["DslotConv2d", "DslotDense", "DslotLayerStats"]
