"""Unified DSLOT layer API: ``DslotDense`` and ``DslotConv2d``.

Every model-facing use of the digit-plane engine goes through these two
layers.  A layer owns the full lowering pipeline — quantize activations,
encode MSDF digit planes, invoke the kernel (Pallas with per-tile early
termination when ``use_pallas``, the chunk-aware jnp replay otherwise),
dequantize — and surfaces per-call ``planes_used`` statistics both as a
return value and through the ``repro.models.stats`` side channel (key
``{name}.skipped_frac`` / ``{name}.planes_used_mean``), so serving and
benchmark entry points can report the paper's energy-saving proxy per layer.

Layers are frozen dataclasses (configuration only); parameters are plain
dicts of jnp arrays like the rest of the model stack (``models/layers.py``).
``DslotConv2d`` lowers convolution through ``core.conv.im2col`` so the conv
SOPs hit exactly the same kernel datapath as dense layers — the DSLR-CNN
extension of the paper's PE array to full CNN layers, at tile granularity.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.conv import im2col
from repro.kernels.ops import DslotStats, dslot_matmul
from repro.models import stats as stats_channel

__all__ = ["DslotDense", "DslotConv2d", "DslotLayerStats"]


class DslotLayerStats(NamedTuple):
    name: str
    planes_used: jax.Array       # (Mt, Nt) int32 — digit planes per tile
    n_planes: int
    skipped_frac: jax.Array      # scalar f32 — fraction of planes skipped

    @classmethod
    def of(cls, name: str, st: DslotStats) -> "DslotLayerStats":
        return cls(name=name, planes_used=st.planes_used,
                   n_planes=st.n_planes, skipped_frac=st.skipped_frac)


def _record(name: str, st: DslotStats) -> None:
    stats_channel.record(f"{name}.skipped_frac", st.skipped_frac)
    stats_channel.record(f"{name}.planes_used_mean",
                         jnp.mean(st.planes_used.astype(jnp.float32)))


@dataclasses.dataclass(frozen=True)
class DslotDense:
    """Dense layer executed on the digit-plane DSLOT engine.

    ``relu=True`` fuses the activation into the kernel and enables per-tile
    early termination (the paper's Algorithm 1); ``relu=False`` (e.g. a
    logits head) runs all planes.  ``use_pallas`` selects the Pallas kernel
    (interpret mode off-TPU) over the vectorized jnp replay — identical
    semantics and identical ``planes_used``, different execution.
    """
    d_in: int
    d_out: int
    name: str = "dslot_dense"
    n_bits: int = 8
    n_planes: int | None = None      # runtime precision knob (<= n_bits)
    relu: bool = True
    signed: bool = False             # activation quantization range
    sort_columns: bool = False
    block_m: int = 128
    block_n: int = 128
    block_k: int | None = None       # None = auto VMEM-budget selection
    use_pallas: bool = False

    def init(self, key, dtype=jnp.float32) -> dict:
        w = jax.random.normal(key, (self.d_in, self.d_out),
                              jnp.float32) * self.d_in ** -0.5
        return {"w": w.astype(dtype)}

    def apply(self, params: dict, x: jax.Array
              ) -> tuple[jax.Array, DslotLayerStats]:
        """x: (..., d_in) -> (..., d_out), plus per-tile plane statistics."""
        lead = x.shape[:-1]
        flat = x.reshape(-1, self.d_in).astype(jnp.float32)
        y, st = dslot_matmul(
            flat, params["w"].astype(jnp.float32),
            n_bits=self.n_bits, n_planes=self.n_planes, relu=self.relu,
            block_m=self.block_m, block_n=self.block_n, block_k=self.block_k,
            backend="pallas" if self.use_pallas else "jnp",
            sort_columns=self.sort_columns, signed=self.signed)
        _record(self.name, st)
        return (y.astype(x.dtype).reshape(*lead, self.d_out),
                DslotLayerStats.of(self.name, st))


@dataclasses.dataclass(frozen=True)
class DslotConv2d:
    """2-D convolution lowered to the DSLOT kernel via im2col.

    Input (B, H, W, C), weights (k, k, C, M), valid padding.  The im2col
    matrix (B*Ho*Wo, k*k*C) streams through the digit-plane matmul, so a
    "tile" is a block of spatial output positions x output channels — the
    tile-granular analogue of the paper's four-PE pooling group, and early
    termination kills provably-ReLU-dead spatial regions per channel block.
    """
    in_channels: int
    out_channels: int
    kernel_size: int
    stride: int = 1
    name: str = "dslot_conv2d"
    n_bits: int = 8
    n_planes: int | None = None
    relu: bool = True
    signed: bool = False
    sort_columns: bool = False
    block_m: int = 128
    block_n: int = 128
    block_k: int | None = None
    use_pallas: bool = False

    def init(self, key, dtype=jnp.float32) -> dict:
        k, c, m = self.kernel_size, self.in_channels, self.out_channels
        fan_in = k * k * c
        w = jax.random.normal(key, (k, k, c, m), jnp.float32) * fan_in ** -0.5
        return {"w": w.astype(dtype)}

    def apply(self, params: dict, x: jax.Array
              ) -> tuple[jax.Array, DslotLayerStats]:
        """x: (B, H, W, C) -> (B, Ho, Wo, M), plus plane statistics."""
        B = x.shape[0]
        k, c, m = self.kernel_size, self.in_channels, self.out_channels
        assert x.shape[-1] == c, (x.shape, c)
        cols = im2col(x.astype(jnp.float32), k, self.stride)
        _, Ho, Wo, kkc = cols.shape
        y, st = dslot_matmul(
            cols.reshape(B * Ho * Wo, kkc),
            params["w"].astype(jnp.float32).reshape(kkc, m),
            n_bits=self.n_bits, n_planes=self.n_planes, relu=self.relu,
            block_m=self.block_m, block_n=self.block_n, block_k=self.block_k,
            backend="pallas" if self.use_pallas else "jnp",
            sort_columns=self.sort_columns, signed=self.signed)
        _record(self.name, st)
        return (y.astype(x.dtype).reshape(B, Ho, Wo, m),
                DslotLayerStats.of(self.name, st))
