"""Unified DSLOT layer API: ``DslotDense`` and ``DslotConv2d``.

Every model-facing use of the digit-plane engine goes through these two
layers, now built on the **prepare/execute split** (``kernels.ops``):

* ``init`` returns params WITH prepared state — the weight lowering
  (column sort, padding, block geometry, termination tables) runs exactly
  once per layer per model lifetime;
* ``prepare(params)`` attaches/refreshes the prepared state for externally
  trained weights;
* ``calibrate(params, x_sample)`` stores a fixed activation-quantization
  scale in the prepared state, removing the data-dependent ``jnp.max`` from
  the per-request hot path;
* ``apply(params, x, n_planes=...)`` executes at a RUNTIME precision — an
  explicit argument, a value from the active ``repro.runtime``
  precision scope (policy-supplied, possibly a per-row jax array), or the
  layer's static default, in that order.  Changing precision never
  re-prepares weights and never retraces.  Per-row budgets are consumed
  INSIDE the kernel (SMEM budget vector) and digit planes are derived
  in-kernel from the quantized activations — no plane tensor, no
  row-masking pass outside the kernel (see ``kernels/ops.py``).

Per-call statistics (``planes_used``, ``skipped_frac``, per-row effective
planes, weight-side ``planes_bounded``) surface both as return values and
through the ``repro.models.stats`` side channel (keys
``{name}.skipped_frac`` / ``{name}.planes_used_mean`` /
``{name}.row_planes_used`` / ``{name}.planes_bounded_mean``), so serving
and benchmark entry points can report the paper's energy-saving proxy per
layer and per request.

``DslotConv2d`` lowers convolution through ``core.conv.im2col`` (valid or
same padding) so conv SOPs hit exactly the same kernel datapath as dense
layers — the DSLR-CNN extension of the paper's PE array, at tile
granularity.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.conv import im2col
from repro.kernels.ops import (DslotStats, DslotWeights, calibrate_scale,
                               dslot_execute, dslot_prepare)
from repro.models import stats as stats_channel
from repro.runtime import current_precision

__all__ = ["DslotDense", "DslotConv2d", "DslotLayerStats"]


class DslotLayerStats(NamedTuple):
    name: str
    planes_used: jax.Array       # (Mt, Nt) int32 — digit planes per tile
    n_planes: int
    skipped_frac: jax.Array      # scalar f32 — fraction of planes skipped
    row_planes_used: jax.Array | None = None  # (rows,) f32 effective planes
    planes_bounded: jax.Array | None = None  # (Mt, Nt) int32 — planes never
                                 # issued: static weight-side MSR bound

    @classmethod
    def of(cls, name: str, st: DslotStats) -> "DslotLayerStats":
        return cls(name=name, planes_used=st.planes_used,
                   n_planes=st.n_planes, skipped_frac=st.skipped_frac,
                   row_planes_used=st.row_planes_used,
                   planes_bounded=st.planes_bounded)


def _record(name: str, st: DslotStats) -> None:
    stats_channel.record(f"{name}.skipped_frac", st.skipped_frac)
    stats_channel.record(f"{name}.planes_used_mean",
                         jnp.mean(st.planes_used.astype(jnp.float32)))
    if st.row_planes_used is not None:
        stats_channel.record(f"{name}.row_planes_used", st.row_planes_used)
    if st.planes_bounded is not None:
        stats_channel.record(f"{name}.planes_bounded_mean",
                             jnp.mean(st.planes_bounded.astype(jnp.float32)))


def _resolve_precision(name: str, explicit, static_default):
    """explicit arg > active runtime precision scope > layer static field."""
    if explicit is not None:
        return explicit
    scoped = current_precision(name, None)
    if scoped is not None:
        return scoped
    return static_default


def _rows_precision(n_planes, lead: tuple, rows: int):
    """Broadcast a per-request (B,) budget to the (B*S,) flattened rows."""
    if n_planes is None or not hasattr(n_planes, "ndim"):
        return n_planes
    n_planes = jnp.asarray(n_planes)
    if n_planes.ndim == 1 and lead and n_planes.shape[0] != rows \
            and rows % n_planes.shape[0] == 0:
        n_planes = jnp.repeat(n_planes, rows // n_planes.shape[0])
    return n_planes


@dataclasses.dataclass(frozen=True)
class DslotDense:
    """Dense layer executed on the digit-plane DSLOT engine.

    ``relu=True`` fuses the activation into the kernel and enables per-tile
    early termination (the paper's Algorithm 1); ``relu=False`` (e.g. a
    logits head) runs all planes.  ``use_pallas`` selects the Pallas kernel
    (interpret mode off-TPU) over the vectorized jnp replay — identical
    semantics and identical ``planes_used``, different execution.
    """
    d_in: int
    d_out: int
    name: str = "dslot_dense"
    n_bits: int = 8
    n_planes: int | None = None      # default precision (<= n_bits)
    relu: bool = True
    signed: bool = False             # activation quantization range
    sort_columns: bool = False
    block_m: int = 128
    block_n: int = 128
    block_k: int | None = None       # None = auto VMEM-budget selection
    use_pallas: bool = False
    mesh: object | None = None       # tensor-parallel mesh (N-axis shards)
    tp_axis: str = "model"

    # ------------------------------------------------------------ lifecycle

    def init(self, key, dtype=jnp.float32) -> dict:
        w = jax.random.normal(key, (self.d_in, self.d_out),
                              jnp.float32) * self.d_in ** -0.5
        return self.prepare({"w": w.astype(dtype)})

    def prepare(self, params: dict) -> dict:
        """Attach the one-time prepared state (weight-stationary lowering)."""
        prepared = dslot_prepare(
            params["w"].astype(jnp.float32), n_bits=self.n_bits,
            relu=self.relu, signed=self.signed,
            sort_columns=self.sort_columns, block_m=self.block_m,
            block_n=self.block_n, block_k=self.block_k,
            backend="pallas" if self.use_pallas else "jnp",
            mesh=self.mesh, tp_axis=self.tp_axis)
        return {**params, "dslot": prepared}

    def calibrate(self, params: dict, x_sample: jax.Array) -> dict:
        """Store a fixed activation scale from a calibration batch."""
        prep: DslotWeights = params.get("dslot") or \
            self.prepare(params)["dslot"]
        scale = calibrate_scale(x_sample.reshape(-1, self.d_in),
                                n_bits=self.n_bits, signed=self.signed)
        return {**params, "dslot": prep.with_scale(scale)}

    # ------------------------------------------------------------ execution

    def apply(self, params: dict, x: jax.Array, *, n_planes=None
              ) -> tuple[jax.Array, DslotLayerStats]:
        """x: (..., d_in) -> (..., d_out), plus per-tile plane statistics.

        ``n_planes``: runtime precision — int, i32 scalar, or per-request
        (B,) vector (broadcast over the sequence axis); defaults to the
        active precision scope, then the layer's static field.
        """
        lead = x.shape[:-1]
        flat = x.reshape(-1, self.d_in).astype(jnp.float32)
        prep = params.get("dslot")
        if prep is None:                      # unprepared (legacy) params:
            prep = self.prepare(params)["dslot"]   # trace-time fallback
        npl = _resolve_precision(self.name, n_planes, self.n_planes)
        npl = _rows_precision(npl, lead, flat.shape[0])
        y, st = dslot_execute(prep, flat, n_planes=npl)
        _record(self.name, st)
        return (y.astype(x.dtype).reshape(*lead, self.d_out),
                DslotLayerStats.of(self.name, st))


@dataclasses.dataclass(frozen=True)
class DslotConv2d:
    """2-D convolution lowered to the DSLOT kernel via im2col.

    Input (B, H, W, C), weights (k, k, C, M), valid or same padding.  The
    im2col matrix (B*Ho*Wo, k*k*C) streams through the digit-plane matmul,
    so a "tile" is a block of spatial output positions x output channels —
    the tile-granular analogue of the paper's four-PE pooling group, and
    early termination kills provably-ReLU-dead spatial regions per channel
    block.
    """
    in_channels: int
    out_channels: int
    kernel_size: int
    stride: int = 1
    padding: str = "valid"           # "valid" | "same"
    name: str = "dslot_conv2d"
    n_bits: int = 8
    n_planes: int | None = None
    relu: bool = True
    signed: bool = False
    sort_columns: bool = False
    block_m: int = 128
    block_n: int = 128
    block_k: int | None = None
    use_pallas: bool = False
    mesh: object | None = None       # tensor-parallel mesh (N-axis shards)
    tp_axis: str = "model"

    # ------------------------------------------------------------ lifecycle

    def init(self, key, dtype=jnp.float32) -> dict:
        k, c, m = self.kernel_size, self.in_channels, self.out_channels
        fan_in = k * k * c
        w = jax.random.normal(key, (k, k, c, m), jnp.float32) * fan_in ** -0.5
        return self.prepare({"w": w.astype(dtype)})

    def _kkc(self) -> int:
        return self.kernel_size ** 2 * self.in_channels

    def prepare(self, params: dict) -> dict:
        prepared = dslot_prepare(
            params["w"].astype(jnp.float32).reshape(self._kkc(),
                                                    self.out_channels),
            n_bits=self.n_bits, relu=self.relu, signed=self.signed,
            sort_columns=self.sort_columns, block_m=self.block_m,
            block_n=self.block_n, block_k=self.block_k,
            backend="pallas" if self.use_pallas else "jnp",
            mesh=self.mesh, tp_axis=self.tp_axis)
        return {**params, "dslot": prepared}

    def calibrate(self, params: dict, x_sample: jax.Array) -> dict:
        """Calibrate on sample feature maps (B, H, W, C)."""
        prep: DslotWeights = params.get("dslot") or \
            self.prepare(params)["dslot"]
        cols = im2col(x_sample.astype(jnp.float32), self.kernel_size,
                      self.stride, self.padding)
        scale = calibrate_scale(cols, n_bits=self.n_bits, signed=self.signed)
        return {**params, "dslot": prep.with_scale(scale)}

    # ------------------------------------------------------------ execution

    def apply(self, params: dict, x: jax.Array, *, n_planes=None
              ) -> tuple[jax.Array, DslotLayerStats]:
        """x: (B, H, W, C) -> (B, Ho, Wo, M), plus plane statistics.

        A per-request (B,) ``n_planes`` vector is broadcast over each
        image's Ho*Wo output rows.
        """
        B = x.shape[0]
        k, c, m = self.kernel_size, self.in_channels, self.out_channels
        assert x.shape[-1] == c, (x.shape, c)
        cols = im2col(x.astype(jnp.float32), k, self.stride, self.padding)
        _, Ho, Wo, kkc = cols.shape
        prep = params.get("dslot")
        if prep is None:
            prep = self.prepare(params)["dslot"]
        npl = _resolve_precision(self.name, n_planes, self.n_planes)
        npl = _rows_precision(npl, (B,), B * Ho * Wo)
        y, st = dslot_execute(prep, cols.reshape(B * Ho * Wo, kkc),
                              n_planes=npl)
        _record(self.name, st)
        return (y.astype(x.dtype).reshape(B, Ho, Wo, m),
                DslotLayerStats.of(self.name, st))
