"""optim subpackage of the DSLOT-NN reproduction."""
