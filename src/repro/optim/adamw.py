"""AdamW with decoupled weight decay, global-norm clipping and a
warmup+cosine schedule — pure JAX, optimizer state inherits parameter
shardings (ZeRO: m/v are sharded exactly like their parameters).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    m: dict
    v: dict
    count: jax.Array


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=zeros,
                    v=jax.tree.map(jnp.zeros_like, zeros),
                    count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt: OptState, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_opt, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    count = opt.count + 1
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         opt.m, grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         opt.v, grads)

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:     # no decay on norms/biases
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, OptState(m=new_m, v=new_v, count=count), \
        {"grad_norm": gnorm, "lr": lr}
