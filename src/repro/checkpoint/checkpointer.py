"""Sharded, async, integrity-checked checkpointing with elastic restore.

Layout (one directory per step):
    step_000123/
      manifest.json     — pytree structure, shapes, dtypes, crc32 per leaf
      shard_<i>.npz     — leaf arrays (one file per save worker)
      _COMMITTED        — written last; a directory without it is ignored

Properties needed at 1000-node scale, reproduced faithfully in-process:
* **atomicity** — writes go to ``<dir>.tmp`` and are renamed after the commit
  marker; a crash mid-save never corrupts the latest checkpoint.
* **async** — ``save_async`` snapshots to host memory (device_get) and writes
  on a background thread; training continues immediately.
* **integrity** — crc32 per leaf, verified on restore.
* **elastic restore** — ``restore`` takes an optional (mesh, shardings):
  arrays are re-laid-out onto the *target* mesh, which may differ from the
  mesh that saved them (node loss -> smaller mesh; scale-up -> larger).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any

import numpy as np

import jax

# dtypes numpy's npz handles natively; anything else (ml_dtypes' bfloat16,
# float8s) is stored as a same-width unsigned-int bit pattern.
_NATIVE_DTYPES = {str(np.dtype(t)) for t in
                  ("f2", "f4", "f8", "i1", "i2", "i4", "i8",
                   "u1", "u2", "u4", "u8", "b1", "c8", "c16")}
_BITS_VIEW = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _flatten(tree) -> list[tuple[str, Any]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out.append((key, leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save

    def save(self, step: int, tree) -> str:
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        return self._write(step, host)

    def save_async(self, step: int, tree) -> None:
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> str:
        name = f"step_{step:08d}"
        final = os.path.join(self.dir, name)
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)

        flat = _flatten(host_tree)
        manifest = {"step": step, "leaves": {}}
        arrays = {}
        for i, (key, arr) in enumerate(flat):
            arr = np.asarray(arr)
            dtype_name = str(arr.dtype)
            stored = arr
            if dtype_name not in _NATIVE_DTYPES:
                # ml_dtypes (bfloat16, float8s) -> bit-pattern view for npz
                stored = arr.view(_BITS_VIEW[arr.dtype.itemsize])
            arrays[f"a{i}"] = stored
            manifest["leaves"][key] = {
                "idx": i, "shape": list(arr.shape), "dtype": dtype_name,
                "crc32": zlib.crc32(np.ascontiguousarray(stored).tobytes()),
            }
        np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
            f.write("ok")
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for d in sorted(os.listdir(self.dir)):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, d, "_COMMITTED")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """Restore into the structure of ``target_tree``.  ``shardings``
        (same pytree of NamedShardings) re-lays leaves onto the target mesh —
        elastic restart across different mesh shapes."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "shard_0.npz"))

        by_key = {}
        for key, meta in manifest["leaves"].items():
            arr = data[f"a{meta['idx']}"]
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise IOError(f"checkpoint corruption at leaf {key}")
            if meta["dtype"] not in _NATIVE_DTYPES:
                import ml_dtypes
                arr = arr.view(np.dtype(getattr(ml_dtypes, meta["dtype"])))
            by_key[key] = arr

        flat_target = _flatten(target_tree)
        flat_shard = _flatten(shardings)[:len(flat_target)] if shardings \
            is not None else None
        restored = []
        for i, (key, tgt) in enumerate(flat_target):
            if key not in by_key:
                raise KeyError(f"missing leaf {key} in checkpoint")
            arr = by_key[key].astype(np.dtype(tgt.dtype))
            if flat_shard is not None:
                sh = flat_shard[i][1]
                restored.append(jax.make_array_from_callback(
                    arr.shape, sh, lambda idx, a=arr: a[idx]))
            else:
                restored.append(jax.numpy.asarray(arr))
        treedef = jax.tree_util.tree_structure(target_tree)
        return jax.tree_util.tree_unflatten(treedef, restored)
