"""checkpoint subpackage of the DSLOT-NN reproduction."""
