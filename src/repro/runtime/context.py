"""Precision context: thread a runtime precision into nested DSLOT layers.

Model code (MLP blocks, CNN layers) is called through jitted entry points
whose signatures don't carry a precision argument.  Instead, the caller opens
``precision_scope(n_planes)`` around the traced call and layers ask
``current_precision(name, default)`` at trace time — the value (a python int,
a ``{layer_name: planes}`` dict, or a traced jax array such as a per-slot
budget vector) flows into the trace like any other closed-over input.

Inside ``jax.jit`` this works exactly like ``repro.models.stats``: the scope
must be entered *inside* the traced function (or around a fresh trace) so the
layers see it while tracing.
"""

from __future__ import annotations

import contextlib
from typing import Any, Iterator

_ACTIVE: list[Any] = []


@contextlib.contextmanager
def precision_scope(n_planes: Any) -> Iterator[None]:
    """Make ``n_planes`` the active runtime precision for DSLOT layers.

    ``n_planes``: int | jax i32 array (scalar or per-row) | dict mapping
    layer names to either.  ``None`` entries fall through to the layer
    default.  (The argument is named ``n_planes`` everywhere precision
    crosses an API boundary — ``generate``, ``Request``, kernels.)
    """
    _ACTIVE.append(n_planes)
    try:
        yield
    finally:
        _ACTIVE.pop()


def current_precision(name: str, default: Any = None) -> Any:
    """Precision for layer ``name`` from the innermost active scope."""
    if not _ACTIVE:
        return default
    value = _ACTIVE[-1]
    if isinstance(value, dict):
        value = value.get(name, value.get("*", None))
    return default if value is None else value
