"""Precision policies: who decides how many digit planes a request runs.

A policy is consulted by the serving engine at ENQUEUE time
(``ServeEngine.try_add`` calls ``next_precision`` once the request has
joined the admission queue — a queue-full rejection consumes no grant, so a
retry gets a fresh one; the granted budget then applies to the request's
prefill chunks and every pooled decode step) and fed the observed execution
statistics when the request finishes (``observe``).  Three implementations:

* :class:`Fixed` — every request at one precision (the paper's static knob).
* :class:`PerLayerSchedule` — a per-layer plane budget (early CNN layers are
  precision-sensitive, logit heads are not); yields the dict form consumed
  by ``precision_scope``.
* :class:`AdaptiveBudget` — closes the loop on the engine's
  ``planes_used`` / ``skipped_frac`` feedback: keeps an EMA of the effective
  planes actually executed per output and picks the next request's precision
  so that estimated work stays under an average plane budget (the software
  analogue of running the accelerator inside a power envelope).

Policies are plain python state machines — they run OUTSIDE jit, between
engine steps, and only ever hand integers (or dicts of integers) to the
traced side through ``precision_scope``.  See ``docs/serving.md`` for where
they sit in the admission pipeline.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Protocol


@dataclasses.dataclass
class PolicyFeedback:
    """One execution's observed statistics, fed back to the policy.

    The same record feeds the serving layer's SLO controller
    (``repro.serve.slo.SloController.observe``), which tracks per-tier
    planes-used — ``tier`` carries the request's QoS tier there and is
    ``None`` for policy-only (non-engine) callers.
    """
    n_planes: int                   # precision the request ran at
    planes_used_mean: float         # effective planes per output row
    skipped_frac: float             # fraction of plane budget skipped
    tier: str | None = None         # QoS tier (serving engine fills this)


class PrecisionPolicy(Protocol):
    def next_precision(self) -> Any:
        """Precision for the next admitted request: int or per-layer dict."""
        ...

    def observe(self, fb: PolicyFeedback) -> None:
        """Feed back observed statistics (no-op for static policies)."""
        ...


@dataclasses.dataclass
class Fixed:
    """Every request at ``n_planes`` digit planes."""
    n_planes: int = 8

    def next_precision(self) -> int:
        return self.n_planes

    def observe(self, fb: PolicyFeedback) -> None:
        pass


@dataclasses.dataclass
class PerLayerSchedule:
    """Static per-layer plane budgets, e.g. ``{"conv1": 8, "dense1": 4}``.

    ``default`` applies to layers not named in the schedule (the ``"*"``
    entry of the precision-scope dict form).
    """
    schedule: dict[str, int]
    default: int | None = None

    def next_precision(self) -> dict[str, int]:
        out = dict(self.schedule)
        if self.default is not None:
            out["*"] = self.default
        return out

    def observe(self, fb: PolicyFeedback) -> None:
        pass


@dataclasses.dataclass
class AdaptiveBudget:
    """Pick each request's precision to hold average executed planes at or
    under ``plane_budget`` (an energy proxy: one plane == one MXU pass per
    tile == one OLM digit cycle in the paper's datapath).

    The engine reports the effective planes per output row it actually
    executed (``planes_used_mean``); early termination means a request run
    at precision D typically costs less than D.  We track an EMA of the
    cost-per-granted-plane ratio and grant the largest precision whose
    predicted cost fits the budget — so workloads with many ReLU-dead
    outputs automatically earn higher precision, and dense workloads are
    throttled, without ever retracing (precision is a runtime argument).
    """
    plane_budget: float = 5.0
    min_planes: int = 2
    max_planes: int = 8
    ema: float = 0.3                 # feedback smoothing
    # cost_ratio: observed executed-planes per granted plane, EMA'd.
    cost_ratio: float = 1.0
    last_feedback: PolicyFeedback | None = None

    def next_precision(self) -> int:
        # largest D with predicted cost D * cost_ratio <= budget
        d = int(self.plane_budget / max(self.cost_ratio, 1e-6))
        return max(self.min_planes, min(self.max_planes, d))

    def observe(self, fb: PolicyFeedback) -> None:
        self.last_feedback = fb
        if fb.n_planes <= 0:
            return
        ratio = fb.planes_used_mean / fb.n_planes
        ratio = min(max(ratio, 0.0), 1.0)
        self.cost_ratio = (1 - self.ema) * self.cost_ratio + self.ema * ratio
