"""Runtime precision-policy subsystem for the DSLOT engine.

The paper's "precision tuned at run-time" becomes a first-class serving
concept here: a :class:`PrecisionPolicy` decides how many digit planes each
request (or each layer) executes, and the engine feeds back the observed
``planes_used`` / ``skipped_frac`` so adaptive policies can close the loop.

``precision_scope`` / ``current_precision`` thread a runtime precision value
(int, per-layer dict, or a traced per-row jax array) into DSLOT layers that
are buried inside jitted model code without changing every call signature —
the same pattern as ``repro.models.stats``.
"""

from .context import current_precision, precision_scope
from .policy import (AdaptiveBudget, Fixed, PerLayerSchedule, PolicyFeedback,
                     PrecisionPolicy)

__all__ = ["AdaptiveBudget", "Fixed", "PerLayerSchedule", "PolicyFeedback",
           "PrecisionPolicy", "current_precision", "precision_scope"]
