"""Pallas TPU kernel: digit-serial MSDF matmul with fused in-kernel digit
encoding and per-tile early termination.

TPU-native adaptation of DSLOT-NN's datapath (DESIGN.md §2/§4.2).  The FPGA
design streams one signed digit per cycle through online multipliers and kills
a SOP the moment its MSDF prefix goes negative.  A TPU has no per-lane early
exit, so the unit of "digit" becomes a *digit plane* (one MXU matmul) and the
unit of termination becomes an *output tile*:

    C = sum_d 2^(n-1-d) * (P_d @ W),      P_d in {-1,0,1}^(M x K), d MSDF

Like the paper's engine — and unlike the first port — the digit planes are
never materialized in HBM.  The kernel input is the quantized activation
block ``q`` itself ((M, K) integer, |q| < 2^n_bits); each grid step derives
plane ``d`` of the resident VMEM chunk arithmetically (sign-magnitude
recoding: bit ``n_bits-1-d`` of |q| times sign(q) — the same digits
``ref.make_planes`` produces, one plane at a time).  That removes the
(D, M, K) plane tensor (an up-to-8x inflation of the activation stream that
had to be written to and re-read from HBM once per plane) and means
predicated-off planes and terminated tiles skip their encode work for free:
a digit that is never consumed is never computed.

Weights stream through VMEM in ``block_k`` chunks (grid axis ``c``), so ``K``
is no longer bounded by what fits in VMEM at once.  After accumulating
(plane d, chunk c) the remaining work can contribute at most

    R[d, c][n] = 2^(n-1-d) * S_c[n]  +  (2^(n-1-d) - 2^(n-npl)) * T[n]

to output column n, where ``S_c`` is the |W| column-sum over the K chunks not
yet seen in the current plane, ``T`` the |W| column-sum over ALL of K, and
``npl`` the runtime precision (digits are bounded by 1 in magnitude; the
second term is the geometric sum of the unseen planes).  ``R`` decreases
monotonically along the (d, c) iteration order, so a tile with
``max_m(acc + R) < 0`` everywhere is *provably* negative under ReLU at the
earliest chunk that observes it: its remaining MXU passes (and digit
extraction) are SKIPPED (predicated with ``pl.when``) and it emits zeros —
the tile-granular Algorithm 1, now chunk-aware.  At the last chunk of a plane
``S_c = 0`` and the bound coincides with the untiled kernel's, so tiling can
only terminate a tile at the same plane or an earlier one.

Runtime precision is two-level: ``n_planes_rt`` (i32 scalar in SMEM)
predicates whole planes off for the entire call, and ``row_budget`` (i32
per-row vector, one ``(block_m,)`` SMEM block per M-tile) zeroes digits
beyond each row's own budget inside the extraction — per-request precision
in a serving batch without masking work outside the kernel.  Both are
runtime values: changing precision never retraces.

Grid/layout: ``grid = (M/bm, N/bn, D, K/bk)`` with the digit-plane and
K-chunk axes innermost (sequential, "arbitrary" semantics); the f32
accumulator and the termination flag live in VMEM/SMEM scratch that persists
across the (d, c) axes.  The ``q`` block index is ``(i, c)`` — independent
of the plane axis — so when the whole (padded) K fits one chunk (the common
``select_block_k`` outcome) the chunk stays resident across all D planes and
activations are read from HBM ONCE per (i, j) tile instead of D times.
Blocks are MXU-aligned on real TPU (bm, bn multiples of 128, bk a multiple
of 128 when tiled; any size in interpret mode).  ``block_k=None`` picks the
largest K chunk that keeps the working set inside the VMEM budget — there is
no whole-K residency requirement anymore.

Weights may be float32 or bfloat16 (accumulation is always f32).  Quantized
activations are stored at the narrowest integer width that holds the
quantization range (``q_storage_dtype``) and widened to i32 in VMEM.
``dslot_matmul_pallas_batched`` is the batched entry point: it folds a
leading batch axis into M (every output tile stays inside one batch element
because ``M % block_m == 0``), which is exactly equivalent to a vmap but
keeps a single sequential grid, and forwards the prepared termination tables
and runtime precision of the unbatched entry.

Validated in interpret mode against ``ref.dslot_matmul_ref`` (CPU container);
targeted at TPU v5e.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["dslot_matmul_pallas", "dslot_matmul_pallas_batched",
           "DslotMatmulOut", "colsum_tables", "select_block_k",
           "q_storage_dtype"]

_VMEM_BUDGET_BYTES = 12 * 1024 * 1024  # leave headroom below v5e's ~16 MiB
_LANE = 128                            # TPU lane width: K-chunk alignment


class DslotMatmulOut(NamedTuple):
    out: jax.Array               # (M, N) f32 — [relu](A_D @ W)
    planes_used: jax.Array       # (M/bm, N/bn) int32 — digit planes entered


def q_storage_dtype(n_bits: int, signed: bool = False) -> jnp.dtype:
    """Narrowest integer dtype holding the quantized-activation range.

    Unsigned ``n_bits``-bit quantization spans [0, 2^n_bits - 1] (u8 for the
    default 8-bit mode); signed spans ±(2^(n_bits-1) - 1) (i8 at 8 bits).
    This is the HBM footprint of the kernel's activation input — one byte
    per element at 8 bits versus the D int8 planes per element the
    materialized layout moved.  Values are widened to i32 in VMEM before
    digit extraction, so the storage dtype never changes results (pinned by
    ``tests/test_ktiling.py``); unsigned dtypes are exercised in interpret
    mode only — if Mosaic rejects u8 loads on real TPU, fall back to the
    next signed width here.
    """
    qmax = 2 ** (n_bits - 1) - 1 if signed else 2 ** n_bits - 1
    if signed:
        if qmax <= 127:
            return jnp.dtype(jnp.int8)
        if qmax <= 32767:
            return jnp.dtype(jnp.int16)
        return jnp.dtype(jnp.int32)
    if qmax <= 255:
        return jnp.dtype(jnp.uint8)
    if qmax <= 65535:
        return jnp.dtype(jnp.uint16)
    return jnp.dtype(jnp.int32)


def select_block_k(K: int, block_m: int, block_n: int, w_itemsize: int,
                   act_itemsize: int = 1,
                   budget: int = _VMEM_BUDGET_BYTES) -> int:
    """Largest K chunk whose working set fits the VMEM budget.

    Working set per grid step: one quantized-activation chunk
    (bm, bk) x ``act_itemsize`` (the integer ``q`` block digits are derived
    from — there is no separate plane chunk), one weight chunk (bk, bn), the
    f32 accumulator + output tile (bm, bn) and two f32 colsum rows (bn); the
    SMEM scalars (runtime precision, per-row budgets, termination flag) are
    negligible.  Returns K itself when the whole reduction fits (the untiled
    fast path — which also makes the ``q`` chunk resident across all D
    planes); otherwise a lane-aligned chunk size.
    """
    fixed = 2 * block_m * block_n * 4 + 2 * block_n * 4
    per_k = block_m * act_itemsize + block_n * w_itemsize
    avail = budget - fixed
    if avail < per_k * _LANE:
        raise ValueError(
            f"block_m={block_m} x block_n={block_n} alone exceeds the VMEM "
            f"budget ({budget} B); shrink the output tile")
    bk = avail // per_k
    if bk >= K:
        return K
    return max(_LANE, (bk // _LANE) * _LANE)


def colsum_tables(w: jax.Array, block_k: int) -> tuple[jax.Array, jax.Array]:
    """|W| column-sum termination tables over the ``block_k``-chunked K axis.

    ``w``: (Kp, N) padded weights with ``Kp % block_k == 0``.  Returns
    ``(suffix_colsum (Kt, N), total_colsum (1, N))`` — per-chunk "what the
    current plane has not seen yet" suffixes and the all-of-K total that the
    kernel's remaining-contribution bound reads.  The ONE implementation
    shared by ``ops.dslot_prepare`` (weight-stationary: computed once) and
    the kernel's default path below (one-shot callers with no prepared
    tables).
    """
    Kp, N = w.shape
    assert Kp % block_k == 0, (Kp, block_k)
    absw = jnp.abs(w.astype(jnp.float32))
    chunk_colsum = absw.reshape(Kp // block_k, block_k, N).sum(axis=1)
    total_colsum = chunk_colsum.sum(axis=0, keepdims=True)       # (1, N)
    return total_colsum - jnp.cumsum(chunk_colsum, axis=0), total_colsum


def _kernel(npl_ref, bnd_ref, bud_ref, q_ref, w_ref, sfx_ref, tot_ref,
            out_ref, used_ref, acc_ref, term_ref, *, n_bits: int,
            n_planes: int, n_kchunks: int, relu: bool):
    d = pl.program_id(2)
    c = pl.program_id(3)

    @pl.when(jnp.logical_and(d == 0, c == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        term_ref[0] = 0
        used_ref[...] = jnp.zeros_like(used_ref)

    # Runtime precision: planes at d >= npl are skipped entirely (their MXU
    # pass AND their digit extraction are predicated off), so precision is a
    # per-call argument — changing it never retraces or re-lowers the kernel.
    # The static per-N-tile MSR bound (SMEM scalar per j, baked at
    # dslot_prepare time from weight-side analysis — core.msr) caps the
    # plane count the same way: the effective plane budget of this tile is
    # min(n_planes_rt, row_budget, msr_bound[j]), so weight-inert tiles
    # never extract digits or issue MXU passes at all.
    npl = npl_ref[0, 0]
    terminated = jnp.logical_or(jnp.logical_or(term_ref[0] > 0, d >= npl),
                                d >= bnd_ref[0, 0])

    @pl.when(jnp.logical_not(terminated))
    def _accumulate():
        # On-the-fly MSDF digit extraction (ref.sd_digit_plane, inlined):
        # plane d of the resident quantized chunk is bit (n_bits-1-d) of |q|
        # times sign(q) — derived here, never stored in HBM.
        q = q_ref[...].astype(jnp.int32)                   # (bm, bk)
        bit = (jnp.abs(q) >> (n_bits - 1 - d)) & 1
        digit = (bit * jnp.sign(q)).astype(jnp.float32)
        # Per-row precision: rows whose budget is exhausted contribute zero
        # digits from this plane on (the SMEM (block_m,) budget vector of
        # this M-tile) — per-request precision inside a pooled batch.
        live = (bud_ref[0, :] > d).astype(jnp.float32)     # (bm,)
        plane = digit * live[:, None]
        w = w_ref[...].astype(jnp.float32)                 # (bk, bn)
        scale = jnp.exp2(jnp.asarray(n_bits - 1, jnp.float32)
                         - d.astype(jnp.float32))
        acc_ref[...] += scale * jnp.dot(
            plane, w, preferred_element_type=jnp.float32)

        @pl.when(c == 0)
        def _count_plane():
            used_ref[0, 0] += 1

        if relu:
            # Chunk-aware remaining-contribution bound (module docstring):
            # unseen chunks of this plane + all chunks of unseen planes up to
            # the runtime precision npl (geometric tail 2^(n_bits - npl)).
            tail = jnp.exp2(jnp.asarray(n_bits, jnp.float32)
                            - npl.astype(jnp.float32))
            rem = scale * sfx_ref[0] \
                + (scale - tail) * tot_ref[0]              # (bn,)
            provably_neg = jnp.all(acc_ref[...] + rem[None, :] < 0.0)
            term_ref[0] = jnp.where(provably_neg, 1, term_ref[0])

    @pl.when(jnp.logical_and(d == n_planes - 1, c == n_kchunks - 1))
    def _finalize():
        acc = acc_ref[...]
        if relu:
            acc = jnp.maximum(acc, 0.0)
            acc = jnp.where(term_ref[0] > 0, 0.0, acc)
        out_ref[...] = acc


def _pad_to(x: jax.Array, m: int, axis: int) -> jax.Array:
    """Zero-pad ``axis`` up to the next multiple of ``m`` (shared with ops.py)."""
    r = (-x.shape[axis]) % m
    if r == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, r)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=(
    "n_bits", "n_planes", "relu", "block_m", "block_n", "block_k",
    "interpret"))
def dslot_matmul_pallas(q: jax.Array, w: jax.Array, *, n_bits: int = 8,
                        n_planes: int | None = None, relu: bool = True,
                        block_m: int = 128, block_n: int = 128,
                        block_k: int | None = None,
                        n_planes_rt: jax.Array | None = None,
                        row_budget: jax.Array | None = None,
                        suffix_colsum: jax.Array | None = None,
                        total_colsum: jax.Array | None = None,
                        plane_bound: jax.Array | None = None,
                        interpret: bool = True) -> DslotMatmulOut:
    """Run the digit-serial matmul kernel with fused digit encoding.

    q:       (M, K) integer quantized activations, |q| < 2^n_bits (see
             ``ops.quantize_activations``); any int dtype — widened to i32
             inside the kernel.  Digit planes are derived from ``q`` in the
             kernel (``ref.sd_digit_plane``), never materialized.
    w:       (K, N) float32/bfloat16 weights.
    n_planes: STATIC plane-axis depth D of the grid (default ``n_bits``) —
             use for a statically-truncated precision where the grid itself
             shrinks (the fused one-shot path).
    block_k: K chunk size streamed through VMEM (None = auto-select the
             largest chunk that fits the budget; K is zero-padded to a
             multiple — zero rows contribute nothing to sums or bounds).
    n_planes_rt: optional RUNTIME precision (i32 scalar, <= D): planes at
             d >= n_planes_rt are predicated off — no retrace across
             precisions.  None runs all D planes.
    row_budget: optional RUNTIME per-row precision ((M,) i32): digits of row
             m beyond ``row_budget[m]`` are zeroed during extraction (SMEM
             (block_m,) vector per M-tile).  The scalar ``n_planes_rt``
             still bounds the whole call — pass the row max (as
             ``ops.dslot_execute`` does) so fully-exhausted planes skip
             their passes.  None means every row runs to ``n_planes_rt``.
    suffix_colsum / total_colsum: the |W| column-sum termination tables
             ((Kt, N) / (1, N) over the bk-padded K), precomputed once by
             ``ops.dslot_prepare`` for weight-stationary serving.  None
             recomputes them here via ``colsum_tables`` (the one-shot path).
    plane_bound: optional STATIC-per-weights plane upper bound per N-tile
             ((N/block_n,) i32, from ``DslotWeights.msr_bound``): tile j
             runs at most ``plane_bound[j]`` planes — weight-side sparsity
             baked at prepare time (``core.msr.tile_plane_bound`` emits
             only output-exact bounds).  Rides in SMEM like the runtime
             precision scalar; None means no weight-side cap.
    M % block_m == 0 and N % block_n == 0 (callers pad — see ``ops.py``).
    """
    M, K = q.shape
    K2, N = w.shape
    assert K == K2, (q.shape, w.shape)
    assert M % block_m == 0 and N % block_n == 0, (M, N, block_m, block_n)
    if n_planes is not None and n_planes < 1:
        raise ValueError(f"n_planes must be >= 1, got {n_planes}")
    D = min(n_planes or n_bits, n_bits)

    bk = block_k or select_block_k(K, block_m, block_n, w.dtype.itemsize,
                                   q.dtype.itemsize)
    vmem = (block_m * bk * q.dtype.itemsize) \
        + (bk * block_n * w.dtype.itemsize) \
        + 2 * (block_m * block_n * 4) + 2 * block_n * 4
    if vmem > _VMEM_BUDGET_BYTES:
        raise ValueError(
            f"working set {vmem / 2**20:.1f} MiB for block_k={bk} exceeds the "
            f"VMEM budget; pass a smaller block_k (or None to auto-select)")
    q = _pad_to(q, bk, axis=1)
    w = _pad_to(w, bk, axis=0)
    Kp = w.shape[0]
    Kt = Kp // bk

    if suffix_colsum is None or total_colsum is None:
        suffix_colsum, total_colsum = colsum_tables(w, bk)
    assert suffix_colsum.shape == (Kt, N), (suffix_colsum.shape, Kt, N)
    assert total_colsum.shape == (1, N), (total_colsum.shape, N)

    if n_planes_rt is None:
        n_planes_rt = jnp.asarray(D, jnp.int32)
    npl = jnp.asarray(n_planes_rt, jnp.int32).reshape(1, 1)
    if plane_bound is None:
        bnd = jnp.full((1, N // block_n), D, jnp.int32)
    else:
        assert plane_bound.shape == (N // block_n,), \
            (plane_bound.shape, N, block_n)
        bnd = jnp.asarray(plane_bound, jnp.int32).reshape(1, -1)
    if row_budget is None:
        bud = jnp.full((1, M), npl[0, 0], jnp.int32)
    else:
        assert row_budget.shape == (M,), (row_budget.shape, M)
        bud = jnp.asarray(row_budget, jnp.int32).reshape(1, M)

    grid = (M // block_m, N // block_n, D, Kt)
    kernel = functools.partial(_kernel, n_bits=n_bits, n_planes=D,
                               n_kchunks=Kt, relu=relu)
    out, used = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, d, c: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j, d, c: (0, j),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block_m), lambda i, j, d, c: (0, i),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_m, bk), lambda i, j, d, c: (i, c)),
            pl.BlockSpec((bk, block_n), lambda i, j, d, c: (c, j)),
            pl.BlockSpec((1, block_n), lambda i, j, d, c: (c, j)),
            pl.BlockSpec((1, block_n), lambda i, j, d, c: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, d, c: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j, d, c: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), jnp.float32),
            jax.ShapeDtypeStruct((M // block_m, N // block_n), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), jnp.float32),   # accumulator
            pltpu.SMEM((1,), jnp.int32),                   # termination flag
        ],
        interpret=interpret,
    )(npl, bnd, bud, q, w, suffix_colsum, total_colsum)
    return DslotMatmulOut(out=out, planes_used=used)


def dslot_matmul_pallas_batched(q: jax.Array, w: jax.Array, *,
                                n_bits: int = 8,
                                n_planes: int | None = None,
                                relu: bool = True,
                                block_m: int = 128, block_n: int = 128,
                                block_k: int | None = None,
                                n_planes_rt: jax.Array | None = None,
                                row_budget: jax.Array | None = None,
                                suffix_colsum: jax.Array | None = None,
                                total_colsum: jax.Array | None = None,
                                plane_bound: jax.Array | None = None,
                                interpret: bool = True) -> DslotMatmulOut:
    """Batched entry point: q (B, M, K) sharing one weight matrix.

    The batch axis is folded into M — with ``M % block_m == 0`` every output
    tile lies inside a single batch element, so results and per-tile
    termination are identical to B independent kernel launches, but the grid
    stays one sequential sweep.  The full unbatched surface passes through:
    ``n_planes_rt`` (runtime scalar precision), ``row_budget`` ((B,)
    per-request or (B, M) per-row budgets, expanded to the folded rows), the
    prepared ``suffix_colsum``/``total_colsum`` termination tables, and the
    static per-N-tile ``plane_bound`` (weight-side, batch-invariant) — so
    batched serving callers reuse ``dslot_prepare``'s tables instead of
    recomputing |W| column-sums per call.  Returns out (B, M, N) and
    planes_used (B, M/bm, N/bn).
    """
    B, M, K = q.shape
    assert M % block_m == 0, (M, block_m)
    if row_budget is not None:
        row_budget = jnp.asarray(row_budget, jnp.int32)
        if row_budget.shape == (B,):            # one budget per batch element
            row_budget = jnp.repeat(row_budget, M)
        else:
            assert row_budget.shape == (B, M), (row_budget.shape, B, M)
            row_budget = row_budget.reshape(B * M)
    r = dslot_matmul_pallas(q.reshape(B * M, K), w, n_bits=n_bits,
                            n_planes=n_planes, relu=relu,
                            block_m=block_m, block_n=block_n,
                            block_k=block_k, n_planes_rt=n_planes_rt,
                            row_budget=row_budget,
                            suffix_colsum=suffix_colsum,
                            total_colsum=total_colsum,
                            plane_bound=plane_bound, interpret=interpret)
    N = r.out.shape[-1]
    return DslotMatmulOut(
        out=r.out.reshape(B, M, N),
        planes_used=r.planes_used.reshape(B, M // block_m, -1))
