"""Pallas TPU kernel: digit-plane MSDF matmul with per-tile early termination.

TPU-native adaptation of DSLOT-NN's datapath (DESIGN.md §2/§4.2).  The FPGA
design streams one signed digit per cycle through online multipliers and kills
a SOP the moment its MSDF prefix goes negative.  A TPU has no per-lane early
exit, so the unit of "digit" becomes a *digit plane* (one MXU matmul) and the
unit of termination becomes an *output tile*:

    C = sum_d 2^(n-1-d) * (P_d @ W),      P_d in {-1,0,1}^(M x K), d MSDF

After accumulating plane d, the remaining planes can contribute at most
``R_d[n] = (2^(n-1-d) - 2^(n-D)) * sum_k |W[k, n]|`` to any element of output
column n (digits are bounded by 1 in magnitude).  A tile with
``max_m(acc + R_d) < 0`` everywhere is provably negative under ReLU: its
remaining ``D-d-1`` MXU passes are SKIPPED (predicated with ``pl.when``) and it
emits zeros — the tile-granular Algorithm 1.  MSDF ordering makes ``R_d``
shrink geometrically, which is exactly the paper's "sign is known from the
first non-zero digit" property.

Grid/layout: ``grid = (M/bm, N/bn, D)`` with the digit-plane axis innermost
(sequential, "arbitrary" semantics); the f32 accumulator and the termination
flag live in VMEM/SMEM scratch that persists across the plane axis.  Blocks
are MXU-aligned (bm, bn multiples of 128 on real TPU; any size in interpret
mode).  W is reloaded per (i, j) tile and stays VMEM-resident across planes
(weight-stationary — the paper's dataflow).

Validated in interpret mode against ``ref.dslot_matmul_ref`` (CPU container);
targeted at TPU v5e (BlockSpec VMEM budget asserted at trace time).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["dslot_matmul_pallas", "DslotMatmulOut"]

_VMEM_BUDGET_BYTES = 12 * 1024 * 1024  # leave headroom below v5e's ~16 MiB


class DslotMatmulOut(NamedTuple):
    out: jax.Array               # (M, N) f32 — [relu](A_D @ W)
    planes_used: jax.Array       # (M/bm, N/bn) int32 — MXU passes per tile


def _kernel(planes_ref, w_ref, out_ref, used_ref, acc_ref, term_ref, *,
            n_bits: int, n_planes: int, relu: bool, block_m: int,
            block_n: int):
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        term_ref[0] = 0
        used_ref[...] = jnp.zeros_like(used_ref)

    terminated = term_ref[0] > 0

    @pl.when(jnp.logical_not(terminated))
    def _accumulate():
        plane = planes_ref[0].astype(jnp.float32)          # (bm, K)
        w = w_ref[...].astype(jnp.float32)                 # (K, bn)
        scale = jnp.exp2(jnp.asarray(n_bits - 1, jnp.float32)
                         - d.astype(jnp.float32))
        acc_ref[...] += scale * jnp.dot(
            plane, w, preferred_element_type=jnp.float32)
        used_ref[0, 0] += 1

        if relu:
            # Remaining-contribution bound per output column (see module doc).
            rem = (scale - 2.0 ** (n_bits - n_planes)) * \
                jnp.sum(jnp.abs(w), axis=0)                # (bn,)
            provably_neg = jnp.all(acc_ref[...] + rem[None, :] < 0.0)
            term_ref[0] = jnp.where(provably_neg, 1, term_ref[0])

    @pl.when(d == n_planes - 1)
    def _finalize():
        acc = acc_ref[...]
        if relu:
            acc = jnp.maximum(acc, 0.0)
            acc = jnp.where(term_ref[0] > 0, 0.0, acc)
        out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=(
    "n_bits", "relu", "block_m", "block_n", "interpret"))
def dslot_matmul_pallas(planes: jax.Array, w: jax.Array, *, n_bits: int = 8,
                        relu: bool = True, block_m: int = 128,
                        block_n: int = 128, interpret: bool = True
                        ) -> DslotMatmulOut:
    """Run the digit-plane matmul kernel.

    planes: (D, M, K) int8 MSDF digit planes (see ``ref.make_planes``).
    w:      (K, N) float32/bfloat16 weights.
    M % block_m == 0 and N % block_n == 0 (callers pad — see ``ops.py``).
    """
    D, M, K = planes.shape
    K2, N = w.shape
    assert K == K2, (planes.shape, w.shape)
    assert M % block_m == 0 and N % block_n == 0, (M, N, block_m, block_n)

    vmem = (block_m * K * 1) + (K * block_n * w.dtype.itemsize) \
        + 2 * (block_m * block_n * 4)
    assert vmem <= _VMEM_BUDGET_BYTES, (
        f"VMEM working set {vmem/2**20:.1f} MiB exceeds budget; "
        f"shrink block_m/block_n or shard K")

    grid = (M // block_m, N // block_n, D)
    kernel = functools.partial(_kernel, n_bits=n_bits, n_planes=D, relu=relu,
                               block_m=block_m, block_n=block_n)
    out, used = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, K), lambda i, j, d: (d, i, 0)),
            pl.BlockSpec((K, block_n), lambda i, j, d: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, d: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j, d: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), jnp.float32),
            jax.ShapeDtypeStruct((M // block_m, N // block_n), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), jnp.float32),   # accumulator
            pltpu.SMEM((1,), jnp.int32),                   # termination flag
        ],
        interpret=interpret,
    )(planes, w)
    return DslotMatmulOut(out=out, planes_used=used)
