"""Pure-jnp oracles for the digit-plane DSLOT kernels.

The oracle defines the semantics the Pallas kernel must match bit-for-bit
(up to float accumulation order): a quantized matmul evaluated MSDF over
signed-digit planes, with optional fused ReLU.  Early termination in the
kernel is a pure work-saving — it must never change the result, so the oracle
simply computes the full product.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.digits import fixed_to_sd

__all__ = ["make_planes", "sd_digit_plane", "dslot_matmul_ref",
           "plane_value_ref", "csd_matmul_ref"]


def make_planes(a_q: jax.Array, n_bits: int, n_planes: int | None = None
                ) -> jax.Array:
    """SD digit planes of a signed integer matrix, MSDF.

    ``a_q`` int32 (M, K) with ``|a_q| < 2^n_bits``.  Returns int8
    ``(D, M, K)`` planes with ``a_q ~= sum_d planes[d] * 2^(n_bits-1-d)``
    (exact when D = n_bits; truncating D < n_bits is the paper's runtime
    precision knob — error < 2^(n_bits-D)).

    This is the REFERENCE encoder: it materializes all D planes at once.
    The execution paths never do — they derive one plane at a time with
    ``sd_digit_plane`` (jnp replay) or the same arithmetic inlined in the
    Pallas kernel, and tests pin those against this oracle.
    """
    planes = fixed_to_sd(a_q, n_bits)          # digit d weight 2^-(d+1) of q/2^n
    if n_planes is not None:
        planes = planes[:n_planes]
    return planes


def sd_digit_plane(a_q: jax.Array, n_bits: int, d) -> jax.Array:
    """Plane ``d`` of ``make_planes(a_q, n_bits)``, computed arithmetically
    without materializing the ``(D, ...)`` digit tensor.

    Sign-magnitude recoding (``fixed_to_sd``): digit ``d`` of ``q`` is bit
    ``n_bits - 1 - d`` of ``|q|`` times ``sign(q)`` — a shift, a mask, and a
    sign multiply on the value itself.  ``d`` may be a traced i32 scalar
    (the kernels compute the plane of the CURRENT grid step / scan step from
    the resident value chunk, which is what makes the digit stream
    on-the-fly rather than a precomputed tensor).  Returns int8, same shape
    as ``a_q``, digits in {-1, 0, 1}.
    """
    q = jnp.asarray(a_q, jnp.int32)
    d = jnp.asarray(d, jnp.int32)
    bit = (jnp.abs(q) >> (n_bits - 1 - d)) & 1
    return (bit * jnp.sign(q)).astype(jnp.int8)


def plane_value_ref(planes: jax.Array, n_bits: int) -> jax.Array:
    """Reconstruct the (possibly truncated) integer value of digit planes."""
    D = planes.shape[0]
    w = 2.0 ** (n_bits - 1 - jnp.arange(D, dtype=jnp.float32))
    return jnp.tensordot(w, planes.astype(jnp.float32), axes=(0, 0))


def dslot_matmul_ref(planes: jax.Array, w: jax.Array, n_bits: int,
                     relu: bool = True) -> jax.Array:
    """Oracle: ``C = [relu](A_D @ W)`` where ``A_D`` is the plane-truncated
    integer activation.  Evaluated plane-by-plane MSDF exactly like the kernel
    (same accumulation order, f32).

    planes: (D, M, K) int8;  w: (K, N) float32.  Returns (M, N) float32.
    """
    D, M, K = planes.shape
    w = w.astype(jnp.float32)

    def body(d, acc):
        scale = jnp.exp2(jnp.asarray(n_bits - 1 - d, jnp.float32))
        return acc + scale * jnp.dot(planes[d].astype(jnp.float32), w,
                                     preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, D, body, jnp.zeros((M, w.shape[1]), jnp.float32))
    return jnp.maximum(acc, 0.0) if relu else acc


def csd_matmul_ref(planes: jax.Array, w: jax.Array, n_bits: int,
                   relu: bool = False) -> jax.Array:
    """Oracle for the CSD/Booth enumeration prototype (``core.csd``).

    Same MSDF plane-by-plane evaluation as ``dslot_matmul_ref`` but over
    CSD digit planes: plane ``p`` of ``core.csd.csd_recode`` carries weight
    ``2^(n_bits - p)`` (one position higher than binary — CSD of an n-bit
    magnitude can carry into ``2^n_bits``), and there are ``n_bits + 1``
    planes.  With integer-valued ``w`` every step is exact in f32, so this
    must equal ``q @ w`` bit-for-bit — the bench's exactness gate.

    planes: (n_bits + 1, M, K) int8;  w: (K, N) float32.
    """
    D, M, K = planes.shape
    w = w.astype(jnp.float32)

    def body(p, acc):
        scale = jnp.exp2(jnp.asarray(n_bits - p, jnp.float32))
        return acc + scale * jnp.dot(planes[p].astype(jnp.float32), w,
                                     preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, D, body, jnp.zeros((M, w.shape[1]), jnp.float32))
    return jnp.maximum(acc, 0.0) if relu else acc
