"""TPU hot-spot kernels: the DSLOT digit-plane matmul.

``dslot_matmul.py`` — pl.pallas_call kernel (K-chunked VMEM streaming with a
chunk-aware per-tile early-termination bound, auto block-size selection,
bf16 weights, batched entry); ``ops.py`` — jit'd wrapper with quantization /
padding / column-sorting and a jnp backend replaying identical termination
accounting; ``ref.py`` — pure-jnp oracle the kernel is tested against
(tests/test_kernels.py, tests/test_ktiling.py).
"""

from .dslot_matmul import (DslotMatmulOut, dslot_matmul_pallas,
                           dslot_matmul_pallas_batched, select_block_k)
from .ops import (DslotStats, DslotWeights, calibrate_scale, dslot_execute,
                  dslot_matmul, dslot_prepare, prepare_call_count,
                  quantize_activations)
from .ref import dslot_matmul_ref, make_planes

__all__ = ["DslotMatmulOut", "DslotStats", "DslotWeights", "dslot_matmul",
           "dslot_prepare", "dslot_execute", "calibrate_scale",
           "prepare_call_count", "dslot_matmul_pallas",
           "dslot_matmul_pallas_batched", "select_block_k",
           "quantize_activations", "dslot_matmul_ref", "make_planes"]
