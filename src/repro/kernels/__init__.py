"""TPU hot-spot kernels: the digit-serial DSLOT matmul.

``dslot_matmul.py`` — pl.pallas_call kernel (fused in-kernel MSDF digit
encoding straight from the quantized activation block — no materialized
(D, M, K) plane tensor — K-chunked VMEM streaming with a chunk-aware
per-tile early-termination bound, SMEM runtime precision scalar + per-row
budget vector + static per-N-tile weight-side MSR plane bound, auto
block-size selection, bf16 weights, batched entry);
``ops.py`` — jit'd wrapper with quantization / padding / column-sorting and
a jnp backend replaying identical termination accounting plane-free;
``ref.py`` — pure-jnp oracle the kernels are tested against
(tests/test_kernels.py, tests/test_ktiling.py, tests/test_fused_digits.py).
"""

from .dslot_matmul import (DslotMatmulOut, colsum_tables,
                           dslot_matmul_pallas, dslot_matmul_pallas_batched,
                           q_storage_dtype, select_block_k)
from .ops import (DslotStats, DslotWeights, calibrate_scale, dslot_execute,
                  dslot_matmul, dslot_prepare, prepare_call_count,
                  quantize_activations)
from .ref import csd_matmul_ref, dslot_matmul_ref, make_planes, sd_digit_plane

__all__ = ["DslotMatmulOut", "DslotStats", "DslotWeights", "dslot_matmul",
           "dslot_prepare", "dslot_execute", "calibrate_scale",
           "prepare_call_count", "dslot_matmul_pallas",
           "dslot_matmul_pallas_batched", "colsum_tables", "select_block_k",
           "q_storage_dtype", "quantize_activations", "dslot_matmul_ref",
           "csd_matmul_ref", "make_planes", "sd_digit_plane"]
