"""TPU hot-spot kernels: the DSLOT digit-plane matmul.

``dslot_matmul.py`` — pl.pallas_call kernel (BlockSpec VMEM tiling, per-tile
early negative termination); ``ops.py`` — jit'd wrapper with quantization /
padding / column-sorting; ``ref.py`` — pure-jnp oracle the kernel is tested
against (shape/dtype sweeps + hypothesis, tests/test_kernels.py).
"""

from .ops import DslotStats, dslot_matmul, quantize_activations
from .ref import dslot_matmul_ref, make_planes

__all__ = ["DslotStats", "dslot_matmul", "quantize_activations",
           "dslot_matmul_ref", "make_planes"]
