"""Framework-facing ops for the digit-plane DSLOT engine.

The engine is split into a **prepare/execute** pair — the software analogue of
the paper's weight-stationary dataflow:

* ``dslot_prepare(w, ...) -> DslotWeights`` — everything that depends only on
  the weights, computed ONCE per layer per model lifetime: column-sort
  permutation (+ inverse), block geometry (``block_k`` VMEM auto-selection),
  N/K padding, and the |W| column-sum termination tables the kernel's
  chunk-aware bound reads.  Weights are stationary, so all of this is
  amortized over every subsequent request.
* ``dslot_execute(prepared, x, n_planes=...)`` — the per-request hot path:
  quantize activations (against a calibrated FIXED scale when one is stored
  in the prepared state — no data-dependent ``jnp.max`` in the hot path),
  run the kernel, dequantize.  MSDF digit planes are derived INSIDE the
  kernel from the quantized block (``ref.sd_digit_plane`` arithmetic), never
  materialized as a (D, M, K) tensor in HBM — the activation stream the
  kernel reads is the ~n_bits/8-byte-per-element ``q`` itself, not D digit
  planes of it.  ``n_planes`` is a RUNTIME argument (scalar or per-row
  vector): planes beyond it are predicated off in the Pallas kernel / masked
  in the jnp replay (per-row budgets travel as an SMEM vector into the
  kernel), so changing precision never retraces — this is the paper's
  "precision tuned at run-time" as a first-class request parameter.
* ``calibrate_scale(x_sample, ...)`` — one-shot activation-range calibration;
  store the result via ``DslotWeights.with_scale``.

``dslot_matmul`` remains as the fused one-shot entry point (prepare+execute
in a single jit) used by benchmarks and ad-hoc callers; layers and the
serving engine go through the split API.  ``docs/kernel.md`` maps the kernel
to the paper; ``docs/architecture.md`` shows where this split sits in the
serving stack.

NOTE for chunked/serving use: without a calibrated ``x_scale`` the execute
path quantizes against the per-call activation max, which depends on the
token window each call sees — pin a scale (``calibrate_scale`` +
``DslotWeights.with_scale`` or ``DslotConfig.act_scale``) when results must
be invariant to how a sequence is split into calls (e.g. chunked prefill).

Backends: ``"pallas"`` (interpret on CPU, compiled on TPU; real skipped MXU
passes), ``"jnp"`` (vectorized replay with identical semantics and identical
termination statistics), ``"auto"`` (pallas on TPU, jnp elsewhere).

Beyond-paper optimization (``sort_columns=True``): weight-stationary column
reordering.  Tile termination requires *spatially clustered* dead outputs;
sorting output columns by their weight column-sum (a static, offline
permutation — exactly the paper's stationary-weight assumption) clusters
ReLU-dead neurons into contiguous tiles, which measurably raises the
skipped-pass fraction.  The inverse permutation is applied to the output, so
results are unchanged.

Tensor parallelism (``dslot_prepare(mesh=..., tp_axis=...)``): the prepared
state shards along the OUTPUT (N) axis at tile granularity across the mesh's
``tp_axis`` — the software analogue of replicating the paper's PE array.
Early termination is a per-N-tile decision and the |W| colsum termination
tables and MSR plane bounds are per-column/per-tile, so every shard runs the
SAME kernel on its own column slice with its own termination tables and no
cross-device coordination; outputs and per-tile ``planes_used`` concatenate
back (``shard_map`` with the activations replicated), and the global
``DslotStats`` accounting is computed from the reassembled arrays exactly as
in the single-device path — results and statistics are bit-identical to
``mesh=None`` (pinned by ``tests/test_tensor_parallel.py``).  When the tile
count does not divide the shard count, extra all-zero N-tiles (plane bound
0 — exact no-ops, the ``core.msr`` mechanism) pad the shard layout and are
sliced off after the gather.  See ``docs/distributed.md``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.msr import tile_plane_bound

from .dslot_matmul import (_pad_to, colsum_tables, dslot_matmul_pallas,
                           q_storage_dtype, select_block_k)
from .ref import sd_digit_plane

__all__ = ["DslotStats", "DslotWeights", "dslot_matmul", "dslot_prepare",
           "dslot_execute", "calibrate_scale", "prepare_call_count",
           "quantize_activations"]

_PREPARE_CALLS = 0


def prepare_call_count() -> int:
    """Number of ``dslot_prepare`` invocations (trace-time for jitted
    callers) since process start — tests assert prepare-once behaviour."""
    return _PREPARE_CALLS


class DslotStats(NamedTuple):
    planes_used: jax.Array      # (Mt, Nt) int32 — MXU passes per output tile
    n_planes: int               # plane budget the call was traced with
    skipped_frac: jax.Array     # scalar — fraction of plane-passes skipped
                                # (includes weight-side bounded planes: the
                                # bound caps planes_used, so activation- and
                                # weight-side savings compound here)
    row_planes_used: jax.Array | None = None  # (M,) f32 — effective planes
                                # per output row (serving: per-slot account)
    planes_bounded: jax.Array | None = None  # (Mt, Nt) int32 — planes never
                                # ISSUED because the static weight-side MSR
                                # bound capped the tile below its granted
                                # budget; disjoint from the activation-side
                                # early-termination planes_used accounting


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DslotWeights:
    """Prepared (weight-stationary) state of one DSLOT layer.

    Array children are jit/vmap/scan-compatible; the geometry/config fields
    are pytree aux data, so passing a ``DslotWeights`` through ``jax.jit``
    makes them static automatically.
    """
    w: jax.Array                  # (Kp, Np) padded (+sorted) weights
    suffix_colsum: jax.Array      # (Kt, Np) f32 — unseen-chunk bound table
    total_colsum: jax.Array       # (1, Np) f32 — all-of-K bound table
    inv_perm: jax.Array | None    # (N,) i32 undo of column sort, or None
    x_scale: jax.Array | None     # () f32 calibrated activation step, or
                                  # None -> dynamic per-call max (fallback)
    msr_bound: jax.Array | None = None  # (Nt,) i32 static per-N-tile plane
                                  # upper bound from weight-side MSR
                                  # analysis (core.msr), or None = no cap
    # -- static geometry / config (pytree aux data) --
    n_bits: int = 8
    relu: bool = True
    signed: bool = False
    block_m: int = 128
    block_n: int = 128
    block_k: int = 0              # resolved chunk size (never None here)
    backend: str = "jnp"          # resolved: "pallas" | "jnp"
    d_in: int = 0                 # K before padding
    d_out: int = 0                # N before padding
    mesh: Mesh | None = None      # tensor-parallel device mesh, or None =
                                  # single-device execution
    tp_axis: str = "model"        # mesh axis the N (output) tiles shard over

    def tree_flatten(self):
        children = (self.w, self.suffix_colsum, self.total_colsum,
                    self.inv_perm, self.x_scale, self.msr_bound)
        aux = (self.n_bits, self.relu, self.signed, self.block_m,
               self.block_n, self.block_k, self.backend, self.d_in,
               self.d_out, self.mesh, self.tp_axis)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def tp_shards(self) -> int:
        """Tensor-parallel shard count (1 when unsharded)."""
        return 1 if self.mesh is None else int(self.mesh.shape[self.tp_axis])

    def with_scale(self, x_scale) -> "DslotWeights":
        """Attach a calibrated activation scale (see ``calibrate_scale``)."""
        return dataclasses.replace(
            self, x_scale=jnp.asarray(x_scale, jnp.float32))


def quantize_activations(x: jax.Array, n_bits: int = 8, signed: bool = False,
                         scale: jax.Array | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """Symmetric activation quantization -> (q int32, step float32).

    ``scale=None`` derives the step from this batch's max (data-dependent —
    fine for one-shot calls, a hot-path sync for serving); a calibrated
    fixed ``scale`` skips the reduction and clips outliers instead.
    """
    qmax = float(2 ** n_bits - 1 if not signed else 2 ** (n_bits - 1) - 1)
    if scale is None:
        amax = jnp.maximum(jnp.max(jnp.abs(x)) if signed else jnp.max(x),
                           1e-12)
        step = amax / qmax
    else:
        step = jnp.asarray(scale, jnp.float32)
    lo = -qmax if signed else 0.0
    q = jnp.clip(jnp.round(x / step), lo, qmax).astype(jnp.int32)
    return q, step


def calibrate_scale(x_sample: jax.Array, n_bits: int = 8,
                    signed: bool = False) -> jax.Array:
    """Fixed activation quantization step from a calibration batch."""
    qmax = float(2 ** n_bits - 1 if not signed else 2 ** (n_bits - 1) - 1)
    amax = jnp.max(jnp.abs(x_sample)) if signed else jnp.max(x_sample)
    return (jnp.maximum(amax, 1e-12) / qmax).astype(jnp.float32)


def dslot_prepare(w: jax.Array, *, n_bits: int = 8, relu: bool = True,
                  signed: bool = False, sort_columns: bool = False,
                  block_m: int = 128, block_n: int = 128,
                  block_k: int | None = None, backend: str = "auto",
                  x_scale: jax.Array | None = None,
                  msr_bound: bool = True, mesh: Mesh | None = None,
                  tp_axis: str = "model") -> DslotWeights:
    """One-time weight lowering: sort, pad, pick ``block_k``, build the
    termination tables and the weight-side MSR plane bound.  Call once per
    layer; reuse across every request.

    ``w``: (K, N) float32/bfloat16.  For a stacked weight (L, K, N) use
    ``jax.vmap(lambda wl: dslot_prepare(wl, ...))`` — all children map.

    ``msr_bound=True`` profiles the padded/sorted weight tiles
    (``core.msr.tile_plane_bound``) and bakes a static per-N-tile plane
    upper bound into the prepared state: tiles proven output-inert from the
    weight side alone (exactly-zero columns — including every N-padding
    tile — and, under unsigned+ReLU, all-non-positive tiles) get bound 0
    and are never issued by any backend.  Only output-exact bounds are
    emitted, so results are bit-identical to ``msr_bound=False``.

    ``mesh``/``tp_axis`` make every subsequent ``dslot_execute`` run
    tensor-parallel: N tiles shard across ``mesh.shape[tp_axis]`` devices
    under ``shard_map``, each shard terminating against its own slice of
    the colsum tables and MSR bounds (see the module docstring).  Results
    are bit-identical to ``mesh=None``.
    """
    global _PREPARE_CALLS
    _PREPARE_CALLS += 1
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if mesh is not None and tp_axis not in mesh.axis_names:
        raise ValueError(
            f"tp_axis {tp_axis!r} not in mesh axes {mesh.axis_names}")
    K, N = w.shape

    inv_perm = None
    if sort_columns:
        perm = jnp.argsort(jnp.sum(w, axis=0))          # dead cols first
        w = w[:, perm]
        inv_perm = jnp.argsort(perm)

    bk = block_k or select_block_k(K, block_m, block_n, w.dtype.itemsize,
                                   q_storage_dtype(n_bits, signed).itemsize)
    w_p = _pad_to(w, block_n, axis=1)
    w_p = _pad_to(w_p, bk, axis=0)

    suffix_colsum, total_colsum = colsum_tables(w_p, bk)
    bound = tile_plane_bound(w_p, block_n, n_bits=n_bits, relu=relu,
                             signed=signed) if msr_bound else None

    return DslotWeights(
        w=w_p, suffix_colsum=suffix_colsum, total_colsum=total_colsum,
        inv_perm=inv_perm, x_scale=x_scale, msr_bound=bound, n_bits=n_bits,
        relu=relu, signed=signed, block_m=block_m, block_n=block_n,
        block_k=bk, backend=backend, d_in=K, d_out=N, mesh=mesh,
        tp_axis=tp_axis)


# ------------------------------------------------------------- execution

def _jnp_path(q: jax.Array, w: jax.Array, n_bits: int, n_planes: int,
              relu: bool, block_m: int, block_n: int, bk: int,
              suffix: jax.Array, total: jax.Array, npl: jax.Array,
              row_budget: jax.Array, tile_bound: jax.Array):
    """Reference evaluation + termination accounting, plane-free.

    Computes every plane (no skipping — this is CPU) but derives the exact
    per-tile ``planes_used`` the Pallas kernel would report, by replaying the
    chunk-aware bound check in the kernel's (plane outer, K-chunk inner)
    iteration order.  Digit planes are never stacked: each scan step derives
    plane ``d`` of its K chunk from the quantized activations on the fly
    (``ref.sd_digit_plane``, inlined on the pre-split sign/magnitude), so
    peak activation memory is O(M*K) — not O(D*M*K) — and stays at O(M*N)
    per step regardless of how small ``bk`` is (only the per-step per-tile
    dead flags are stacked).

    ``npl`` is the runtime precision (i32 scalar): planes at d >= npl
    contribute nothing and ``planes_used`` is clamped to it — the same
    semantics as the kernel's predicated passes.  ``row_budget`` ((M,) i32)
    zeroes each row's digits beyond its own budget — identical to the
    kernel's SMEM per-row budget vector.  ``tile_bound`` ((Nt,) i32) is the
    static weight-side MSR plane bound: columns of tile j accumulate
    nothing at d >= tile_bound[j] and the tile's planes_used is capped by
    it — the mirror of the kernel's per-j SMEM bound scalar (a frozen tile
    whose stale termination check fires in the replay is indistinguishable
    after the cap, same as the npl clamp below).

    q (M, Kp) integer pre-padded; w (Kp, N); suffix (Kt, N) and total (N,)
    are the prepared |W| column-sum bound tables; n_planes is the static
    plane-axis depth D.
    """
    M, K = q.shape
    D = n_planes
    N = w.shape[1]
    Kt = K // bk
    Mt, Nt = M // block_m, N // block_n
    npl_f = npl.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    w_chunks = wf.reshape(Kt, bk, N)
    # K-chunk-major activation layout at its narrow storage width: the only
    # activation tensor the scan streams is (Kt, M, bk) = M*K elements, no D
    # factor — sign/magnitude are split per step on the resident chunk
    q_chunks = q.reshape(M, Kt, bk).transpose(1, 0, 2)
    scales = jnp.exp2(jnp.asarray(n_bits - 1, jnp.float32)
                      - jnp.arange(D, dtype=jnp.float32))
    step_scale = jnp.repeat(scales, Kt)                         # (D*Kt,)

    # Remaining-contribution bound after step (d, c):
    # scale_d * suffix_colsum[c] + (scale_d - 2^(n_bits - npl)) * total.
    tail = jnp.exp2(jnp.asarray(n_bits, jnp.float32) - npl_f)
    step_rem = (scales[:, None, None] * suffix[None, :, :]
                + ((scales - tail)[:, None, None]
                   * total[None, None, :])).reshape(D * Kt, N)

    bound_cols = jnp.repeat(tile_bound.astype(jnp.int32), block_n,
                            total_repeat_length=N)              # (N,)

    def body(acc, step):
        d, c, scale, rem = step
        qc = jax.lax.dynamic_index_in_dim(q_chunks, c, keepdims=False)
        # on-the-fly digit (the pinned shared arithmetic), with rows past
        # their budget (and planes past npl <= max budget) zeroed
        digit = sd_digit_plane(qc, n_bits, d).astype(jnp.float32) \
            * (row_budget > d).astype(jnp.float32)[:, None]
        wc = jax.lax.dynamic_index_in_dim(w_chunks, c, keepdims=False)
        # weight-side MSR bound: columns of a tile whose static plane bound
        # is exhausted freeze — the kernel's per-j SMEM bound predicate
        contrib = scale * jnp.dot(digit, wc,
                                  preferred_element_type=jnp.float32)
        acc = acc + contrib * (bound_cols > d).astype(jnp.float32)[None, :]
        bound = acc + rem[None, :]
        dead = jnp.all(bound.reshape(Mt, block_m, Nt, block_n) < 0.0,
                       axis=(1, 3))                             # (Mt, Nt)
        return acc, dead

    d_idx = jnp.repeat(jnp.arange(D), Kt)                       # plane per step
    c_idx = jnp.tile(jnp.arange(Kt), D)                         # w chunk per step
    acc, dead_after = jax.lax.scan(
        body, jnp.zeros((M, N), jnp.float32),
        (d_idx, c_idx, step_scale, step_rem))
    out = jnp.maximum(acc, 0.0) if relu else acc
    if relu:
        # only bound checks at steps the kernel actually enters (d < npl)
        # count; later (masked) steps can fire the stale bound spuriously,
        # but min() with npl makes them indistinguishable from no-fire.
        ever = jnp.any(dead_after, axis=0)
        first = jnp.argmax(dead_after, axis=0)                  # 0-based step
        used = jnp.where(ever, first // Kt + 1, D).astype(jnp.int32)
    else:
        used = jnp.full((Mt, Nt), D, jnp.int32)
    # a tile never runs past its weight-side bound (the kernel only counts
    # planes it actually enters); the npl clamp handles stale fires beyond
    used = jnp.minimum(used, tile_bound.astype(jnp.int32)[None, :])
    return out, jnp.minimum(used, npl.astype(jnp.int32))


def _run_backend(cfg: DslotWeights, q_p: jax.Array, w: jax.Array,
                 suffix: jax.Array, total: jax.Array, npl_scalar: jax.Array,
                 bud_p: jax.Array, bnd: jax.Array, D: int
                 ) -> tuple[jax.Array, jax.Array]:
    """One backend invocation on (a shard of) the prepared weights.

    ``w``/``suffix``/``total``/``bnd`` may be the full prepared arrays or a
    device-local N slice of them — both backends are column-independent, so
    the same code serves the single-device path and each shard_map body.
    Returns padded ``(out (Mp, N), planes_used (Mt, Nt))``.
    """
    if cfg.backend == "pallas":
        out_p, used = dslot_matmul_pallas(
            q_p, w, n_bits=cfg.n_bits, n_planes=D, relu=cfg.relu,
            block_m=cfg.block_m, block_n=cfg.block_n, block_k=cfg.block_k,
            n_planes_rt=npl_scalar, row_budget=bud_p,
            suffix_colsum=suffix, total_colsum=total,
            plane_bound=bnd, interpret=jax.default_backend() != "tpu")
        return out_p, jnp.minimum(used, npl_scalar.astype(jnp.int32))
    return _jnp_path(q_p, w, cfg.n_bits, D, cfg.relu,
                     cfg.block_m, cfg.block_n, cfg.block_k,
                     suffix, total[0], npl_scalar, bud_p, bnd)


def _sharded_exec(cfg: DslotWeights, q_p: jax.Array, npl_scalar: jax.Array,
                  bud_p: jax.Array, bnd: jax.Array, D: int
                  ) -> tuple[jax.Array, jax.Array]:
    """Tensor-parallel execute: N tiles shard over ``cfg.mesh[cfg.tp_axis]``.

    Activations (and the per-row budget / runtime precision scalar) are
    replicated; the prepared weight columns, colsum termination tables and
    per-tile MSR bounds split along N at tile granularity, so each device
    runs the identical kernel on its slice with its own termination state.
    When ``Nt`` does not divide the shard count, the layout is padded with
    all-zero tiles carrying plane bound 0 — exact no-ops by the ``core.msr``
    mechanism — and the pad is sliced off after the out_specs gather.
    Bit-identical to the unsharded path (both backends are column-
    independent); per-shard ``planes_used`` concatenates into the same
    global (Mt, Nt) table the stats reduction already consumes.
    """
    mesh, axis = cfg.mesh, cfg.tp_axis
    shards = int(mesh.shape[axis])
    Np = cfg.w.shape[1]
    Nt = Np // cfg.block_n
    Nt_pad = -(-Nt // shards) * shards
    extra = (Nt_pad - Nt) * cfg.block_n
    w_s = jnp.pad(cfg.w, [(0, 0), (0, extra)])
    sfx_s = jnp.pad(cfg.suffix_colsum, [(0, 0), (0, extra)])
    tot_s = jnp.pad(cfg.total_colsum, [(0, 0), (0, extra)])
    bnd_s = jnp.pad(bnd, (0, Nt_pad - Nt))      # pad tiles: bound 0 = inert

    def body(w_l, sfx_l, tot_l, bnd_l, q_l, bud_l, npl_l):
        return _run_backend(cfg, q_l, w_l, sfx_l, tot_l, npl_l, bud_l,
                            bnd_l, D)

    in_specs = (P(None, axis), P(None, axis), P(None, axis), P(axis),
                P(), P(), P())
    out_specs = (P(None, axis), P(None, axis))
    # the pallas backend has no replication rule, so the static vma/rep
    # checker is disabled (outputs are genuinely axis-sharded anyway)
    try:
        sm = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    except TypeError:                                  # older kwarg name
        sm = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    out_p, used = sm(w_s, sfx_s, tot_s, bnd_s, q_p, bud_p, npl_scalar)
    return out_p[:, :Np], used[:, :Nt]


def _execute_core(prepared: DslotWeights, x: jax.Array, npl: jax.Array,
                  static_planes: int | None = None
                  ) -> tuple[jax.Array, DslotStats]:
    """Shared execute path.  ``npl`` is i32, scalar or per-row (M,).

    ``static_planes`` (fused one-shot path only) additionally shrinks the
    kernel grid's plane axis to a STATIC depth — the split path keeps the
    grid at ``n_bits`` and predicates instead, trading a few empty grid
    steps for zero retraces.

    No digit-plane tensor is built here: the quantized activations go to the
    backends as-is (at the narrowest integer width that holds them) and each
    backend derives digit planes on the fly — the paper's online generation,
    not an HBM-materialized encoding.  Per-row budgets ride along as a
    runtime vector consumed inside the kernel (SMEM per-M-tile) / scan.
    """
    cfg = prepared
    M, K = x.shape
    assert K == cfg.d_in, (x.shape, cfg.d_in)

    q, step = quantize_activations(x, n_bits=cfg.n_bits, signed=cfg.signed,
                                   scale=cfg.x_scale)
    D = min(static_planes or cfg.n_bits, cfg.n_bits)

    if npl.ndim == 1:
        row_budget = jnp.clip(npl, 1, D)
        npl_scalar = jnp.max(row_budget)
        budget_f = row_budget.astype(jnp.float32)
    else:
        row_budget = None
        npl_scalar = jnp.clip(npl, 1, D)
        budget_f = npl_scalar.astype(jnp.float32)

    q_p = _pad_to(q.astype(q_storage_dtype(cfg.n_bits, cfg.signed)),
                  cfg.block_m, axis=0)
    if q_p.shape[1] < cfg.w.shape[0]:           # match prepared K padding
        q_p = jnp.pad(q_p, [(0, 0), (0, cfg.w.shape[0] - q_p.shape[1])])
    Mp = q_p.shape[0]
    # per-row budget over the padded rows (pad rows: zero budget = all-zero
    # digits, same as the old zero plane padding); scalar budgets broadcast
    bud_p = jnp.full((Mp,), npl_scalar, jnp.int32) if row_budget is None \
        else jnp.pad(row_budget.astype(jnp.int32), (0, Mp - M))

    Nt = cfg.w.shape[1] // cfg.block_n
    bnd = jnp.full((Nt,), D, jnp.int32) if cfg.msr_bound is None \
        else jnp.minimum(cfg.msr_bound.astype(jnp.int32), D)

    if cfg.mesh is not None:
        out_p, used = _sharded_exec(cfg, q_p, npl_scalar, bud_p, bnd, D)
    else:
        out_p, used = _run_backend(cfg, q_p, cfg.w, cfg.suffix_colsum,
                                   cfg.total_colsum, npl_scalar, bud_p,
                                   bnd, D)

    out = out_p[:M, :cfg.d_out] * step
    if cfg.inv_perm is not None:
        out = out[:, cfg.inv_perm]

    # per-row effective planes: tile usage spread over its rows, clipped to
    # each row's own budget — the per-request energy account for serving.
    rows_used = jnp.repeat(used.astype(jnp.float32).mean(axis=1),
                           cfg.block_m, total_repeat_length=used.shape[0]
                           * cfg.block_m)[:M]
    if row_budget is not None:
        rows_used = jnp.minimum(rows_used, budget_f)
        skipped = 1.0 - jnp.mean(rows_used) / jnp.maximum(
            jnp.mean(budget_f), 1.0)
    else:
        skipped = 1.0 - jnp.mean(used.astype(jnp.float32)) / budget_f
    # weight-side never-issued planes: the static MSR bound capped tile j
    # below the call's granted budget — the same for every M-tile/row, so
    # it broadcasts; skipped_frac above already compounds with it (the
    # bound caps planes_used), this field attributes the static share.
    bounded = jnp.broadcast_to(
        jnp.maximum(npl_scalar.astype(jnp.int32) - bnd, 0)[None, :],
        used.shape)
    return out, DslotStats(planes_used=used, n_planes=D,
                           skipped_frac=skipped, row_planes_used=rows_used,
                           planes_bounded=bounded)


@jax.jit
def _dslot_execute_jit(prepared: DslotWeights, x: jax.Array, npl: jax.Array
                       ) -> tuple[jax.Array, DslotStats]:
    return _execute_core(prepared, x, npl)


def dslot_execute(prepared: DslotWeights, x: jax.Array, *,
                  n_planes=None) -> tuple[jax.Array, DslotStats]:
    """Per-request execution against prepared weights: ``[relu](x @ w)``.

    ``x``: (M, d_in) float activations.
    ``n_planes``: runtime precision — None (full ``n_bits``), a python int /
    i32 scalar, or a per-row (M,) i32 vector (serving: one budget per slot).
    Runtime values share one trace; only the scalar/vector distinction (and
    new shapes) retraces.
    """
    if n_planes is None:
        n_planes = prepared.n_bits
    npl = jnp.asarray(n_planes, jnp.int32)
    return _dslot_execute_jit(prepared, x, npl)


@functools.partial(jax.jit, static_argnames=(
    "n_bits", "n_planes", "relu", "block_m", "block_n", "block_k", "backend",
    "sort_columns", "signed"))
def _dslot_matmul_fused(x: jax.Array, w: jax.Array, *, n_bits: int = 8,
                        n_planes: int | None = None, relu: bool = True,
                        block_m: int = 128, block_n: int = 128,
                        block_k: int | None = None,
                        backend: str = "auto", sort_columns: bool = False,
                        signed: bool = False
                        ) -> tuple[jax.Array, DslotStats]:
    D = min(n_planes or n_bits, n_bits)
    prepared = dslot_prepare(
        w, n_bits=n_bits, relu=relu, signed=signed,
        sort_columns=sort_columns, block_m=block_m, block_n=block_n,
        block_k=block_k, backend=backend)
    return _execute_core(prepared, x, jnp.asarray(D, jnp.int32),
                         static_planes=D)


def dslot_matmul(x: jax.Array, w: jax.Array, *, n_bits: int = 8,
                 n_planes: int | None = None, relu: bool = True,
                 block_m: int = 128, block_n: int = 128,
                 block_k: int | None = None,
                 backend: str = "auto", sort_columns: bool = False,
                 signed: bool = False
                 ) -> tuple[jax.Array, DslotStats]:
    """Fused one-shot digit-serial matmul: prepare + execute in one jit.

    Kept for benchmarks and ad-hoc calls; layers and serving use the split
    ``dslot_prepare``/``dslot_execute`` so weight lowering is amortized.
    ``n_planes`` here is STATIC (the kernel grid shrinks); use
    ``dslot_execute`` for runtime precision.

    Weight-side grid trim: since ``n_planes`` is static here, a concrete
    ``w`` whose global MSR plane bound is below ``n_bits`` (every column
    output-inert — the bound is a per-column property, invariant under the
    prepare-time sort/pad) shrinks the static plane axis itself, not just
    the per-tile predicate (clamped to one plane: the grid cannot be
    empty, and planes beyond a tile's bound are exact no-ops).  Traced
    callers (``w`` under jit) skip the eager check and rely on the
    per-tile SMEM bound inside the kernel.
    """
    D = min(n_planes or n_bits, n_bits)
    if not isinstance(w, jax.core.Tracer):
        import numpy as np
        wn = np.asarray(jax.device_get(w))
        inert = (wn == 0.0).all(axis=0)
        if relu and not signed:
            inert |= (wn <= 0.0).all(axis=0)
        if bool(inert.all()):
            D = 1
    return _dslot_matmul_fused(
        x, w, n_bits=n_bits, n_planes=D, relu=relu, block_m=block_m,
        block_n=block_n, block_k=block_k, backend=backend,
        sort_columns=sort_columns, signed=signed)
