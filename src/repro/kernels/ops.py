"""Framework-facing ops for the digit-plane DSLOT engine.

``dslot_matmul`` is the public entry point used by model layers and the
serving engine.  It handles quantization, MSDF plane decomposition, block
padding, backend selection and dequantization:

* ``backend="pallas"`` — the Pallas kernel (interpret mode on CPU, compiled on
  TPU).  Real per-tile early termination: skipped MXU passes.
* ``backend="jnp"``    — pure-jnp evaluation with *identical semantics and
  identical termination statistics* (the bound math is evaluated vectorized,
  but all planes are computed) — fast on CPU, used for large-shape stats.
* ``backend="auto"``   — pallas on TPU, jnp elsewhere.

Beyond-paper optimization (``sort_columns=True``): weight-stationary column
reordering.  Tile termination requires *spatially clustered* dead outputs;
sorting output columns by their weight column-sum (a static, offline
permutation — weights are stationary, exactly the paper's dataflow assumption)
clusters ReLU-dead neurons into contiguous tiles, which measurably raises the
skipped-pass fraction (see EXPERIMENTS.md §Perf).  The inverse permutation is
applied to the output, so results are unchanged.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .dslot_matmul import dslot_matmul_pallas
from .ref import dslot_matmul_ref, make_planes

__all__ = ["DslotStats", "dslot_matmul", "quantize_activations"]


class DslotStats(NamedTuple):
    planes_used: jax.Array      # (Mt, Nt) int32 — MXU passes per output tile
    n_planes: int               # D
    skipped_frac: jax.Array     # scalar — fraction of plane-passes skipped


def quantize_activations(x: jax.Array, n_bits: int = 8, signed: bool = False
                         ) -> tuple[jax.Array, jax.Array]:
    """Symmetric activation quantization -> (q int32, step float32)."""
    qmax = float(2 ** n_bits - 1 if not signed else 2 ** (n_bits - 1) - 1)
    amax = jnp.maximum(jnp.max(jnp.abs(x)) if signed else jnp.max(x), 1e-12)
    step = amax / qmax
    lo = -qmax if signed else 0.0
    q = jnp.clip(jnp.round(x / step), lo, qmax).astype(jnp.int32)
    return q, step


def _pad_to(x: jax.Array, m: int, axis: int) -> jax.Array:
    r = (-x.shape[axis]) % m
    if r == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, r)
    return jnp.pad(x, pads)


def _jnp_path(planes: jax.Array, w: jax.Array, n_bits: int, relu: bool,
              block_m: int, block_n: int):
    """Reference evaluation + vectorized termination accounting.

    Computes every plane (no skipping — this is CPU) but derives the exact
    per-tile ``planes_used`` the Pallas kernel would report, by replaying the
    bound check over the plane-wise cumulative accumulators.
    """
    D, M, K = planes.shape
    N = w.shape[1]
    wf = w.astype(jnp.float32)
    scales = jnp.exp2(jnp.asarray(n_bits - 1, jnp.float32)
                      - jnp.arange(D, dtype=jnp.float32))
    partial = jnp.einsum("dmk,kn->dmn", planes.astype(jnp.float32), wf,
                         preferred_element_type=jnp.float32)
    cum = jnp.cumsum(scales[:, None, None] * partial, axis=0)   # (D, M, N)
    out = cum[-1]
    if relu:
        out = jnp.maximum(out, 0.0)

    # Termination replay: tile (i,j) is dead after plane d if every element's
    # optimistic bound is < 0.
    colsum = jnp.sum(jnp.abs(wf), axis=0)                       # (N,)
    rem = (scales - 2.0 ** (n_bits - D))[:, None]               # (D, 1)
    bound = cum + (rem * colsum[None, :])[:, None, :]           # (D, M, N)
    Mt, Nt = M // block_m, N // block_n
    tiles = bound.reshape(D, Mt, block_m, Nt, block_n)
    dead_after = jnp.all(tiles < 0.0, axis=(2, 4))              # (D, Mt, Nt)
    if relu:
        ever = jnp.any(dead_after, axis=0)
        first = jnp.argmax(dead_after, axis=0)                  # 0-based plane
        used = jnp.where(ever, first + 1, D).astype(jnp.int32)
    else:
        used = jnp.full((Mt, Nt), D, jnp.int32)
    return out, used


@functools.partial(jax.jit, static_argnames=(
    "n_bits", "n_planes", "relu", "block_m", "block_n", "backend",
    "sort_columns", "signed"))
def dslot_matmul(x: jax.Array, w: jax.Array, *, n_bits: int = 8,
                 n_planes: int | None = None, relu: bool = True,
                 block_m: int = 128, block_n: int = 128,
                 backend: str = "auto", sort_columns: bool = False,
                 signed: bool = False
                 ) -> tuple[jax.Array, DslotStats]:
    """Digit-serial (MSDF digit-plane) matmul: ``[relu](x @ w)``.

    ``x`` (M, K) float — activations, quantized here to ``n_bits``.
    ``w`` (K, N) float — weights (kept full precision: the serial-parallel OLM
    takes the weight operand in parallel, so only the streamed activation is
    digit-decomposed; this matches the paper's serial x / parallel Y split).
    ``n_planes`` — runtime precision knob (D <= n_bits), the paper's
    "precision tuned at run time".
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    D = n_planes or n_bits
    M, K = x.shape
    N = w.shape[1]

    q, step = quantize_activations(x, n_bits=n_bits, signed=signed)
    planes = make_planes(q, n_bits, n_planes=D)                 # (D, M, K)

    perm = None
    if sort_columns:
        perm = jnp.argsort(jnp.sum(w, axis=0))                  # dead cols first
        w = w[:, perm]

    planes_p = _pad_to(planes, block_m, axis=1)
    w_p = _pad_to(w.astype(jnp.float32), block_n, axis=1)

    if backend == "pallas":
        out_p, used = dslot_matmul_pallas(
            planes_p, w_p, n_bits=n_bits, relu=relu,
            block_m=block_m, block_n=block_n,
            interpret=jax.default_backend() != "tpu")
        out_p = out_p
    else:
        out_p, used = _jnp_path(planes_p, w_p, n_bits, relu, block_m, block_n)

    out = out_p[:M, :N] * step
    if perm is not None:
        inv = jnp.argsort(perm)
        out = out[:, inv]

    skipped = 1.0 - jnp.mean(used.astype(jnp.float32)) / D
    return out, DslotStats(planes_used=used, n_planes=D, skipped_frac=skipped)
