"""Framework-facing ops for the digit-plane DSLOT engine.

``dslot_matmul`` is the public entry point used by model layers and the
serving engine.  It handles quantization, MSDF plane decomposition, block
padding, backend selection and dequantization:

* ``backend="pallas"`` — the Pallas kernel (interpret mode on CPU, compiled on
  TPU).  Real per-tile early termination: skipped MXU passes.
* ``backend="jnp"``    — pure-jnp evaluation with *identical semantics and
  identical termination statistics* (the bound math is evaluated vectorized,
  but all planes are computed) — fast on CPU, used for large-shape stats.
* ``backend="auto"``   — pallas on TPU, jnp elsewhere.

Beyond-paper optimization (``sort_columns=True``): weight-stationary column
reordering.  Tile termination requires *spatially clustered* dead outputs;
sorting output columns by their weight column-sum (a static, offline
permutation — weights are stationary, exactly the paper's dataflow assumption)
clusters ReLU-dead neurons into contiguous tiles, which measurably raises the
skipped-pass fraction (see EXPERIMENTS.md §Perf).  The inverse permutation is
applied to the output, so results are unchanged.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .dslot_matmul import _pad_to, dslot_matmul_pallas, select_block_k
from .ref import dslot_matmul_ref, make_planes

__all__ = ["DslotStats", "dslot_matmul", "quantize_activations"]


class DslotStats(NamedTuple):
    planes_used: jax.Array      # (Mt, Nt) int32 — MXU passes per output tile
    n_planes: int               # D
    skipped_frac: jax.Array     # scalar — fraction of plane-passes skipped


def quantize_activations(x: jax.Array, n_bits: int = 8, signed: bool = False
                         ) -> tuple[jax.Array, jax.Array]:
    """Symmetric activation quantization -> (q int32, step float32)."""
    qmax = float(2 ** n_bits - 1 if not signed else 2 ** (n_bits - 1) - 1)
    amax = jnp.maximum(jnp.max(jnp.abs(x)) if signed else jnp.max(x), 1e-12)
    step = amax / qmax
    lo = -qmax if signed else 0.0
    q = jnp.clip(jnp.round(x / step), lo, qmax).astype(jnp.int32)
    return q, step


def _jnp_path(planes: jax.Array, w: jax.Array, n_bits: int, relu: bool,
              block_m: int, block_n: int, block_k: int | None):
    """Reference evaluation + termination accounting.

    Computes every plane (no skipping — this is CPU) but derives the exact
    per-tile ``planes_used`` the Pallas kernel would report, by replaying the
    chunk-aware bound check in the kernel's (plane outer, K-chunk inner)
    iteration order.  A ``lax.scan`` over the D*Kt steps keeps peak memory at
    O(M*N) regardless of how small ``block_k`` is (only the per-step per-tile
    dead flags, (D*Kt, Mt, Nt) booleans, are stacked).
    """
    D, M, K = planes.shape
    N = w.shape[1]
    bk = block_k or select_block_k(K, block_m, block_n, 4)
    if K % bk:
        planes = _pad_to(planes, bk, axis=2)
        w = _pad_to(w, bk, axis=0)
        K = w.shape[0]
    Kt = K // bk
    Mt, Nt = M // block_m, N // block_n
    wf = w.astype(jnp.float32)
    w_chunks = wf.reshape(Kt, bk, N)
    # int8 plane chunks in step order (d outer, c inner): (D*Kt, M, bk)
    p_chunks = planes.reshape(D, M, Kt, bk).transpose(0, 2, 1, 3) \
        .reshape(D * Kt, M, bk)
    scales = jnp.exp2(jnp.asarray(n_bits - 1, jnp.float32)
                      - jnp.arange(D, dtype=jnp.float32))
    step_scale = jnp.repeat(scales, Kt)                         # (D*Kt,)

    # Remaining-contribution bound after step (d, c):
    # scale_d * suffix_colsum[c] + (scale_d - 2^(n-D)) * total.
    chunk_colsum = jnp.sum(jnp.abs(w_chunks), axis=1)           # (Kt, N)
    total = jnp.sum(chunk_colsum, axis=0)                       # (N,)
    suffix = total[None, :] - jnp.cumsum(chunk_colsum, axis=0)  # (Kt, N)
    step_rem = (scales[:, None, None] * suffix[None, :, :]
                + ((scales - 2.0 ** (n_bits - D))[:, None, None]
                   * total[None, None, :])).reshape(D * Kt, N)

    def body(acc, step):
        p, c, scale, rem = step
        wc = jax.lax.dynamic_index_in_dim(w_chunks, c, keepdims=False)
        acc = acc + scale * jnp.dot(p.astype(jnp.float32), wc,
                                    preferred_element_type=jnp.float32)
        bound = acc + rem[None, :]
        dead = jnp.all(bound.reshape(Mt, block_m, Nt, block_n) < 0.0,
                       axis=(1, 3))                             # (Mt, Nt)
        return acc, dead

    c_idx = jnp.tile(jnp.arange(Kt), D)                         # w chunk per step
    acc, dead_after = jax.lax.scan(
        body, jnp.zeros((M, N), jnp.float32),
        (p_chunks, c_idx, step_scale, step_rem))
    out = jnp.maximum(acc, 0.0) if relu else acc
    if relu:
        ever = jnp.any(dead_after, axis=0)
        first = jnp.argmax(dead_after, axis=0)                  # 0-based step
        used = jnp.where(ever, first // Kt + 1, D).astype(jnp.int32)
    else:
        used = jnp.full((Mt, Nt), D, jnp.int32)
    return out, used


@functools.partial(jax.jit, static_argnames=(
    "n_bits", "n_planes", "relu", "block_m", "block_n", "block_k", "backend",
    "sort_columns", "signed"))
def dslot_matmul(x: jax.Array, w: jax.Array, *, n_bits: int = 8,
                 n_planes: int | None = None, relu: bool = True,
                 block_m: int = 128, block_n: int = 128,
                 block_k: int | None = None,
                 backend: str = "auto", sort_columns: bool = False,
                 signed: bool = False
                 ) -> tuple[jax.Array, DslotStats]:
    """Digit-serial (MSDF digit-plane) matmul: ``[relu](x @ w)``.

    ``x`` (M, K) float — activations, quantized here to ``n_bits``.
    ``w`` (K, N) float — weights (kept full precision: the serial-parallel OLM
    takes the weight operand in parallel, so only the streamed activation is
    digit-decomposed; this matches the paper's serial x / parallel Y split).
    ``n_planes`` — runtime precision knob (D <= n_bits), the paper's
    "precision tuned at run time".
    ``block_k`` — K chunk streamed through VMEM (None = auto-select the
    largest chunk fitting the VMEM budget); both backends replay the same
    chunk-aware termination bound, so ``planes_used`` agrees.
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    # make_planes can only produce n_bits planes; clamp so planes_used /
    # skipped_frac never report savings against planes that don't exist.
    D = min(n_planes or n_bits, n_bits)
    M, K = x.shape
    N = w.shape[1]

    q, step = quantize_activations(x, n_bits=n_bits, signed=signed)
    planes = make_planes(q, n_bits, n_planes=D)                 # (D, M, K)

    perm = None
    if sort_columns:
        perm = jnp.argsort(jnp.sum(w, axis=0))                  # dead cols first
        w = w[:, perm]

    planes_p = _pad_to(planes, block_m, axis=1)
    w_p = _pad_to(w.astype(jnp.float32), block_n, axis=1)

    if backend == "pallas":
        out_p, used = dslot_matmul_pallas(
            planes_p, w_p, n_bits=n_bits, relu=relu,
            block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=jax.default_backend() != "tpu")
    else:
        out_p, used = _jnp_path(planes_p, w_p, n_bits, relu,
                                block_m, block_n, block_k)

    out = out_p[:M, :N] * step
    if perm is not None:
        inv = jnp.argsort(perm)
        out = out[:, inv]

    skipped = 1.0 - jnp.mean(used.astype(jnp.float32)) / D
    return out, DslotStats(planes_used=used, n_planes=D, skipped_frac=skipped)
