"""Serving engine: slot-pool continuous batching with a chunked-prefill
admission pipeline, DSLOT digit-serial execution mode, per-request QoS
tiers under an optional SLO control loop, and streaming token output.

``generate`` is the simple batch API (prefill once, decode N tokens); it
returns a :class:`repro.serve.result.GenerateResult` — tokens plus the
per-request planes-executed account when the DSLOT path is on.  The old
``return_stats=True`` tuple form still works through a deprecation shim.

``ServeEngine`` is the production shape: a fixed pool of B slots; decode
steps advance every live slot together (one jitted step for the whole
pool), finished slots free up immediately.  Construction takes exactly
``(model, params, cfg: ServeConfig)`` — pool geometry, admission knobs,
sampler, precision policy and SLO config all live on the config (the old
``n_slots=``/``max_len=``/``sample=``/``precision_policy=``/
``serve_config=`` keywords are mapped onto a config by a warn-once
deprecation shim).  Admission is NON-BLOCKING and BATCHED: ``try_add`` only
validates and enqueues; the engine's step loop interleaves one batched
admission forward per decode step — up to ``ServeConfig.chunks_per_step``
PREFILLING requests each advance by one fixed-size ``prefill_chunk`` of
prompt, stacked into a single ragged-offset forward (executed by
``repro.serve.prefill.PrefillPipeline``) — so admitting long prompts never
stalls the pool for a full-prompt forward, and a burst of admissions drains
``chunks_per_step`` prompts at a time.  A request moves through PENDING ->
PREFILLING -> DECODING -> DONE (``Request.phase``), and its slot joins the
pooled decode the very step its last prompt chunk lands.

Streaming: every emitted token is pushed through ``Request.on_token`` (when
set) the step it is sampled, and ``Request.token_steps`` records the engine
step of each token — so TTFT and inter-token latency are externally
observable per token, not just engine-internal counters.
``ServeEngine.stream(req)`` wraps both as a generator handle that drives
the engine and yields tokens as they land.

Per-slot position vectors (threaded through the model's per-sequence
KV-cache ring) make the batch composition fully dynamic without
recompilation — merging a finished prefill into a non-empty pool never
disturbs other slots' decode positions, and chunked admission stays
token-exact versus a solo ``generate`` of the same prompt (in DSLOT mode
this additionally requires a calibrated ``DslotConfig.act_scale``: the
per-call-max quantization fallback is not invariant to how a prompt is
split into chunks — ``try_add`` REJECTS budgeted multi-chunk admissions on
an uncalibrated model instead of silently drifting; see ``kernels/ops.py``
and ``docs/serving.md``).

Hardening (``docs/serving.md``, "Failure modes and recovery"): ``step()``
NEVER raises.  Exceptions from admission or decode forwards are absorbed
with bounded retry (``ServeConfig.max_step_retries``) and logged to
``ServeEngine.errors``; state commits are transactional, so a failed step
leaves queue/slots/lanes exactly where they were and
``ServeEngine.check_invariants()`` (``serve/health.py``) passes after every
tick.  Non-finite logit rows quarantine exactly the poisoned slot
(``phase == "quarantined"``) — surviving co-batched requests keep their
bit-exact token streams, the same isolation bar as cancel-mid-batch.
Per-request deadlines (``Request.deadline_steps`` /
``ServeConfig.default_deadline_steps``) evict overdue requests wherever
they are (``phase == "timeout"``) and feed the SLO controller as pressure.
``drain()``/``close()`` give a graceful shutdown path, and the whole
failure surface is exercisable on demand through the deterministic fault
plane in ``serve/faults.py`` (``ServeConfig.faults``).

DSLOT serving mode (``cfg.dslot.enabled`` + ReLU MLPs): the engine prepares
the model's weight-stationary plane tables ONCE at construction
(``Model.prepare_dslot``), every request carries its own digit-plane budget
(explicit ``Request.n_planes`` or assigned by a ``repro.runtime`` precision
policy at enqueue time), prefill chunks and the pooled decode step execute
each request's rows at that request's precision (a runtime argument — no
retrace across precisions), and the per-request planes-executed account is
fed back to the policy when the request finishes (the ``AdaptiveBudget``
loop).  With ``ServeConfig.slo`` set, a ``repro.serve.slo.SloController``
additionally clamps every slot's budget to its QoS tier's current plane
level each step — shedding planes under burst, restoring them under slack
— which is the load side of the paper's run-time-tunable precision.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from dataclasses import dataclass, field
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import DslotWeights
from repro.models import stats as stats_channel
from repro.models.attention import cache_capacity
from repro.models.mlp import mlp_uses_dslot
from repro.models.model_zoo import Model
from repro.runtime import PolicyFeedback, precision_scope
from repro.serve.config import ServeConfig
from repro.serve.faults import FaultInjector
from repro.serve.prefill import (CANCELLED, DECODING, DONE, FAILED,
                                 PREFILLING, QUARANTINED, TIMEOUT,
                                 PrefillPipeline, _batch_axes)
from repro.serve.result import GenerateResult
from repro.serve.slo import STANDARD, TIERS, SloController, SloSignals

_ROWKEY = "mlp_up_dslot.row_planes_used"
_BNDKEY = "mlp_up_dslot.planes_bounded_mean"

# one DeprecationWarning per legacy surface per process — enough to nudge a
# migration without drowning a driving loop in repeats
_LEGACY_WARNED: set[str] = set()


def _warn_once(key: str, msg: str) -> None:
    if key in _LEGACY_WARNED:
        return
    _LEGACY_WARNED.add(key)
    warnings.warn(msg, DeprecationWarning, stacklevel=3)


def greedy_sample(logits: jax.Array, key=None) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(logits: jax.Array, key, temp: float = 0.8) -> jax.Array:
    return jax.random.categorical(key, logits / temp, axis=-1).astype(jnp.int32)


def _collapse_rows(sink: dict, batch: int) -> jax.Array | None:
    """Average the per-row planes-executed records of every DSLOT MLP call
    into one (B,) vector.  Records may be (B,) (plain layers) or carry
    leading stack axes from scan-over-layers; collapse those by mean."""
    vals = []
    for v in sink.get(_ROWKEY, []):
        v = jnp.asarray(v, jnp.float32)
        while v.ndim > 1:
            v = v.mean(axis=0)
        if v.shape == (batch,):
            vals.append(v)
    if not vals:
        return None
    return jnp.mean(jnp.stack(vals), axis=0)


def _collapse_bounded(sink: dict) -> jax.Array | None:
    """Mean weight-side never-issued planes per tile across the step's DSLOT
    MLP calls (scalar — the static MSR bound is request-independent)."""
    vals = [jnp.mean(jnp.asarray(v, jnp.float32))
            for v in sink.get(_BNDKEY, [])]
    if not vals:
        return None
    return jnp.mean(jnp.stack(vals))


def generate(model: Model, params, batch: dict, max_new_tokens: int,
             *, max_len: int | None = None, sample=greedy_sample,
             key=None, n_planes=None, return_stats: bool | None = None
             ) -> GenerateResult:
    """Prefill + greedy/temperature decode.  Returns a ``GenerateResult``
    (``.tokens`` is (B, max_new_tokens); the DSLOT planes-executed account
    rides along when the digit-serial path is on).

    ``n_planes``: runtime DSLOT precision — int or per-request (B,) i32
    vector (ignored unless the model's digit-serial MLP path is enabled).

    ``return_stats`` is DEPRECATED: ``True`` returns the legacy
    ``(tokens, stats_dict)`` tuple, ``False`` the bare tokens array — both
    warn once.  Leave it unset for the ``GenerateResult``.
    """
    if return_stats is not None:
        _warn_once(
            "generate.return_stats",
            "generate(return_stats=...) is deprecated; generate() now "
            "returns a GenerateResult — use .tokens / .planes_used_mean / "
            ".skipped_frac")
    B, S = batch["tokens"].shape
    if model.cfg.frontend and "frontend" in batch:
        S += batch["frontend"].shape[1]
    max_len = max_len or (S + max_new_tokens)
    if n_planes is not None:
        n_planes = jnp.asarray(n_planes, jnp.int32)
        if n_planes.ndim == 0:
            n_planes = jnp.full((B,), n_planes, jnp.int32)
    # stats collection is trace-time gated (no dead work when off): on by
    # default exactly when the DSLOT path can produce them
    want_stats = mlp_uses_dslot(model.cfg) if return_stats is None \
        else bool(return_stats)

    with precision_scope(n_planes):
        logits, state = model.prefill(params, batch, max_len=max_len)
        tok = sample(logits) if key is None else sample(logits, key)

        def step(carry, _):
            tok, state, key = carry
            if want_stats:
                with stats_channel.collect() as sink:
                    lg, state = model.decode_step(params, state, tok[:, None])
                rows = _collapse_rows(sink, B)
                bnd = _collapse_bounded(sink)
                st = {} if rows is None else {"rows": rows}
                if bnd is not None:
                    st["bounded"] = bnd
            else:
                lg, state = model.decode_step(params, state, tok[:, None])
                st = {}
            if key is not None:
                key, sub = jax.random.split(key)
                nxt = sample(lg, sub)
            else:
                nxt = sample(lg)
            return (nxt, state, key), (tok, st)

        (_, _, _), (toks, sts) = jax.lax.scan(
            step, (tok, state, key), None, length=max_new_tokens)
    toks = jnp.moveaxis(toks, 0, 1)                    # (B, max_new)
    granted = used = skipped = None
    if "rows" in sts:
        used = jnp.mean(sts["rows"], axis=0)           # (B,)
        if n_planes is not None:
            granted = n_planes
            budget = n_planes.astype(jnp.float32)
        else:
            # no explicit budget: layers ran at their static default
            granted = budget = float(model.cfg.dslot.n_planes
                                     or model.cfg.dslot.n_bits)
        skipped = 1.0 - used / budget
    bounded = jnp.mean(sts["bounded"]) if "bounded" in sts else None
    result = GenerateResult(tokens=toks, n_planes=granted,
                            planes_used_mean=used, skipped_frac=skipped,
                            planes_bounded_mean=bounded,
                            steps=max_new_tokens, phase=DONE)
    if return_stats is True:
        return toks, result.stats
    if return_stats is False:
        return toks
    return result


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (S,) int32
    max_new: int
    n_planes: int | None = None        # per-request DSLOT precision (None =
                                       # policy-assigned or full n_bits)
    tier: str = STANDARD               # QoS tier (repro.serve.slo.TIERS)
    deadline_steps: int | None = None  # engine steps from enqueue before
                                       # timeout eviction (None = engine's
                                       # ServeConfig.default_deadline_steps)
    on_token: Callable | None = None   # streaming: called (req, token, step)
                                       # the step each token is emitted
    out: list = field(default_factory=list)
    token_steps: list = field(default_factory=list)  # engine step per token
    done: bool = False
    dslot_stats: dict | None = None    # set on finish in DSLOT mode
    result: GenerateResult | None = None  # set on finish / cancel-in-pool
    phase: str = "new"                 # pending|prefilling|decoding|done|...
    enqueue_step: int | None = None    # engine step count at try_add
    first_token_step: int | None = None  # step that emitted out[0]

    @property
    def ttft_steps(self) -> int | None:
        """Engine steps from enqueue to first emitted token."""
        if self.enqueue_step is None or self.first_token_step is None:
            return None
        return self.first_token_step - self.enqueue_step


def _dslot_calibrated(params) -> bool:
    """True iff every prepared ``DslotWeights`` in the tree carries a
    calibrated activation scale (False when none are found)."""
    found, ok = [False], [True]

    def walk(node):
        if isinstance(node, DslotWeights):
            found[0] = True
            if node.x_scale is None:
                ok[0] = False
        elif isinstance(node, dict):
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(params)
    return found[0] and ok[0]


class ServeEngine:
    """Slot-pool continuous batching on a single jitted decode step, with
    chunked-prefill admission interleaved into the step loop and an
    optional SLO plane-shedding control loop."""

    def __init__(self, model: Model, params,
                 cfg: ServeConfig | None = None, *,
                 n_slots: int | None = None, max_len: int | None = None,
                 sample: Callable | None = None,
                 precision_policy=None,
                 serve_config: ServeConfig | None = None):
        legacy = {k: v for k, v in (("n_slots", n_slots),
                                    ("max_len", max_len),
                                    ("sample", sample),
                                    ("precision_policy", precision_policy))
                  if v is not None}
        if serve_config is not None or legacy:
            # deprecation shim: fold the accreted keywords onto a ServeConfig
            if cfg is not None:
                raise TypeError(
                    "pass either cfg=ServeConfig(...) or the legacy "
                    "keywords, not both")
            _warn_once(
                "ServeEngine.kwargs",
                "ServeEngine(model, params, n_slots=..., max_len=..., "
                "serve_config=...) is deprecated; pass a single "
                "ServeConfig: ServeEngine(model, params, ServeConfig("
                "n_slots=..., max_len=..., ...))")
            cfg = dataclasses.replace(serve_config or ServeConfig(), **legacy)
        self.cfg = cfg or ServeConfig()
        self.model = model
        self.dslot = mlp_uses_dslot(model.cfg)
        if self.cfg.mesh is not None:
            # tensor-parallel serving: the DSLOT layers shard via the mesh
            # baked into their prepared state below; the dense projections
            # pick up GSPMD constraints through the pspec registry — both
            # inside the SAME per-step jit, so one engine step still issues
            # exactly one (sharded) forward.
            from repro.models import pspec
            pspec.set_mesh(self.cfg.mesh)
        # one-time weight-stationary lowering: every decode step executes
        # against cached digit-plane tables (no per-call re-encode)
        self.params = model.prepare_dslot(
            params, mesh=self.cfg.mesh,
            tp_axis=self.cfg.tp_axis) if self.dslot else params
        self.n_slots = self.cfg.n_slots
        self.max_len = self.cfg.max_len
        self.sample = self.cfg.sample or greedy_sample
        self.policy = self.cfg.precision_policy
        self.n_bits = model.cfg.dslot.n_bits
        self.calibrated = (not self.dslot) or _dslot_calibrated(self.params)
        self.slo: SloController | None = None if self.cfg.slo is None \
            else SloController(self.n_bits, self.cfg.slo)
        self.state = model.init_decode_state(self.n_slots, self.max_len)
        self.slot_req: list[Request | None] = [None] * self.n_slots
        self.next_tok = np.zeros(self.n_slots, np.int32)
        self.last_budget: np.ndarray | None = None  # budgets of last decode
        self._acc_planes = np.zeros(self.n_slots, np.float64)
        self._acc_bounded = np.zeros(self.n_slots, np.float64)
        self._acc_steps = np.zeros(self.n_slots, np.int64)
        self._steps = 0
        self._ttft_obs: list[int] = []     # TTFTs landed since last signal
        self._last_rows_mean: float | None = None
        # hardening state: the fault log (step, site, repr(exc)) of every
        # absorbed exception, the quarantine/timeout eviction records, and
        # the optional deterministic fault-injection plane
        self.errors: list[tuple[int, str, str]] = []
        self.quarantined: list[tuple[int, int]] = []   # (step, uid)
        self.timeouts: list[tuple[int, int]] = []      # (step, uid)
        self.injector: FaultInjector | None = \
            None if self.cfg.faults is None else FaultInjector(self.cfg.faults)
        self._closed = False
        self._state_axes = None            # lazy: KV-corruption fault hook
        self.pipeline = PrefillPipeline(
            model=model, params=self.params, max_len=self.max_len,
            chunk=self.cfg.prefill_chunk,
            chunks_per_step=self.cfg.chunks_per_step,
            max_queue=self.cfg.max_queue,
            jit_chunks=self.cfg.jit_prefill,
            dslot=self.dslot, calibrated=self.calibrated,
            injector=self.injector)

        def _decode(p, st, t, npl):
            with stats_channel.collect() as sink, precision_scope(npl):
                lg, st2 = model.decode_step(p, st, t)
            rows = _collapse_rows(sink, self.n_slots)
            bnd = _collapse_bounded(sink)
            aux = {} if rows is None else {"rows": rows}
            if bnd is not None:
                aux["bounded"] = bnd
            # per-slot non-finite detection, fused into the step (one
            # reduce) — the quarantine guard reads it on the host
            aux["finite"] = jnp.all(jnp.isfinite(lg), axis=-1)
            return lg, st2, aux

        self._decode = jax.jit(_decode)

    @property
    def serve_config(self) -> ServeConfig:
        """Back-compat alias for the engine's config."""
        return self.cfg

    # ------------------------------------------------------------ requests

    def try_add(self, req: Request) -> bool:
        """Enqueue a request for admission — NON-blocking.

        No model work happens here: the request joins the FIFO admission
        queue and the step loop prefills it one ``prefill_chunk`` at a time,
        interleaved with pooled decode steps.  Returns False only when the
        admission queue is full (``ServeConfig.max_queue``) — retry later.

        Requests that can NEVER run are rejected immediately with
        ``ValueError``: an empty prompt, a non-1-D or non-integer-dtype
        prompt, token ids outside ``[0, vocab_size)`` (either would poison
        the shared embedding gather / KV ring for co-batched requests), a
        non-positive generation budget, ``len(prompt) + max_new > max_len``
        (the KV ring would wrap and silently corrupt the sequence
        mid-decode), a whole-prompt admission (``prefill_chunk == 0``)
        whose prompt exceeds the KV ring capacity (for SWA the ring is only
        ``window`` wide — a one-chunk ingest would wrap and evict its own
        in-window keys), an unknown QoS tier, or — in DSLOT mode — a
        per-request plane budget whose prompt would be split into multiple
        chunks on a model with NO calibrated activation scale (per-call-max
        quantization is not chunk-invariant, so the chunked prefill would
        silently diverge from a one-shot prefill of the same prompt; pin
        ``DslotConfig.act_scale``).

        Policy-assigned precision (DSLOT mode) is granted here, at enqueue:
        a scalar policy (``Fixed``, ``AdaptiveBudget``) grants this
        request's plane budget directly; a per-layer policy
        (``PerLayerSchedule``) is flattened to the budget of the engine's
        DSLOT consumer (the MLP up-projection, falling back to the
        schedule's ``"*"`` default).
        """
        if self._closed:
            raise RuntimeError("ServeEngine is closed")
        prompt = np.asarray(req.prompt)
        if prompt.ndim != 1:
            raise ValueError(
                f"request {req.uid}: prompt must be 1-D, got shape "
                f"{prompt.shape}")
        if not np.issubdtype(prompt.dtype, np.integer):
            raise ValueError(
                f"request {req.uid}: prompt dtype {prompt.dtype} is not an "
                f"integer type — token ids must be integers (a float "
                f"prompt would be silently truncated into the shared ring)")
        req.prompt = prompt
        P = int(len(req.prompt))
        if P < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        vocab = int(self.model.cfg.vocab_size)
        lo, hi = int(prompt.min()), int(prompt.max())
        if lo < 0 or hi >= vocab:
            raise ValueError(
                f"request {req.uid}: token ids must be in [0, {vocab}), "
                f"got range [{lo}, {hi}] — an out-of-vocab id reads "
                f"garbage through the embedding gather and poisons the "
                f"shared decode state")
        if req.max_new < 1:
            raise ValueError(
                f"request {req.uid}: max_new must be >= 1, got {req.max_new}")
        if P + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt ({P}) + max_new ({req.max_new}) "
                f"= {P + req.max_new} exceeds max_len ({self.max_len}); the "
                f"KV ring would wrap and corrupt the sequence")
        cap = cache_capacity(self.model.cfg, self.max_len)
        if self.pipeline.chunk == 0 and P > cap:
            # whole-prompt admission runs the prompt as ONE chunk; wider
            # than the ring (the SWA window, when smaller than max_len) it
            # would wrap and silently evict its own in-window keys.
            raise ValueError(
                f"request {req.uid}: whole-prompt admission "
                f"(prefill_chunk=0) cannot ingest a {P}-token prompt into "
                f"a KV ring of capacity {cap} (sliding window "
                f"{self.model.cfg.window}); the ring would wrap.  Use "
                f"chunked admission (prefill_chunk > 0)")
        known_tiers = self.slo.tiers if self.slo is not None else TIERS
        if req.tier not in known_tiers:
            raise ValueError(
                f"request {req.uid}: unknown QoS tier {req.tier!r} "
                f"(known: {sorted(known_tiers)})")
        wants_budget = req.n_planes is not None or (
            self.dslot and self.policy is not None)
        if (self.dslot and not self.calibrated and wants_budget
                and 0 < self.pipeline.chunk < P):
            raise ValueError(
                f"request {req.uid}: a per-request DSLOT plane budget with "
                f"a chunked prompt ({P} tokens > prefill_chunk="
                f"{self.pipeline.chunk}) requires a calibrated activation "
                "scale — per-call max quantization is not invariant to how "
                "the prompt is split into chunks.  Set DslotConfig.act_scale"
                " (or DslotWeights.with_scale), or use prefill_chunk=0")
        if not self.pipeline.enqueue(req):
            return False        # queue full: the policy is NOT consulted, so
                                # a later retry gets a fresh grant
        if self.dslot and req.n_planes is None and self.policy is not None:
            nxt = self.policy.next_precision()
            if isinstance(nxt, dict):
                nxt = nxt.get("mlp_up_dslot", nxt.get("*", self.n_bits))
            req.n_planes = int(nxt)
        req.enqueue_step = self._steps
        return True

    def cancel(self, uid: int) -> bool:
        """Abandon a request wherever it is in its lifecycle.

        Pending: removed from the queue.  Mid-prefill: the private chunk
        state is dropped and the reserved slot released — the pool was
        never written, so nothing needs cleaning.  Decoding: the slot is
        freed; its stale rows are invisible to other slots (per-sequence
        rings) and are replaced wholesale by the next admission's merge.

        Cancellation is terminal: ``req.done`` is set (with
        ``phase == "cancelled"`` distinguishing it from a natural finish)
        and ``req.result`` carries whatever was produced, so
        ``while not req.done`` driving loops exit.  A cancelled request
        is never returned from ``step()``.
        """
        return self._evict(uid, CANCELLED) is not None

    def _evict(self, uid: int, phase: str) -> Request | None:
        """Terminate a request wherever it lives (queue, prefill lane, or
        decode slot) with the given terminal phase, freeing its slot and
        lane, and attach its ``GenerateResult``.  The shared machinery
        behind ``cancel`` (CANCELLED), deadline eviction (TIMEOUT),
        poisoned-slot isolation (QUARANTINED) and admission-failure
        eviction (FAILED)."""
        found = next((r for r in list(self.pipeline.queue)
                      + [t.req for t in self.pipeline.active]
                      if r.uid == uid), None)
        if self.pipeline.cancel(uid):
            if found is not None:
                found.phase = phase
                found.result = self._result_of(found)
            return found
        for i, req in enumerate(self.slot_req):
            if req is not None and req.uid == uid:
                req.phase = phase
                req.done = True
                req.result = self._result_of(req)
                self.slot_req[i] = None
                return req
        return None

    def stream(self, req: Request) -> Iterator[int]:
        """Generator handle over a request's token stream.

        Admits ``req`` if it is new (raising ``RuntimeError`` on a full
        queue), then drives ``step()`` and yields each generated token as
        it lands — the pull-based twin of the ``Request.on_token`` push
        callback.  Other slots keep decoding underneath; interleave
        ``stream`` handles freely with direct ``step()`` calls.

        A consumer that stops iterating (``break``, garbage collection,
        explicit ``close()``) CANCELS the request: the ``finally`` below
        runs on ``GeneratorExit``, so an abandoned stream frees its slot
        and lane instead of stranding them forever (the pre-hardening
        leak).
        """
        if req.phase == "new" and not self.try_add(req):
            raise RuntimeError(
                f"request {req.uid}: admission queue full")
        sent = 0
        try:
            while True:
                while sent < len(req.out):
                    yield req.out[sent]
                    sent += 1
                if req.done:
                    return
                self.step()
        finally:
            if not req.done:
                self.cancel(req.uid)

    @property
    def queue_depth(self) -> int:
        """Admitted-but-not-yet-decodable requests (pending + prefilling)."""
        return len(self.pipeline)

    @property
    def steps(self) -> int:
        """Engine steps taken so far (the clock ``ttft_steps`` is in)."""
        return self._steps

    def slot_phases(self) -> list[str]:
        """Phase of each pool slot: 'free' | PREFILLING | DECODING."""
        held = {t.slot for t in self.pipeline.active}
        return [PREFILLING if i in held
                else (DECODING if r is not None else "free")
                for i, r in enumerate(self.slot_req)]

    def _free_slot(self, exclude: set = frozenset()) -> int | None:
        held = {t.slot for t in self.pipeline.active}
        for i, r in enumerate(self.slot_req):
            if r is None and i not in held and i not in exclude:
                return i
        return None

    def _budget_vector(self) -> jax.Array:
        npl = []
        for r in self.slot_req:
            base = self.n_bits if r is None or r.n_planes is None \
                else r.n_planes
            if self.slo is not None and r is not None:
                base = self.slo.budget_for(r.tier, base)
            npl.append(int(base))
        return jnp.asarray(npl, jnp.int32)

    # ------------------------------------------------------------ stepping

    def _admission_tick(self) -> None:
        """One step's worth of admission work: one batched lane forward
        advancing every active task, plus leftover ``chunks_per_step``
        budget spent on the head task (the hybrid tick); completed prefills
        are merged into their slots' rows (the PR 2 per-slot position
        vectors keep live slots undisturbed) and decode from THIS step
        on."""
        for task in self.pipeline.tick(self._free_slot):
            i = task.slot
            self.state = _merge_slot(self.state, task.state, i)
            self.slot_req[i] = task.req
            task.req.phase = DECODING
            self._acc_planes[i] = 0.0
            self._acc_bounded[i] = 0.0
            self._acc_steps[i] = 0
            # first token through the engine's sample fn (greedy by default),
            # matching what ``generate`` does with its prefill logits
            self.next_tok[i] = int(jax.device_get(self.sample(task.logits)[0]))

    def _evict_timeouts(self) -> int:
        """Deadline sweep: evict every request past its deadline — queued,
        mid-prefill, or decoding — with ``phase == "timeout"``.  Runs
        BEFORE the admission tick so an already-overdue queued request
        never claims a lane.  Returns the eviction count (fed to the SLO
        controller as pressure)."""
        default = self.cfg.default_deadline_steps
        expired = []
        for req in (list(self.pipeline.queue)
                    + [t.req for t in self.pipeline.active]
                    + [r for r in self.slot_req if r is not None]):
            dl = req.deadline_steps if req.deadline_steps is not None \
                else default
            if dl is None or req.enqueue_step is None:
                continue
            if self._steps - req.enqueue_step > dl:
                expired.append(req.uid)
        n = 0
        for uid in expired:
            if self._evict(uid, TIMEOUT) is not None:
                self.timeouts.append((self._steps, uid))
                n += 1
        return n

    def _fault_slot(self, fault) -> int | None:
        """Resolve a fault's target to a pool slot.  ``uid`` targets wait
        (return None, keeping the fault pending) until the request is
        actually decoding; ``slot`` targets fire as planned."""
        if fault.uid is not None:
            for i, r in enumerate(self.slot_req):
                if r is not None and r.uid == fault.uid:
                    return i
            return None
        if fault.slot is not None and 0 <= fault.slot < self.n_slots:
            return fault.slot
        return None

    def _corrupt_slot(self, state, slot: int):
        """Scribble NaN over one slot's floating-point rows of the decode
        state (KV ring) — the ``kv_corrupt`` fault hook.  Int leaves (ring
        positions) are left intact, so the corruption models a bad VALUE
        write, not broken indexing; the quarantine guard catches the NaN
        logits it produces on the very next decode step."""
        if self._state_axes is None:
            self._state_axes = _batch_axes(self.model, self.max_len)

        def scribble(leaf, ax):
            if ax < 0 or not jnp.issubdtype(leaf.dtype, jnp.floating):
                return leaf
            idx = (slice(None),) * ax + (slice(slot, slot + 1),)
            return leaf.at[idx].set(jnp.nan)

        return jax.tree.map(scribble, state, self._state_axes)

    def step(self) -> list[Request]:
        """One engine step: deadline sweep, admission chunk(s), SLO
        control, then advance all live slots by one token.  Returns
        finished requests.

        NEVER raises (a closed engine excepted): exceptions from admission
        or decode work are retried up to ``ServeConfig.max_step_retries``
        times within the step and logged to ``self.errors``.  Admission
        that fails every retry evicts its in-flight tasks with
        ``phase == "failed"`` (a deterministically poisoned prompt must not
        wedge the lanes forever); a decode that fails every retry stalls
        the pool one step with state untouched — both leave the engine in a
        state where ``check_invariants()`` passes and the next ``step()``
        proceeds.
        """
        if self._closed:
            raise RuntimeError("ServeEngine is closed")
        self._steps += 1
        inj = self.injector
        if inj is not None:
            inj.begin_step(self._steps)
            for f in inj.slow_steps():            # artificial latency
                time.sleep(f.value or 0.0)
            for uid in inj.cancels():             # replayable cancel storms
                self.cancel(uid)
        timed_out = self._evict_timeouts()
        f0 = self.pipeline.forwards
        for _ in range(self.cfg.max_step_retries + 1):
            try:
                if inj is not None:
                    inj.raise_if("admission_tick")
                self._admission_tick()
                break
            except Exception as e:  # noqa: BLE001 — absorb, log, retry
                self.errors.append((self._steps, "admission", repr(e)))
        else:
            # every retry failed: fail the in-flight admissions so the
            # lanes recover next step (the queue is untouched — see the
            # step() docstring)
            for task in list(self.pipeline.active):
                self._evict(task.req.uid, FAILED)
        if self.slo is not None:
            # load signals: queue AFTER this step's admissions, the TTFTs
            # that landed since the last update, and last decode's planes
            self.slo.update(SloSignals(
                queue_depth=self.queue_depth,
                ttft_steps=self._ttft_obs,
                decode_stalled=self.pipeline.forwards > f0,
                planes_used_mean=self._last_rows_mean,
                timed_out=timed_out))
            self._ttft_obs = []
        if all(r is None for r in self.slot_req):
            return []
        toks = jnp.asarray(self.next_tok[:, None])
        budgets = self._budget_vector()
        decoded = None
        for _ in range(self.cfg.max_step_retries + 1):
            try:
                if inj is not None:
                    inj.raise_if("decode_forward")
                decoded = self._decode(self.params, self.state, toks, budgets)
                break
            except Exception as e:  # noqa: BLE001
                self.errors.append((self._steps, "decode", repr(e)))
        if decoded is None:
            # decode failed every retry: state/tokens/accounting untouched,
            # the pool stalls exactly one step and retries next step
            return []
        logits, state2, aux = decoded
        self.last_budget = np.asarray(jax.device_get(budgets))
        poisoned = False
        if inj is not None:
            logits, poisoned = inj.poison_logits(logits, self._fault_slot)
        fin = None
        if self.cfg.quarantine_nonfinite:
            fin = np.asarray(jax.device_get(
                jnp.all(jnp.isfinite(logits), axis=-1) if poisoned
                else aux["finite"]))
        self.state = state2
        if inj is not None:
            for slot in inj.kv_corruptions(self._fault_slot):
                self.state = self._corrupt_slot(self.state, slot)
        nxt = np.asarray(jax.device_get(self.sample(logits)))
        rows = np.asarray(jax.device_get(aux["rows"])) \
            if "rows" in aux else None
        bounded = float(jax.device_get(aux["bounded"])) \
            if "bounded" in aux else None
        self._last_rows_mean = None if rows is None else float(rows.mean())
        finished = []
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if fin is not None and not fin[i]:
                # quarantine BEFORE emitting: the poisoned logits never
                # reach the stream.  Only this slot is touched — rows are
                # computationally independent (per-sequence rings, row-wise
                # MLP/norm), so survivors' tokens stay bit-identical to a
                # run that never admitted the poisoned request.
                self.quarantined.append((self._steps, req.uid))
                req.phase = QUARANTINED
                req.done = True
                req.result = self._result_of(req)
                self.slot_req[i] = None
                continue
            tok = int(self.next_tok[i])
            req.out.append(tok)
            req.token_steps.append(self._steps)
            if req.first_token_step is None:
                req.first_token_step = self._steps
                if req.ttft_steps is not None:
                    self._ttft_obs.append(req.ttft_steps)
            if req.on_token is not None:
                req.on_token(req, tok, self._steps)
            self.next_tok[i] = nxt[i]
            if rows is not None:
                self._acc_planes[i] += float(rows[i])
                if bounded is not None:
                    self._acc_bounded[i] += bounded
                self._acc_steps[i] += 1
            if len(req.out) >= req.max_new:
                req.done = True
                req.phase = DONE
                self._finish_stats(i, req)
                finished.append(req)
                self.slot_req[i] = None
        return finished

    # -------------------------------------------------------- shutdown

    @property
    def closed(self) -> bool:
        """True once :meth:`close` has sealed the engine."""
        return self._closed

    def live_requests(self) -> list[Request]:
        """Every request the engine still owes work: queued, mid-prefill,
        and decoding."""
        return (list(self.pipeline.queue)
                + [t.req for t in self.pipeline.active]
                + [r for r in self.slot_req if r is not None])

    def drain(self, max_steps: int | None = None) -> list[Request]:
        """Graceful shutdown, phase 1: step until every admitted request
        reaches a terminal state (finished, timed out, quarantined, or
        cancelled), admitting nothing new yourself.  Returns the requests
        that finished NATURALLY during the drain (evictions are on
        ``req.result`` / the engine's ``timeouts``/``quarantined`` logs).

        ``max_steps`` bounds the drain; ``None`` derives a worst-case
        sequential bound from the live work (every prompt's chunks plus its
        full generation budget) — exceeding it means the engine lost
        liveness, which IS worth raising about (``RuntimeError``), unlike
        anything inside ``step()``.
        """
        if self._closed:
            return []
        if max_steps is None:
            chunk = self.pipeline.chunk or self.max_len
            max_steps = 16 + sum(
                -(-len(r.prompt) // max(1, chunk)) + r.max_new
                for r in self.live_requests())
        finished: list[Request] = []
        for _ in range(max_steps):
            if not self.live_requests():
                return finished
            finished.extend(self.step())
        if self.live_requests():
            raise RuntimeError(
                f"drain did not converge in {max_steps} steps; still live: "
                f"{[r.uid for r in self.live_requests()]}")
        return finished

    def close(self) -> list[Request]:
        """Graceful shutdown, phase 2 (or immediate shutdown on its own):
        cancel everything still in flight — queued, prefilling, decoding —
        attaching each request's ``GenerateResult`` with whatever it
        produced, then seal the engine: ``try_add`` and ``step`` raise
        ``RuntimeError`` afterwards.  Idempotent.  Returns the requests
        cancelled by this call; ``drain()`` first for a shutdown that
        finishes in-flight work instead of cutting it."""
        if self._closed:
            return []
        cancelled = []
        for req in self.live_requests():
            if self._evict(req.uid, CANCELLED) is not None:
                cancelled.append(req)
        self._closed = True
        return cancelled

    def check_invariants(self) -> None:
        """Audit slot/queue/lane/ring accounting; raises
        ``repro.serve.health.InvariantViolation`` on corruption.  The chaos
        suites call this after every step."""
        from repro.serve.health import check_invariants
        check_invariants(self)

    def _result_of(self, req: Request, granted=None, used=None,
                   skipped=None, bounded=None) -> GenerateResult:
        return GenerateResult(
            tokens=list(req.out), n_planes=granted,
            planes_used_mean=used, skipped_frac=skipped,
            planes_bounded_mean=bounded,
            ttft_steps=req.ttft_steps,
            steps=None if req.enqueue_step is None
            else self._steps - req.enqueue_step,
            phase=req.phase, uid=req.uid, tier=req.tier)

    def _finish_stats(self, i: int, req: Request) -> None:
        granted = used = skipped = bounded = None
        if self.dslot and self._acc_steps[i] > 0:
            granted = req.n_planes if req.n_planes is not None \
                else self.n_bits
            if self.slo is not None:
                # a tier floor may have raised the effective budget above
                # the granted one (e.g. reserved pins full precision)
                granted = max(int(granted), self.slo.floor(req.tier))
            used = self._acc_planes[i] / self._acc_steps[i]
            # skipped_frac counts every granted-but-not-executed plane:
            # activation-side early termination AND the weight-side static
            # MSR bound (which caps planes_used inside the kernel), so the
            # two savings compound here; planes_bounded_mean attributes the
            # static weight-side share on its own.
            skipped = 1.0 - float(used) / float(granted)
            bounded = self._acc_bounded[i] / self._acc_steps[i]
            fb = PolicyFeedback(n_planes=int(granted),
                                planes_used_mean=float(used),
                                skipped_frac=skipped, tier=req.tier)
            req.dslot_stats = {"n_planes": fb.n_planes,
                               "planes_used_mean": fb.planes_used_mean,
                               "skipped_frac": fb.skipped_frac,
                               "planes_bounded_mean": float(bounded)}
            if self.policy is not None:
                self.policy.observe(fb)
            if self.slo is not None:
                self.slo.observe(fb)
        req.result = self._result_of(req, granted=granted, used=used,
                                     skipped=skipped, bounded=bounded)


def _merge_slot(pool_state: dict, one_state: dict, slot: int) -> dict:
    """Copy a batch-1 prefill state into row ``slot`` of the pooled state.

    Works leaf-by-leaf: the batch axis of each leaf is wherever its shape
    differs from the pooled leaf (axis 0 for plain layers and the position
    vector, axis 1 under a leading scan-stack axis).  Only that row of the
    pool is written, so live slots keep decoding undisturbed.
    """
    def merge(pool, one):
        if pool.shape == one.shape:
            if pool.shape and pool.shape[0] == 1:
                return one                       # 1-slot pool: full replace
            return pool                          # unbatched leaf: shared
        diff = [a for a, (ps, os) in enumerate(zip(pool.shape, one.shape))
                if ps != os]
        if len(diff) == 1 and one.shape[diff[0]] == 1:
            ax = diff[0]
            idx = (slice(None),) * ax + (slice(slot, slot + 1),)
            return pool.at[idx].set(one)
        return pool

    return jax.tree.map(merge, pool_state, one_state)
