"""Serving engine: batched prefill + decode with slot-based continuous
batching, DSLOT digit-serial execution mode, and per-request accounting.

``generate`` is the simple batch API (prefill once, decode N tokens).
``ServeEngine`` is the production shape: a fixed pool of B slots; requests
join free slots, decode steps advance every live slot together (one jitted
step for the whole pool), finished slots free up immediately.  Per-slot
position counters and done-flags make the batch composition fully dynamic
without recompilation.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import Model


def greedy_sample(logits: jax.Array, key=None) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(logits: jax.Array, key, temp: float = 0.8) -> jax.Array:
    return jax.random.categorical(key, logits / temp, axis=-1).astype(jnp.int32)


def generate(model: Model, params, batch: dict, max_new_tokens: int,
             *, max_len: int | None = None, sample=greedy_sample,
             key=None) -> jax.Array:
    """Prefill + greedy/temperature decode.  Returns (B, max_new_tokens)."""
    S = batch["tokens"].shape[1]
    if model.cfg.frontend and "frontend" in batch:
        S += batch["frontend"].shape[1]
    max_len = max_len or (S + max_new_tokens)
    logits, state = model.prefill(params, batch, max_len=max_len)
    tok = sample(logits) if key is None else sample(logits, key)

    def step(carry, _):
        tok, state, key = carry
        lg, state = model.decode_step(params, state, tok[:, None])
        if key is not None:
            key, sub = jax.random.split(key)
            nxt = sample(lg, sub)
        else:
            nxt = sample(lg)
        return (nxt, state, key), tok

    (_, _, _), toks = jax.lax.scan(
        step, (tok, state, key), None, length=max_new_tokens)
    return jnp.moveaxis(toks, 0, 1)                    # (B, max_new)


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (S,) int32
    max_new: int
    out: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Slot-pool continuous batching on a single jitted decode step."""

    def __init__(self, model: Model, params, *, n_slots: int,
                 max_len: int, sample: Callable = greedy_sample):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.sample = sample
        self.state = model.init_decode_state(n_slots, max_len)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int64)
        self.slot_budget = np.zeros(n_slots, np.int64)
        self.next_tok = np.zeros(n_slots, np.int32)
        self._decode = jax.jit(
            lambda p, st, t: model.decode_step(p, st, t))

    # ------------------------------------------------------------ requests

    def try_add(self, req: Request) -> bool:
        """Admit a request into a free slot (prefill runs immediately).

        NOTE: per-slot prefill into a shared pooled cache requires per-slot
        position offsets; for clarity each admitted request here restarts the
        pool's shared position counter only when the pool is empty —
        production multi-position pools would keep per-slot pos vectors.  The
        engine still demonstrates slot reuse + dynamic batch composition.
        """
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        if not free:
            return False
        i = free[0]
        # single-slot prefill through the batch-1 path
        batch = {"tokens": jnp.asarray(req.prompt[None])}
        logits, st = self.model.prefill(self.model_params_for(i), batch,
                                        max_len=self.max_len)
        # merge slot i's caches into the pool
        self.state = _merge_slot(self.state, st, i)
        self.slot_req[i] = req
        self.slot_pos[i] = len(req.prompt)
        self.slot_budget[i] = req.max_new
        self.next_tok[i] = int(jax.device_get(jnp.argmax(logits[0])))
        return True

    def model_params_for(self, slot: int):
        return self.params

    # ------------------------------------------------------------ stepping

    def step(self) -> list[Request]:
        """Advance all live slots by one token; returns finished requests."""
        if all(r is None for r in self.slot_req):
            return []
        toks = jnp.asarray(self.next_tok[:, None])
        logits, self.state = self._decode(self.params, self.state, toks)
        nxt = np.asarray(jax.device_get(self.sample(logits)))
        finished = []
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            req.out.append(int(self.next_tok[i]))
            self.slot_budget[i] -= 1
            self.next_tok[i] = nxt[i]
            if self.slot_budget[i] <= 0:
                req.done = True
                finished.append(req)
                self.slot_req[i] = None
        return finished


def _merge_slot(pool_state: dict, one_state: dict, slot: int) -> dict:
    """Copy a batch-1 decode state into slot ``slot`` of the pooled state."""
    def merge(pool, one):
        if pool.ndim >= 1 and one.ndim == pool.ndim and \
                one.shape[0] == 1 and pool.shape[0] != one.shape[0] and \
                pool.shape[1:] == one.shape[1:]:
            return pool.at[slot:slot + 1].set(one)
        return pool

    merged = jax.tree.map(merge, pool_state["caches"], one_state["caches"])
    return {"caches": merged, "pos": one_state["pos"]}
