"""Serving engine: slot-pool continuous batching with a chunked-prefill
admission pipeline, DSLOT digit-serial execution mode, and per-request
accounting.

``generate`` is the simple batch API (prefill once, decode N tokens); in
DSLOT mode it takes a runtime per-request precision and can return
planes-executed statistics per request.

``ServeEngine`` is the production shape: a fixed pool of B slots; decode
steps advance every live slot together (one jitted step for the whole
pool), finished slots free up immediately.  Admission is NON-BLOCKING and
BATCHED: ``try_add`` only validates and enqueues; the engine's step loop
interleaves one batched admission forward per decode step — up to
``ServeConfig.chunks_per_step`` PREFILLING requests each advance by one
fixed-size ``prefill_chunk`` of prompt, stacked into a single ragged-offset
forward (executed by ``repro.serve.prefill.PrefillPipeline``) — so
admitting long prompts never stalls the pool for a full-prompt forward,
and a burst of admissions drains ``chunks_per_step`` prompts at a time.  A
request moves through PENDING -> PREFILLING -> DECODING -> DONE
(``Request.phase``), and its slot joins the pooled decode the very step
its last prompt chunk lands.

Per-slot position vectors (threaded through the model's per-sequence
KV-cache ring) make the batch composition fully dynamic without
recompilation — merging a finished prefill into a non-empty pool never
disturbs other slots' decode positions, and chunked admission stays
token-exact versus a solo ``generate`` of the same prompt (in DSLOT mode
this additionally requires a calibrated ``DslotConfig.act_scale``: the
per-call-max quantization fallback is not invariant to how a prompt is
split into chunks — see ``kernels/ops.py`` and ``docs/serving.md``).

DSLOT serving mode (``cfg.dslot.enabled`` + ReLU MLPs): the engine prepares
the model's weight-stationary plane tables ONCE at construction
(``Model.prepare_dslot``), every request carries its own digit-plane budget
(explicit ``Request.n_planes`` or assigned by a ``repro.runtime`` precision
policy at enqueue time), prefill chunks and the pooled decode step execute
each request's rows at that request's precision (a runtime argument — no
retrace across precisions), and the per-request planes-executed account is
fed back to the policy when the request finishes (the ``AdaptiveBudget``
loop).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import stats as stats_channel
from repro.models.mlp import mlp_uses_dslot
from repro.models.model_zoo import Model
from repro.runtime import PolicyFeedback, PrecisionPolicy, precision_scope
from repro.serve.config import ServeConfig
from repro.serve.prefill import (CANCELLED, DECODING, DONE, PREFILLING,
                                 PrefillPipeline)

_ROWKEY = "mlp_up_dslot.row_planes_used"


def greedy_sample(logits: jax.Array, key=None) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(logits: jax.Array, key, temp: float = 0.8) -> jax.Array:
    return jax.random.categorical(key, logits / temp, axis=-1).astype(jnp.int32)


def _collapse_rows(sink: dict, batch: int) -> jax.Array | None:
    """Average the per-row planes-executed records of every DSLOT MLP call
    into one (B,) vector.  Records may be (B,) (plain layers) or carry
    leading stack axes from scan-over-layers; collapse those by mean."""
    vals = []
    for v in sink.get(_ROWKEY, []):
        v = jnp.asarray(v, jnp.float32)
        while v.ndim > 1:
            v = v.mean(axis=0)
        if v.shape == (batch,):
            vals.append(v)
    if not vals:
        return None
    return jnp.mean(jnp.stack(vals), axis=0)


def generate(model: Model, params, batch: dict, max_new_tokens: int,
             *, max_len: int | None = None, sample=greedy_sample,
             key=None, n_planes=None, return_stats: bool = False):
    """Prefill + greedy/temperature decode.  Returns (B, max_new_tokens),
    or ``(tokens, stats)`` with ``return_stats=True``.

    ``n_planes``: runtime DSLOT precision — int or per-request (B,) i32
    vector (ignored unless the model's digit-serial MLP path is enabled).
    ``stats``: {"planes_used_mean": (B,) effective digit planes per request,
    "skipped_frac": (B,)} — the per-request energy account, averaged over
    decode steps (empty when the DSLOT path is off).
    """
    B, S = batch["tokens"].shape
    if model.cfg.frontend and "frontend" in batch:
        S += batch["frontend"].shape[1]
    max_len = max_len or (S + max_new_tokens)
    if n_planes is not None:
        n_planes = jnp.asarray(n_planes, jnp.int32)
        if n_planes.ndim == 0:
            n_planes = jnp.full((B,), n_planes, jnp.int32)

    with precision_scope(n_planes):
        logits, state = model.prefill(params, batch, max_len=max_len)
        tok = sample(logits) if key is None else sample(logits, key)

        def step(carry, _):
            tok, state, key = carry
            if return_stats:       # stats collection is trace-time gated:
                with stats_channel.collect() as sink:   # no dead work in
                    lg, state = model.decode_step(       # the plain path
                        params, state, tok[:, None])
                rows = _collapse_rows(sink, B)
                st = {} if rows is None else {"rows": rows}
            else:
                lg, state = model.decode_step(params, state, tok[:, None])
                st = {}
            if key is not None:
                key, sub = jax.random.split(key)
                nxt = sample(lg, sub)
            else:
                nxt = sample(lg)
            return (nxt, state, key), (tok, st)

        (_, _, _), (toks, sts) = jax.lax.scan(
            step, (tok, state, key), None, length=max_new_tokens)
    toks = jnp.moveaxis(toks, 0, 1)                    # (B, max_new)
    if not return_stats:
        return toks
    stats: dict = {}
    if "rows" in sts:
        used = jnp.mean(sts["rows"], axis=0)           # (B,)
        if n_planes is not None:
            budget = n_planes.astype(jnp.float32)
        else:
            # no explicit budget: layers ran at their static default
            budget = float(model.cfg.dslot.n_planes
                           or model.cfg.dslot.n_bits)
        stats = {"planes_used_mean": used,
                 "skipped_frac": 1.0 - used / budget}
    return toks, stats


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (S,) int32
    max_new: int
    n_planes: int | None = None        # per-request DSLOT precision (None =
                                       # policy-assigned or full n_bits)
    out: list = field(default_factory=list)
    done: bool = False
    dslot_stats: dict | None = None    # set on finish in DSLOT mode
    phase: str = "new"                 # pending|prefilling|decoding|done|...
    enqueue_step: int | None = None    # engine step count at try_add
    first_token_step: int | None = None  # step that emitted out[0]

    @property
    def ttft_steps(self) -> int | None:
        """Engine steps from enqueue to first emitted token."""
        if self.enqueue_step is None or self.first_token_step is None:
            return None
        return self.first_token_step - self.enqueue_step


class ServeEngine:
    """Slot-pool continuous batching on a single jitted decode step, with
    chunked-prefill admission interleaved into the step loop."""

    def __init__(self, model: Model, params, *, n_slots: int,
                 max_len: int, sample: Callable = greedy_sample,
                 precision_policy: PrecisionPolicy | None = None,
                 serve_config: ServeConfig | None = None):
        self.model = model
        self.dslot = mlp_uses_dslot(model.cfg)
        # one-time weight-stationary lowering: every decode step executes
        # against cached digit-plane tables (no per-call re-encode)
        self.params = model.prepare_dslot(params) if self.dslot else params
        self.n_slots = n_slots
        self.max_len = max_len
        self.sample = sample
        self.policy = precision_policy
        self.n_bits = model.cfg.dslot.n_bits
        self.serve_config = serve_config or ServeConfig()
        self.state = model.init_decode_state(n_slots, max_len)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.next_tok = np.zeros(n_slots, np.int32)
        self._acc_planes = np.zeros(n_slots, np.float64)
        self._acc_steps = np.zeros(n_slots, np.int64)
        self._steps = 0
        self.pipeline = PrefillPipeline(
            model=model, params=self.params, max_len=max_len,
            chunk=self.serve_config.prefill_chunk,
            chunks_per_step=self.serve_config.chunks_per_step,
            max_queue=self.serve_config.max_queue,
            jit_chunks=self.serve_config.jit_prefill)

        def _decode(p, st, t, npl):
            with stats_channel.collect() as sink, precision_scope(npl):
                lg, st2 = model.decode_step(p, st, t)
            rows = _collapse_rows(sink, self.n_slots)
            return lg, st2, {} if rows is None else {"rows": rows}

        self._decode = jax.jit(_decode)

    # ------------------------------------------------------------ requests

    def try_add(self, req: Request) -> bool:
        """Enqueue a request for admission — NON-blocking.

        No model work happens here: the request joins the FIFO admission
        queue and the step loop prefills it one ``prefill_chunk`` at a time,
        interleaved with pooled decode steps.  Returns False only when the
        admission queue is full (``ServeConfig.max_queue``) — retry later.

        Requests that can NEVER run are rejected immediately with
        ``ValueError``: an empty prompt, a non-positive generation budget,
        or ``len(prompt) + max_new > max_len`` (the KV ring would wrap and
        silently corrupt the sequence mid-decode).

        Policy-assigned precision (DSLOT mode) is granted here, at enqueue:
        a scalar policy (``Fixed``, ``AdaptiveBudget``) grants this
        request's plane budget directly; a per-layer policy
        (``PerLayerSchedule``) is flattened to the budget of the engine's
        DSLOT consumer (the MLP up-projection, falling back to the
        schedule's ``"*"`` default).
        """
        P = int(len(req.prompt))
        if P < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.max_new < 1:
            raise ValueError(
                f"request {req.uid}: max_new must be >= 1, got {req.max_new}")
        if P + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt ({P}) + max_new ({req.max_new}) "
                f"= {P + req.max_new} exceeds max_len ({self.max_len}); the "
                f"KV ring would wrap and corrupt the sequence")
        if not self.pipeline.enqueue(req):
            return False        # queue full: the policy is NOT consulted, so
                                # a later retry gets a fresh grant
        if self.dslot and req.n_planes is None and self.policy is not None:
            nxt = self.policy.next_precision()
            if isinstance(nxt, dict):
                nxt = nxt.get("mlp_up_dslot", nxt.get("*", self.n_bits))
            req.n_planes = int(nxt)
        req.enqueue_step = self._steps
        return True

    def cancel(self, uid: int) -> bool:
        """Abandon a request wherever it is in its lifecycle.

        Pending: removed from the queue.  Mid-prefill: the private chunk
        state is dropped and the reserved slot released — the pool was
        never written, so nothing needs cleaning.  Decoding: the slot is
        freed; its stale rows are invisible to other slots (per-sequence
        rings) and are replaced wholesale by the next admission's merge.

        Cancellation is terminal: ``req.done`` is set (with
        ``phase == "cancelled"`` distinguishing it from a natural finish),
        so ``while not req.done`` driving loops exit.  A cancelled request
        is never returned from ``step()``.
        """
        if self.pipeline.cancel(uid):
            return True
        for i, req in enumerate(self.slot_req):
            if req is not None and req.uid == uid:
                req.phase = CANCELLED
                req.done = True
                self.slot_req[i] = None
                return True
        return False

    @property
    def queue_depth(self) -> int:
        """Admitted-but-not-yet-decodable requests (pending + prefilling)."""
        return len(self.pipeline)

    @property
    def steps(self) -> int:
        """Engine steps taken so far (the clock ``ttft_steps`` is in)."""
        return self._steps

    def slot_phases(self) -> list[str]:
        """Phase of each pool slot: 'free' | PREFILLING | DECODING."""
        held = {t.slot for t in self.pipeline.active}
        return [PREFILLING if i in held
                else (DECODING if r is not None else "free")
                for i, r in enumerate(self.slot_req)]

    def _free_slot(self, exclude: set = frozenset()) -> int | None:
        held = {t.slot for t in self.pipeline.active}
        for i, r in enumerate(self.slot_req):
            if r is None and i not in held and i not in exclude:
                return i
        return None

    def _budget_vector(self) -> jax.Array:
        npl = [self.n_bits if r is None or r.n_planes is None
               else r.n_planes for r in self.slot_req]
        return jnp.asarray(npl, jnp.int32)

    # ------------------------------------------------------------ stepping

    def _admission_tick(self) -> None:
        """One step's worth of admission work: at most ``chunks_per_step``
        prompt chunks — batched into one forward when the model supports
        ragged stacked extension; completed prefills are merged into their
        slots' rows (the PR 2 per-slot position vectors keep live slots
        undisturbed) and decode from THIS step on."""
        for task in self.pipeline.tick(self._free_slot):
            i = task.slot
            self.state = _merge_slot(self.state, task.state, i)
            self.slot_req[i] = task.req
            task.req.phase = DECODING
            self._acc_planes[i] = 0.0
            self._acc_steps[i] = 0
            # first token through the engine's sample fn (greedy by default),
            # matching what ``generate`` does with its prefill logits
            self.next_tok[i] = int(jax.device_get(self.sample(task.logits)[0]))

    def step(self) -> list[Request]:
        """One engine step: admission chunk(s), then advance all live slots
        by one token.  Returns finished requests."""
        self._steps += 1
        self._admission_tick()
        if all(r is None for r in self.slot_req):
            return []
        toks = jnp.asarray(self.next_tok[:, None])
        logits, self.state, aux = self._decode(
            self.params, self.state, toks, self._budget_vector())
        nxt = np.asarray(jax.device_get(self.sample(logits)))
        rows = np.asarray(jax.device_get(aux["rows"])) \
            if "rows" in aux else None
        finished = []
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            req.out.append(int(self.next_tok[i]))
            if req.first_token_step is None:
                req.first_token_step = self._steps
            self.next_tok[i] = nxt[i]
            if rows is not None:
                self._acc_planes[i] += float(rows[i])
                self._acc_steps[i] += 1
            if len(req.out) >= req.max_new:
                req.done = True
                req.phase = DONE
                self._finish_stats(i, req)
                finished.append(req)
                self.slot_req[i] = None
        return finished

    def _finish_stats(self, i: int, req: Request) -> None:
        if not self.dslot or self._acc_steps[i] == 0:
            return
        granted = req.n_planes if req.n_planes is not None else self.n_bits
        used = self._acc_planes[i] / self._acc_steps[i]
        fb = PolicyFeedback(n_planes=int(granted),
                            planes_used_mean=float(used),
                            skipped_frac=1.0 - float(used) / float(granted))
        req.dslot_stats = {"n_planes": fb.n_planes,
                           "planes_used_mean": fb.planes_used_mean,
                           "skipped_frac": fb.skipped_frac}
        if self.policy is not None:
            self.policy.observe(fb)


def _merge_slot(pool_state: dict, one_state: dict, slot: int) -> dict:
    """Copy a batch-1 prefill state into row ``slot`` of the pooled state.

    Works leaf-by-leaf: the batch axis of each leaf is wherever its shape
    differs from the pooled leaf (axis 0 for plain layers and the position
    vector, axis 1 under a leading scan-stack axis).  Only that row of the
    pool is written, so live slots keep decoding undisturbed.
    """
    def merge(pool, one):
        if pool.shape == one.shape:
            if pool.shape and pool.shape[0] == 1:
                return one                       # 1-slot pool: full replace
            return pool                          # unbatched leaf: shared
        diff = [a for a, (ps, os) in enumerate(zip(pool.shape, one.shape))
                if ps != os]
        if len(diff) == 1 and one.shape[diff[0]] == 1:
            ax = diff[0]
            idx = (slice(None),) * ax + (slice(slot, slot + 1),)
            return pool.at[idx].set(one)
        return pool

    return jax.tree.map(merge, pool_state, one_state)
