"""Serving layer: slot-pool engine + chunked-prefill admission pipeline +
SLO-driven precision elasticity.

Public surface (pinned by ``tests/test_public_api.py``):

* ``ServeEngine(model, params, cfg: ServeConfig)`` / ``generate`` — the two
  serving paths, both yielding :class:`GenerateResult`.
* ``ServeConfig`` — every engine knob beyond ``(model, params)``.
* ``Request`` — one in-flight generation (QoS ``tier``, streaming
  ``on_token`` / ``token_steps``, terminal ``result``).
* ``SloConfig`` / ``SloController`` / ``TierSpec`` + tier names — the SLO
  plane-shedding control loop (``repro.serve.slo``).

See ``docs/serving.md`` for the slot lifecycle, the admission/decode
overlap design, and the SLO/QoS control loop.
"""

from repro.serve.config import ServeConfig
from repro.serve.engine import Request, ServeEngine, generate
from repro.serve.prefill import (CANCELLED, DECODING, DONE, PENDING,
                                 PREFILLING, PrefillPipeline, PrefillTask)
from repro.serve.result import GenerateResult
from repro.serve.slo import (DEGRADABLE, RESERVED, STANDARD, TIERS,
                             SloConfig, SloController, SloSignals, TierSpec,
                             default_tiers)

__all__ = ["ServeConfig", "Request", "ServeEngine", "generate",
           "GenerateResult",
           "PrefillPipeline", "PrefillTask", "PENDING", "PREFILLING",
           "DECODING", "DONE", "CANCELLED",
           "SloConfig", "SloController", "SloSignals", "TierSpec",
           "default_tiers", "RESERVED", "STANDARD", "DEGRADABLE", "TIERS"]
