"""serve subpackage of the DSLOT-NN reproduction."""
