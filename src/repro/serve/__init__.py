"""Serving layer: slot-pool engine + chunked-prefill admission pipeline.

See ``docs/serving.md`` for the slot lifecycle and the admission/decode
overlap design.
"""

from repro.serve.config import ServeConfig
from repro.serve.engine import Request, ServeEngine, generate
from repro.serve.prefill import (CANCELLED, DECODING, DONE, PENDING,
                                 PREFILLING, PrefillPipeline, PrefillTask)

__all__ = ["ServeConfig", "Request", "ServeEngine", "generate",
           "PrefillPipeline", "PrefillTask", "PENDING", "PREFILLING",
           "DECODING", "DONE", "CANCELLED"]
