"""Serving layer: slot-pool engine + chunked-prefill admission pipeline +
SLO-driven precision elasticity + a hardened failure surface.

Public surface (pinned by ``tests/test_public_api.py``):

* ``ServeEngine(model, params, cfg: ServeConfig)`` / ``generate`` — the two
  serving paths, both yielding :class:`GenerateResult`.
* ``ServeConfig`` — every engine knob beyond ``(model, params)``.
* ``Request`` — one in-flight generation (QoS ``tier``, per-request
  ``deadline_steps``, streaming ``on_token`` / ``token_steps``, terminal
  ``result``).
* ``SloConfig`` / ``SloController`` / ``TierSpec`` + tier names — the SLO
  plane-shedding control loop (``repro.serve.slo``).
* ``Fault`` / ``FaultPlan`` / ``FaultInjector`` / ``TransientFault`` — the
  deterministic fault-injection plane (``repro.serve.faults``), and
  ``audit_engine`` / ``check_invariants`` / ``InvariantViolation`` — the
  crash-consistency oracle (``repro.serve.health``).
* Lifecycle phases: PENDING -> PREFILLING -> DECODING -> DONE, with the
  terminal evictions CANCELLED / TIMEOUT / QUARANTINED / FAILED.

See ``docs/serving.md`` for the slot lifecycle, the admission/decode
overlap design, the SLO/QoS control loop, and "Failure modes and
recovery" for the hardening contracts.
"""

from repro.serve.config import ServeConfig
from repro.serve.engine import Request, ServeEngine, generate
from repro.serve.faults import (FAULT_KINDS, Fault, FaultInjector, FaultPlan,
                                TransientFault)
from repro.serve.health import (InvariantViolation, audit_engine,
                                check_invariants)
from repro.serve.prefill import (CANCELLED, DECODING, DONE, FAILED, PENDING,
                                 PREFILLING, QUARANTINED, TIMEOUT,
                                 PrefillPipeline, PrefillTask)
from repro.serve.result import GenerateResult
from repro.serve.slo import (DEGRADABLE, RESERVED, STANDARD, TIERS,
                             SloConfig, SloController, SloSignals, TierSpec,
                             default_tiers)

__all__ = ["ServeConfig", "Request", "ServeEngine", "generate",
           "GenerateResult",
           "PrefillPipeline", "PrefillTask", "PENDING", "PREFILLING",
           "DECODING", "DONE", "CANCELLED", "TIMEOUT", "QUARANTINED",
           "FAILED",
           "Fault", "FaultPlan", "FaultInjector", "TransientFault",
           "FAULT_KINDS",
           "InvariantViolation", "audit_engine", "check_invariants",
           "SloConfig", "SloController", "SloSignals", "TierSpec",
           "default_tiers", "RESERVED", "STANDARD", "DEGRADABLE", "TIERS"]
