"""Chunked-prefill admission pipeline: digit-pipelined overlap for serving.

The paper's core idea — start subsequent operations as soon as the first
digits arrive instead of waiting for the full result — applied at the
serving layer: instead of blocking the whole decode pool for one full-prompt
forward per admission (the old ``try_add``), admission work is cut into
fixed-size prompt chunks and the engine interleaves at most
``chunks_per_step`` chunks with every pooled decode step.  Live slots keep
decoding at their usual cadence; a pending prompt trickles into its KV cache
a chunk at a time and the slot becomes decodable the very step its last
chunk lands.

Lifecycle of a request::

    try_add --> PENDING ----> PREFILLING ----------> DECODING --> DONE
               (queued,       (slot reserved;        (in the pooled
                FIFO)          chunks accumulate      decode step)
                               into a private
                               batch-1 state)

Chunk mechanics: the first chunk runs ``model.prefill`` (builds a fresh
batch-1 ring sized for ``max_len``), later chunks run ``model.extend``
(multi-token decode-mode append at the current offset, writing KV at
positions ``off .. off+c-1`` through the per-sequence position vectors).
The accumulating state is **private** to the task — the pool is written
exactly once, by ``_merge_slot`` on completion, which replaces the reserved
slot's rows wholesale.  That makes the pipeline trivially safe against
everything that happens to the pool in between (pooled decode steps write
garbage KV into reserved rows exactly as they always did into free rows;
the final merge wipes it) and makes cancelling a mid-prefill request free:
drop the task, nothing to clean up.

Sliding-window attention is the one stack that cannot extend a ring
chunk-by-chunk (a chunk landing at offset ``o`` recycles ring slots that
still hold in-window keys needed by the chunk's own earliest queries), so
SWA configs fall back to whole-prompt chunks — admission is still
queue-paced, one admission per step, but each is a single forward.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Callable

import jax
import jax.numpy as jnp

from repro.runtime import precision_scope

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.serve.engine import Request

__all__ = ["PENDING", "PREFILLING", "DECODING", "DONE", "CANCELLED",
           "PrefillTask", "PrefillPipeline"]

# Request lifecycle phases (``Request.phase``).
PENDING = "pending"          # queued, no slot yet
PREFILLING = "prefilling"    # slot reserved, prompt chunks in flight
DECODING = "decoding"        # merged into the pool, advancing every step
DONE = "done"                # finished, slot released
CANCELLED = "cancelled"      # abandoned at any earlier phase


@dataclass
class PrefillTask:
    """One in-flight admission: a request, its reserved slot, and the
    private batch-1 decode state its prompt chunks accumulate into."""
    req: "Request"
    slot: int
    offset: int = 0                  # prompt tokens already processed
    state: dict | None = None        # batch-1 model decode state
    logits: Any = None               # last chunk's final-position logits
    chunks_done: int = 0

    @property
    def remaining(self) -> int:
        return len(self.req.prompt) - self.offset


@dataclass
class PrefillPipeline:
    """FIFO admission queue + the chunk executor (one task in flight).

    The engine calls :meth:`tick` once per step with a free-slot provider;
    the pipeline claims the queue head into a slot when one is available and
    advances the in-flight task by at most ``chunks_per_step`` chunks,
    returning completed tasks for the engine to merge into the pool.
    """
    model: Any
    params: Any
    max_len: int
    chunk: int = 32
    chunks_per_step: int = 1
    max_queue: int | None = None
    jit_chunks: bool = True
    queue: deque = field(default_factory=deque)
    active: PrefillTask | None = None

    def __post_init__(self):
        if self.model.cfg.attn_type == "swa" and self.chunk:
            # SWA rings recycle slots within chunk+window spans (see module
            # docstring): chunked extension would drop needed keys.
            self.chunk = 0
        # Jitted chunk forwards (the engine's ``_decode`` pattern): the
        # request's DSLOT precision enters as a TRACED i32 argument, so every
        # admission at every precision shares one compile per chunk length —
        # a python int closed over at trace time would recompile per
        # precision and silently pin the first request's budget.  Compile
        # only pays off because chunk lengths are bounded (the fixed chunk
        # plus ragged tails < chunk); with whole-prompt admission
        # (``chunk == 0``, incl. the SWA fallback) every distinct prompt
        # length would be a fresh full-model compile, so that path stays
        # eager.
        model, max_len = self.model, self.max_len

        def _prefill_chunk(params, tokens, npl):
            with precision_scope(npl):
                return model.prefill(params, {"tokens": tokens},
                                     max_len=max_len)

        def _extend_chunk(params, state, tokens, npl):
            with precision_scope(npl):
                return model.extend(params, state, tokens)

        if self.jit_chunks and self.chunk > 0:
            _prefill_chunk = jax.jit(_prefill_chunk)
            _extend_chunk = jax.jit(_extend_chunk)
        self._prefill_chunk = _prefill_chunk
        self._extend_chunk = _extend_chunk

    def _chunk_precision(self, req: "Request") -> jax.Array:
        """The request's plane budget as a traced-friendly i32 scalar.

        ``None`` resolves HERE (at python level) to what ``scope(None)``
        would have meant eagerly — fall through to the layer default
        (``cfg.dslot.n_planes``, then ``n_bits``).  Passing None into the
        traced scope instead would be wrong twice over: it is untraceable,
        and a traced ``n_bits`` stand-in would override a layer default
        smaller than ``n_bits``.
        """
        d = self.model.cfg.dslot
        npl = req.n_planes if req.n_planes is not None \
            else (d.n_planes or d.n_bits)
        return jnp.asarray(npl, jnp.int32)

    # ------------------------------------------------------------- queue

    def __len__(self) -> int:
        """Admissions not yet decodable: queued + in-flight."""
        return len(self.queue) + (1 if self.active is not None else 0)

    def enqueue(self, req: "Request") -> bool:
        if self.max_queue is not None and len(self) >= self.max_queue:
            return False
        req.phase = PENDING
        self.queue.append(req)
        return True

    def cancel(self, uid: int) -> bool:
        """Drop a pending or in-flight admission.  Mid-prefill cancellation
        is free: the pool was never written, so only the private task state
        is discarded (its reserved slot is simply released).  A cancelled
        request is terminal: ``done`` is set so completion loops exit."""
        for req in self.queue:
            if req.uid == uid:
                self.queue.remove(req)
                req.phase = CANCELLED
                req.done = True
                return True
        if self.active is not None and self.active.req.uid == uid:
            self.active.req.phase = CANCELLED
            self.active.req.done = True
            self.active = None
            return True
        return False

    # ------------------------------------------------------------- stepping

    def tick(self, free_slot: Callable[[set], int | None]
             ) -> list[PrefillTask]:
        """Run up to ``chunks_per_step`` chunks of admission work.

        ``free_slot(exclude)`` returns a claimable slot index not in
        ``exclude``, or None (pool full).  Returns the tasks whose LAST
        chunk landed this tick — the engine merges them and their slots
        decode this same step.  Slots of tasks completed WITHIN this tick
        are excluded from claiming (the engine merges them only after the
        tick returns), so ``chunks_per_step > 1`` can never double-book a
        slot.
        """
        completed: list[PrefillTask] = []
        landed: set[int] = set()
        for _ in range(max(1, self.chunks_per_step)):
            if self.active is None and self.queue:
                slot = free_slot(landed)
                if slot is None:
                    break
                req = self.queue.popleft()
                req.phase = PREFILLING
                self.active = PrefillTask(req=req, slot=slot)
            if self.active is None:
                break
            if self._advance(self.active):
                completed.append(self.active)
                landed.add(self.active.slot)
                self.active = None
        return completed

    def _advance(self, task: PrefillTask) -> bool:
        """Process one prompt chunk; True when the prompt is fully in.

        Runs the (jitted, see ``__post_init__``) chunk forwards; the
        request's precision is a runtime argument, so back-to-back
        admissions at different plane budgets hit the same executable.
        """
        req = task.req
        P = len(req.prompt)
        c = self.chunk if self.chunk > 0 else P
        end = min(task.offset + c, P)
        tokens = jnp.asarray(req.prompt[None, task.offset:end])
        npl = self._chunk_precision(req)
        if task.offset == 0:
            task.logits, task.state = self._prefill_chunk(
                self.params, tokens, npl)
        else:
            task.logits, task.state = self._extend_chunk(
                self.params, task.state, tokens, npl)
        task.offset = end
        task.chunks_done += 1
        return end >= P
