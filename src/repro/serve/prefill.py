"""Chunked-prefill admission pipeline: digit-pipelined overlap for serving.

The paper's core idea — start subsequent operations as soon as the first
digits arrive instead of waiting for the full result — applied at the
serving layer: instead of blocking the whole decode pool for one full-prompt
forward per admission (the old ``try_add``), admission work is cut into
fixed-size prompt chunks and the engine interleaves admission work with
every pooled decode step.  Live slots keep decoding at their usual cadence;
pending prompts trickle into their KV caches a chunk at a time and a slot
becomes decodable the very step its last chunk lands.

Like the serial-dataflow batching the paper's comparison baselines lean on
(Stripes; DSLR-CNN), throughput comes from keeping MANY serial streams in
flight at once: admission work is BATCHED.  Up to
``ServeConfig.chunks_per_step`` PREFILLING requests advance together in ONE
forward per engine step — each in its own **lane** of a persistent stacked
decode state, at its own ragged offset, padded to the fixed chunk width,
with per-lane position vectors and per-lane DSLOT plane budgets
(``Model.extend(..., lengths=...)``).

Tensor parallelism needs no pipeline-side code: the engine hands this
pipeline params whose ``DslotWeights`` already carry the serving mesh
(``ServeConfig.mesh`` -> ``Model.prepare_dslot``), so every jitted lane
forward — like every pooled decode step — runs N-sharded under the same
``shard_map``, one sharded forward per engine step
(``docs/distributed.md``).

Lifecycle of a request::

    try_add --> PENDING ----> PREFILLING ----------> DECODING --> DONE
               (queued,       (slot + lane           (in the pooled
                FIFO)          reserved; chunks       decode step)
                               accumulate into the
                               task's lane)

Chunk mechanics (batched mode): every chunk — the first included — runs
``Model.extend`` on the stacked lane state, starting from a freshly reset
lane (an empty ring at position 0 extends bit-identically to a one-shot
``Model.prefill``: masked ring entries are healed by the online softmax).
Lanes are **private** to their tasks — the pool is written exactly once, by
``_merge_slot`` on completion, which replaces the reserved slot's rows with
the finished lane's rows.  That makes the pipeline trivially safe against
everything that happens to the pool in between (pooled decode steps write
garbage KV into reserved rows exactly as they always did into free rows;
the final merge wipes it) and makes cancelling a mid-prefill request free:
drop the task, the lane is reset when the next request claims it.

Right-padding is harmless by construction: pad rows write nothing into the
ring (``q_valid`` masks the scatter), pass through the recurrent scans as
exact identity steps, and don't advance the lane's position, so a ragged
tail chunk costs one fixed-width forward and nothing else.  EVERY zoo
stack batches: sliding-window attention extends chunk-by-chunk by carrying
the pre-write ring alongside each chunk's own keys (so ring recycling can
never evict a live in-window key — ``models/attention.py``), and the
recurrent mixers (ssm/rglru) mask their scans so pad rows carry state
through unchanged.

The tick is HYBRID: the one batched forward advances every active lane,
and any leftover ``chunks_per_step`` budget is spent on extra sequential
chunks of the HEAD task (FIFO) — a lone admission still gets
``chunks_per_step`` chunks per tick, a full lane pool gets one chunk per
lane, and anything in between degrades smoothly.  Chunk boundaries are
fixed multiples of ``chunk`` regardless of which tick runs them, so the
schedule never changes the computed tokens.

``chunk == 0`` means whole-prompt admission: each tick runs ONE eager
batched forward at the widest remaining prompt among the claimed tasks, so
every claimed task completes in the tick it was claimed (eager because
every distinct width would otherwise be a fresh full-model compile).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.attention import cache_capacity
from repro.runtime import precision_scope

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.serve.engine import Request

__all__ = ["PENDING", "PREFILLING", "DECODING", "DONE", "CANCELLED",
           "TIMEOUT", "QUARANTINED", "FAILED",
           "PrefillTask", "PrefillPipeline"]

# Request lifecycle phases (``Request.phase``).
PENDING = "pending"          # queued, no slot yet
PREFILLING = "prefilling"    # slot reserved, prompt chunks in flight
DECODING = "decoding"        # merged into the pool, advancing every step
DONE = "done"                # finished, slot released
CANCELLED = "cancelled"      # abandoned at any earlier phase
# Terminal eviction phases (engine hardening — ``docs/serving.md``):
TIMEOUT = "timeout"          # deadline expired before finish; evicted
QUARANTINED = "quarantined"  # non-finite logits detected; slot isolated
FAILED = "failed"            # admission work kept raising past the retry
                             # budget; evicted so the lane can recover


@dataclass
class PrefillTask:
    """One in-flight admission: a request, its reserved pool slot, and the
    lane of the pipeline's stacked state its prompt chunks accumulate
    into."""
    req: "Request"
    slot: int
    lane: int = -1                   # row of the stacked lane state
    offset: int = 0                  # prompt tokens already processed
    state: dict | None = None        # the extracted lane row, on completion
    logits: Any = None               # last chunk's final-position logits
    chunks_done: int = 0

    @property
    def remaining(self) -> int:
        return len(self.req.prompt) - self.offset


def _batch_axes(model, max_len: int):
    """Locate the batch axis of every decode-state leaf (shape-only, via
    ``eval_shape`` — nothing is allocated).  -1 marks a leaf with no batch
    axis (shared across sequences)."""
    s1 = jax.eval_shape(lambda: model.init_decode_state(1, max_len))
    s2 = jax.eval_shape(lambda: model.init_decode_state(2, max_len))

    def ax(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y]
        return diffs[0] if diffs else -1

    return jax.tree.map(ax, s1, s2)


def _lane_ops(axes, jit: bool):
    """Row extract/insert over a stacked decode state, with the lane index
    as a TRACED scalar (one compile each, any lane) — the eager per-leaf
    form costs dozens of dispatches and a full state copy per call, which
    would eat the batching win at claim/completion time."""

    def extract(state, i):
        return jax.tree.map(
            lambda leaf, a: leaf if a < 0
            else jax.lax.dynamic_slice_in_dim(leaf, i, 1, axis=a),
            state, axes)

    def insert(state, row, i):
        return jax.tree.map(
            lambda leaf, a, r: leaf if a < 0
            else jax.lax.dynamic_update_slice_in_dim(leaf, r, i, axis=a),
            state, axes, row)

    if jit:
        extract, insert = jax.jit(extract), jax.jit(insert)
    return extract, insert


@dataclass
class PrefillPipeline:
    """FIFO admission queue + the chunk executor.

    The engine calls :meth:`tick` once per step with a free-slot provider;
    the pipeline claims queue heads into slots (and lanes) as they become
    available and advances every in-flight task by one chunk in ONE batched
    forward (``chunks_per_step`` lanes), spending any leftover budget on
    extra sequential chunks of the head task (the hybrid tick) — returning
    completed tasks for the engine to merge into the pool.
    """
    model: Any
    params: Any
    max_len: int
    chunk: int = 32
    chunks_per_step: int = 1
    max_queue: int | None = None
    jit_chunks: bool = True
    dslot: bool = False          # model runs the digit-serial MLP path
    calibrated: bool = True      # prepared weights carry an act scale
    queue: deque = field(default_factory=deque)
    active: list = field(default_factory=list)   # in-flight PrefillTasks
    forwards: int = 0                            # model forwards run (a
                                                 # batched tick counts 1)
    injector: Any = None         # repro.serve.faults.FaultInjector — the
                                 # engine installs its own; consulted just
                                 # before every lane forward

    def __post_init__(self):
        cap = cache_capacity(self.model.cfg, self.max_len)
        if self.chunk > cap:
            # batched chunks are padded to the FULL chunk width; wider than
            # the KV ring (max_len, or the SWA window when smaller), the
            # pad phantoms would alias real slots (the attention layer
            # rejects such chunks).  Clamping loses nothing: for full
            # attention a prompt can never exceed max_len anyway (try_add
            # validates), and for SWA any chunk width <= window is exact.
            self.chunk = cap
        model, max_len = self.model, self.max_len
        # Lane-pool batched admission: one persistent stacked decode state
        # with `chunks_per_step` lanes; every tick advances every active
        # lane by one fixed-width chunk in a single forward.  Tokens are
        # always padded to (lanes, chunk), lengths carry the ragged tails,
        # and the per-lane DSLOT budgets enter as a traced (lanes,) i32
        # vector — so there is exactly ONE compile, total, shared by every
        # admission at every precision and every ragged tail length.
        # (``chunk == 0`` is whole-prompt admission: widths vary per tick,
        # so the forward stays eager — each distinct width would otherwise
        # be a fresh full-model compile.)
        self.lanes = max(1, self.chunks_per_step)
        self._axes = _batch_axes(model, max_len)
        self._lane_state = model.init_decode_state(self.lanes, max_len)
        self._fresh = model.init_decode_state(1, max_len)
        self._extract_lane, self._insert_lane = _lane_ops(
            self._axes, self.jit_chunks)

        def _extend_lanes(params, state, tokens, lengths, npl):
            with precision_scope(npl):
                return model.extend(params, state, tokens,
                                    lengths=lengths)

        if self.jit_chunks and self.chunk > 0:
            _extend_lanes = jax.jit(_extend_lanes)
        self._extend_lanes = _extend_lanes

    def _resolve_precision(self, req: "Request | None") -> int:
        """The request's plane budget as a python int.

        ``None`` (no request, or no explicit budget) resolves HERE (at
        python level) to what ``scope(None)`` would have meant eagerly —
        fall through to the layer default (``cfg.dslot.n_planes``, then
        ``n_bits``).  Passing None into the traced scope instead would be
        wrong twice over: it is untraceable, and a traced ``n_bits``
        stand-in would override a layer default smaller than ``n_bits``.
        """
        d = self.model.cfg.dslot
        if req is not None and req.n_planes is not None:
            return int(req.n_planes)
        return int(d.n_planes or d.n_bits)

    # ------------------------------------------------------------- queue

    def __len__(self) -> int:
        """Admissions not yet decodable: queued + in-flight."""
        return len(self.queue) + len(self.active)

    def enqueue(self, req: "Request") -> bool:
        if self.max_queue is not None and len(self) >= self.max_queue:
            return False
        if (self.dslot and not self.calibrated
                and req.n_planes is not None
                and 0 < self.chunk < len(req.prompt)):
            # Chunked prefill quantizes each chunk's activations separately;
            # without a calibrated scale the per-call max fallback makes the
            # result depend on WHERE the prompt was split — a budgeted
            # admission would silently diverge from a one-shot prefill of
            # the same prompt.  Refuse instead of drifting.
            raise ValueError(
                f"request {req.uid}: a per-request DSLOT plane budget with "
                f"a chunked prompt ({len(req.prompt)} tokens > prefill_"
                f"chunk={self.chunk}) requires a calibrated activation "
                "scale — per-call max quantization is not chunk-invariant. "
                "Set DslotConfig.act_scale (or DslotWeights.with_scale), "
                "or use prefill_chunk=0")
        req.phase = PENDING
        self.queue.append(req)
        return True

    def cancel(self, uid: int) -> bool:
        """Drop a pending or in-flight admission.  Mid-prefill cancellation
        is free: the pool was never written, so only the task is discarded —
        its reserved slot is released, and its lane is reset when the next
        claimed request reuses it.  Co-batched survivors are untouched
        (lanes are independent batch rows).  A cancelled request is
        terminal: ``done`` is set so completion loops exit."""
        for req in self.queue:
            if req.uid == uid:
                self.queue.remove(req)
                req.phase = CANCELLED
                req.done = True
                return True
        for task in self.active:
            if task.req.uid == uid:
                task.req.phase = CANCELLED
                task.req.done = True
                self.active.remove(task)
                return True
        return False

    # ------------------------------------------------------------- stepping

    def tick(self, free_slot: Callable[[set], int | None]
             ) -> list[PrefillTask]:
        """Run one step's worth of admission work.

        ``free_slot(exclude)`` returns a claimable slot index not in
        ``exclude``, or None (pool full).  Returns the tasks whose LAST
        chunk landed this tick — the engine merges them and their slots
        decode this same step.  Claiming happens only at tick start,
        before any chunk lands, so admission can never double-book a
        slot completed within the tick.

        HYBRID schedule: claim queue heads into free (slot, lane) pairs up
        to ``chunks_per_step`` lanes, advance ALL active tasks by one chunk
        in a single stacked forward, then spend any leftover
        ``chunks_per_step`` budget on extra sequential chunks of the HEAD
        task (FIFO).  Chunk boundaries are fixed multiples of ``chunk``
        regardless of which tick runs them, so the hybrid schedule never
        changes the computed tokens — only how soon they land.
        """
        completed: list[PrefillTask] = []
        while self.queue and len(self.active) < self.lanes:
            slot = free_slot(set())
            if slot is None:
                break
            req = self.queue.popleft()
            req.phase = PREFILLING
            lane = min(set(range(self.lanes))
                       - {t.lane for t in self.active})
            # reset the lane: an empty ring at position 0 (a previous
            # occupant's stale keys would otherwise be causally visible)
            self._lane_state = self._insert_lane(self._lane_state,
                                                 self._fresh, lane)
            self.active.append(PrefillTask(req=req, slot=slot, lane=lane))
        budget = max(1, self.chunks_per_step)
        spent = 0
        while spent < budget and self.active:
            targets = list(self.active) if spent == 0 else [self.active[0]]
            completed.extend(self._forward_lanes(targets))
            spent += len(targets)
        return completed

    def _forward_lanes(self, targets: list[PrefillTask]
                       ) -> list[PrefillTask]:
        """Advance ``targets`` by one chunk in ONE stacked forward; returns
        the tasks whose prompt is now fully in (extracted from their
        lanes).  Non-target lanes ride along with zero-length rows —
        ``q_valid`` masking makes them exact no-ops on the lane state."""
        L = self.lanes
        c = self.chunk if self.chunk > 0 \
            else max(t.remaining for t in targets)
        toks = np.zeros((L, c), np.int32)
        lens = np.zeros((L,), np.int32)
        npl = np.full((L,), self._resolve_precision(None), np.int32)
        for t in targets:
            end = min(t.offset + c, len(t.req.prompt))
            n = end - t.offset
            toks[t.lane, :n] = t.req.prompt[t.offset:end]
            lens[t.lane] = n
            npl[t.lane] = self._resolve_precision(t.req)
        if self.injector is not None:
            # fault hook: a raise here leaves the tick transactional — no
            # task offset moved, the lane state untouched (the forward is a
            # functional update), so the engine's retry re-runs this exact
            # chunk against this exact state.
            self.injector.raise_if("lane_forward")
        logits, self._lane_state = self._extend_lanes(
            self.params, self._lane_state, jnp.asarray(toks),
            jnp.asarray(lens), jnp.asarray(npl))
        self.forwards += 1
        completed: list[PrefillTask] = []
        for t in targets:
            t.offset += int(lens[t.lane])
            t.chunks_done += 1
            if t.offset >= len(t.req.prompt):
                t.logits = logits[t.lane:t.lane + 1]
                t.state = self._extract_lane(self._lane_state, t.lane)
                self.active.remove(t)
                completed.append(t)
        return completed
