"""Chunked-prefill admission pipeline: digit-pipelined overlap for serving.

The paper's core idea — start subsequent operations as soon as the first
digits arrive instead of waiting for the full result — applied at the
serving layer: instead of blocking the whole decode pool for one full-prompt
forward per admission (the old ``try_add``), admission work is cut into
fixed-size prompt chunks and the engine interleaves admission work with
every pooled decode step.  Live slots keep decoding at their usual cadence;
pending prompts trickle into their KV caches a chunk at a time and a slot
becomes decodable the very step its last chunk lands.

Like the serial-dataflow batching the paper's comparison baselines lean on
(Stripes; DSLR-CNN), throughput comes from keeping MANY serial streams in
flight at once: admission work is BATCHED.  Up to
``ServeConfig.chunks_per_step`` PREFILLING requests advance together in ONE
forward per engine step — each in its own **lane** of a persistent stacked
decode state, at its own ragged offset, padded to the fixed chunk width,
with per-lane position vectors and per-lane DSLOT plane budgets
(``Model.extend(..., lengths=...)``).

Tensor parallelism needs no pipeline-side code: the engine hands this
pipeline params whose ``DslotWeights`` already carry the serving mesh
(``ServeConfig.mesh`` -> ``Model.prepare_dslot``), so every jitted lane
forward — like every pooled decode step — runs N-sharded under the same
``shard_map``, one sharded forward per engine step
(``docs/distributed.md``).

Lifecycle of a request::

    try_add --> PENDING ----> PREFILLING ----------> DECODING --> DONE
               (queued,       (slot + lane           (in the pooled
                FIFO)          reserved; chunks       decode step)
                               accumulate into the
                               task's lane)

Chunk mechanics (batched mode): every chunk — the first included — runs
``Model.extend`` on the stacked lane state, starting from a freshly reset
lane (an empty ring at position 0 extends bit-identically to a one-shot
``Model.prefill``: masked ring entries are healed by the online softmax).
Lanes are **private** to their tasks — the pool is written exactly once, by
``_merge_slot`` on completion, which replaces the reserved slot's rows with
the finished lane's rows.  That makes the pipeline trivially safe against
everything that happens to the pool in between (pooled decode steps write
garbage KV into reserved rows exactly as they always did into free rows;
the final merge wipes it) and makes cancelling a mid-prefill request free:
drop the task, the lane is reset when the next request claims it.

Right-padding is harmless by construction: pad rows write nothing into the
ring (``q_valid`` masks the scatter) and don't advance the lane's position,
so a ragged tail chunk costs one fixed-width forward and nothing else.

Two stacks fall back to the SERIAL path (one task in flight, batch-1
states, ``model.prefill`` then ``model.extend`` per chunk —
``chunks_per_step`` then meaning sequential chunks per tick):

* sliding-window attention cannot extend a ring chunk-by-chunk at all (a
  chunk landing at offset ``o`` recycles ring slots that still hold
  in-window keys needed by the chunk's own earliest queries), so SWA
  configs additionally fall back to whole-prompt chunks;
* recurrent mixers (ssm/rglru) advance carried state per token, so ragged
  right-padding would corrupt their lanes
  (``Model.supports_ragged_batches``).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.runtime import precision_scope

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.serve.engine import Request

__all__ = ["PENDING", "PREFILLING", "DECODING", "DONE", "CANCELLED",
           "TIMEOUT", "QUARANTINED", "FAILED",
           "PrefillTask", "PrefillPipeline"]

# Request lifecycle phases (``Request.phase``).
PENDING = "pending"          # queued, no slot yet
PREFILLING = "prefilling"    # slot reserved, prompt chunks in flight
DECODING = "decoding"        # merged into the pool, advancing every step
DONE = "done"                # finished, slot released
CANCELLED = "cancelled"      # abandoned at any earlier phase
# Terminal eviction phases (engine hardening — ``docs/serving.md``):
TIMEOUT = "timeout"          # deadline expired before finish; evicted
QUARANTINED = "quarantined"  # non-finite logits detected; slot isolated
FAILED = "failed"            # admission work kept raising past the retry
                             # budget; evicted so the lane can recover


@dataclass
class PrefillTask:
    """One in-flight admission: a request, its reserved pool slot, and the
    lane of the pipeline's stacked state (batched mode) or the private
    batch-1 decode state (serial fallback) its prompt chunks accumulate
    into."""
    req: "Request"
    slot: int
    lane: int = -1                   # batched mode: row of the lane state
    offset: int = 0                  # prompt tokens already processed
    state: dict | None = None        # batch-1 model decode state (serial
                                     # mode throughout; batched mode: the
                                     # extracted lane row, on completion)
    logits: Any = None               # last chunk's final-position logits
    chunks_done: int = 0

    @property
    def remaining(self) -> int:
        return len(self.req.prompt) - self.offset


def _batch_axes(model, max_len: int):
    """Locate the batch axis of every decode-state leaf (shape-only, via
    ``eval_shape`` — nothing is allocated).  -1 marks a leaf with no batch
    axis (shared across sequences)."""
    s1 = jax.eval_shape(lambda: model.init_decode_state(1, max_len))
    s2 = jax.eval_shape(lambda: model.init_decode_state(2, max_len))

    def ax(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y]
        return diffs[0] if diffs else -1

    return jax.tree.map(ax, s1, s2)


def _lane_ops(axes, jit: bool):
    """Row extract/insert over a stacked decode state, with the lane index
    as a TRACED scalar (one compile each, any lane) — the eager per-leaf
    form costs dozens of dispatches and a full state copy per call, which
    would eat the batching win at claim/completion time."""

    def extract(state, i):
        return jax.tree.map(
            lambda leaf, a: leaf if a < 0
            else jax.lax.dynamic_slice_in_dim(leaf, i, 1, axis=a),
            state, axes)

    def insert(state, row, i):
        return jax.tree.map(
            lambda leaf, a, r: leaf if a < 0
            else jax.lax.dynamic_update_slice_in_dim(leaf, r, i, axis=a),
            state, axes, row)

    if jit:
        extract, insert = jax.jit(extract), jax.jit(insert)
    return extract, insert


@dataclass
class PrefillPipeline:
    """FIFO admission queue + the chunk executor.

    The engine calls :meth:`tick` once per step with a free-slot provider;
    the pipeline claims queue heads into slots (and lanes) as they become
    available and advances every in-flight task by one chunk — all tasks in
    ONE batched forward (``chunks_per_step`` lanes) when the model supports
    ragged stacked extension, serially otherwise — returning completed
    tasks for the engine to merge into the pool.
    """
    model: Any
    params: Any
    max_len: int
    chunk: int = 32
    chunks_per_step: int = 1
    max_queue: int | None = None
    jit_chunks: bool = True
    dslot: bool = False          # model runs the digit-serial MLP path
    calibrated: bool = True      # prepared weights carry an act scale
    queue: deque = field(default_factory=deque)
    active: list = field(default_factory=list)   # in-flight PrefillTasks
    forwards: int = 0                            # model forwards run (a
                                                 # batched tick counts 1)
    injector: Any = None         # repro.serve.faults.FaultInjector — the
                                 # engine installs its own; consulted just
                                 # before every lane forward

    def __post_init__(self):
        if self.model.cfg.attn_type == "swa" and self.chunk:
            # SWA rings recycle slots within chunk+window spans (see module
            # docstring): chunked extension would drop needed keys.
            self.chunk = 0
        if self.chunk > self.max_len:
            # batched chunks are padded to the FULL chunk width; wider than
            # the KV ring, the pad phantoms would alias real slots (the
            # attention layer rejects such chunks).  A prompt can never
            # exceed max_len anyway (try_add validates), so clamping loses
            # nothing.
            self.chunk = self.max_len
        self.lanes = 1
        self.batched = bool(self.chunk > 0
                            and self.model.supports_ragged_batches)
        model, max_len = self.model, self.max_len
        if self.batched:
            # Lane-pool batched admission: one persistent stacked decode
            # state with `chunks_per_step` lanes; every tick advances every
            # active lane by one fixed-width chunk in a single forward.
            # Tokens are always padded to (lanes, chunk), lengths carry the
            # ragged tails, and the per-lane DSLOT budgets enter as a traced
            # (lanes,) i32 vector — so there is exactly ONE compile, total,
            # shared by every admission at every precision and every ragged
            # tail length.
            self.lanes = max(1, self.chunks_per_step)
            self._axes = _batch_axes(model, max_len)
            self._lane_state = model.init_decode_state(self.lanes, max_len)
            self._fresh = model.init_decode_state(1, max_len)
            self._extract_lane, self._insert_lane = _lane_ops(
                self._axes, self.jit_chunks)

            def _extend_lanes(params, state, tokens, lengths, npl):
                with precision_scope(npl):
                    return model.extend(params, state, tokens,
                                        lengths=lengths)

            if self.jit_chunks:
                _extend_lanes = jax.jit(_extend_lanes)
            self._extend_lanes = _extend_lanes
            return
        # Serial fallback (SWA / whole-prompt / recurrent mixers): jitted
        # batch-1 chunk forwards (the engine's ``_decode`` pattern): the
        # request's DSLOT precision enters as a TRACED i32 argument, so every
        # admission at every precision shares one compile per chunk length —
        # a python int closed over at trace time would recompile per
        # precision and silently pin the first request's budget.  Compile
        # only pays off because chunk lengths are bounded (the fixed chunk
        # plus ragged tails < chunk); with whole-prompt admission
        # (``chunk == 0``, incl. the SWA fallback) every distinct prompt
        # length would be a fresh full-model compile, so that path stays
        # eager.

        def _prefill_chunk(params, tokens, npl):
            with precision_scope(npl):
                return model.prefill(params, {"tokens": tokens},
                                     max_len=max_len)

        def _extend_chunk(params, state, tokens, npl):
            with precision_scope(npl):
                return model.extend(params, state, tokens)

        if self.jit_chunks and self.chunk > 0:
            _prefill_chunk = jax.jit(_prefill_chunk)
            _extend_chunk = jax.jit(_extend_chunk)
        self._prefill_chunk = _prefill_chunk
        self._extend_chunk = _extend_chunk

    def _resolve_precision(self, req: "Request | None") -> int:
        """The request's plane budget as a python int.

        ``None`` (no request, or no explicit budget) resolves HERE (at
        python level) to what ``scope(None)`` would have meant eagerly —
        fall through to the layer default (``cfg.dslot.n_planes``, then
        ``n_bits``).  Passing None into the traced scope instead would be
        wrong twice over: it is untraceable, and a traced ``n_bits``
        stand-in would override a layer default smaller than ``n_bits``.
        """
        d = self.model.cfg.dslot
        if req is not None and req.n_planes is not None:
            return int(req.n_planes)
        return int(d.n_planes or d.n_bits)

    def _chunk_precision(self, req: "Request") -> jax.Array:
        """Serial-path budget as a traced-friendly i32 scalar."""
        return jnp.asarray(self._resolve_precision(req), jnp.int32)

    # ------------------------------------------------------------- queue

    def __len__(self) -> int:
        """Admissions not yet decodable: queued + in-flight."""
        return len(self.queue) + len(self.active)

    def enqueue(self, req: "Request") -> bool:
        if self.max_queue is not None and len(self) >= self.max_queue:
            return False
        if (self.dslot and not self.calibrated
                and req.n_planes is not None
                and 0 < self.chunk < len(req.prompt)):
            # Chunked prefill quantizes each chunk's activations separately;
            # without a calibrated scale the per-call max fallback makes the
            # result depend on WHERE the prompt was split — a budgeted
            # admission would silently diverge from a one-shot prefill of
            # the same prompt.  Refuse instead of drifting.
            raise ValueError(
                f"request {req.uid}: a per-request DSLOT plane budget with "
                f"a chunked prompt ({len(req.prompt)} tokens > prefill_"
                f"chunk={self.chunk}) requires a calibrated activation "
                "scale — per-call max quantization is not chunk-invariant. "
                "Set DslotConfig.act_scale (or DslotWeights.with_scale), "
                "or use prefill_chunk=0")
        req.phase = PENDING
        self.queue.append(req)
        return True

    def cancel(self, uid: int) -> bool:
        """Drop a pending or in-flight admission.  Mid-prefill cancellation
        is free: the pool was never written, so only the task is discarded —
        its reserved slot is released, and its lane is reset when the next
        claimed request reuses it.  Co-batched survivors are untouched
        (lanes are independent batch rows).  A cancelled request is
        terminal: ``done`` is set so completion loops exit."""
        for req in self.queue:
            if req.uid == uid:
                self.queue.remove(req)
                req.phase = CANCELLED
                req.done = True
                return True
        for task in self.active:
            if task.req.uid == uid:
                task.req.phase = CANCELLED
                task.req.done = True
                self.active.remove(task)
                return True
        return False

    # ------------------------------------------------------------- stepping

    def tick(self, free_slot: Callable[[set], int | None]
             ) -> list[PrefillTask]:
        """Run one step's worth of admission work.

        ``free_slot(exclude)`` returns a claimable slot index not in
        ``exclude``, or None (pool full).  Returns the tasks whose LAST
        chunk landed this tick — the engine merges them and their slots
        decode this same step.  Slots of tasks completed WITHIN this tick
        are excluded from claiming (the engine merges them only after the
        tick returns), so admission can never double-book a slot.

        Batched mode: claim queue heads into free (slot, lane) pairs up to
        ``chunks_per_step`` lanes, then advance ALL active tasks by one
        chunk in a single stacked forward.  Serial fallback: up to
        ``chunks_per_step`` sequential chunks of the single in-flight task.
        """
        if not self.batched:
            return self._tick_serial(free_slot)
        completed: list[PrefillTask] = []
        while self.queue and len(self.active) < self.lanes:
            slot = free_slot(set())
            if slot is None:
                break
            req = self.queue.popleft()
            req.phase = PREFILLING
            lane = min(set(range(self.lanes))
                       - {t.lane for t in self.active})
            # reset the lane: an empty ring at position 0 (a previous
            # occupant's stale keys would otherwise be causally visible)
            self._lane_state = self._insert_lane(self._lane_state,
                                                 self._fresh, lane)
            self.active.append(PrefillTask(req=req, slot=slot, lane=lane))
        if not self.active:
            return completed
        L, c = self.lanes, self.chunk
        toks = np.zeros((L, c), np.int32)
        lens = np.zeros((L,), np.int32)
        npl = np.full((L,), self._resolve_precision(None), np.int32)
        for t in self.active:
            end = min(t.offset + c, len(t.req.prompt))
            n = end - t.offset
            toks[t.lane, :n] = t.req.prompt[t.offset:end]
            lens[t.lane] = n
            npl[t.lane] = self._resolve_precision(t.req)
        if self.injector is not None:
            # fault hook: a raise here leaves the tick transactional — no
            # task offset moved, the lane state untouched (the forward is a
            # functional update), so the engine's retry re-runs this exact
            # chunk against this exact state.
            self.injector.raise_if("lane_forward")
        logits, self._lane_state = self._extend_lanes(
            self.params, self._lane_state, jnp.asarray(toks),
            jnp.asarray(lens), jnp.asarray(npl))
        self.forwards += 1
        still: list[PrefillTask] = []
        for t in self.active:
            t.offset += int(lens[t.lane])
            t.chunks_done += 1
            if t.offset >= len(t.req.prompt):
                t.logits = logits[t.lane:t.lane + 1]
                t.state = self._extract_lane(self._lane_state, t.lane)
                completed.append(t)
            else:
                still.append(t)
        self.active = still
        return completed

    def _tick_serial(self, free_slot: Callable[[set], int | None]
                     ) -> list[PrefillTask]:
        """Serial fallback: one task in flight, ``chunks_per_step``
        sequential chunks per tick (whole-prompt chunks for SWA)."""
        completed: list[PrefillTask] = []
        landed: set[int] = set()
        for _ in range(max(1, self.chunks_per_step)):
            if not self.active and self.queue:
                slot = free_slot(landed)
                if slot is None:
                    break
                req = self.queue.popleft()
                req.phase = PREFILLING
                self.active.append(PrefillTask(req=req, slot=slot))
            if not self.active:
                break
            task = self.active[0]
            if self._advance(task):
                completed.append(task)
                landed.add(task.slot)
                self.active.remove(task)
        return completed

    def _advance(self, task: PrefillTask) -> bool:
        """Process one prompt chunk; True when the prompt is fully in.

        Runs the (jitted, see ``__post_init__``) chunk forwards; the
        request's precision is a runtime argument, so back-to-back
        admissions at different plane budgets hit the same executable.
        """
        req = task.req
        P = len(req.prompt)
        c = self.chunk if self.chunk > 0 else P
        end = min(task.offset + c, P)
        tokens = jnp.asarray(req.prompt[None, task.offset:end])
        npl = self._chunk_precision(req)
        if self.injector is not None:
            self.injector.raise_if("lane_forward")  # see batched tick
        if task.offset == 0:
            task.logits, task.state = self._prefill_chunk(
                self.params, tokens, npl)
        else:
            task.logits, task.state = self._extend_chunk(
                self.params, task.state, tokens, npl)
        self.forwards += 1
        task.offset = end
        task.chunks_done += 1
        return end >= P
