"""Serving-layer configuration (engine-level knobs, not model config).

``ServeConfig`` is the ONE construction argument of ``ServeEngine`` beyond
``(model, params)``: pool geometry, the chunked-prefill admission pipeline,
sampling, the precision policy, and the optional SLO control loop all live
here.  Model-level execution knobs (DSLOT precision, block geometry) stay
in ``repro.configs.base.DslotConfig``.

Before this, ``ServeEngine.__init__`` had accreted ``n_slots`` /
``max_len`` / ``sample`` / ``precision_policy`` keywords alongside a
partial ``serve_config`` — the old keywords still work through a
deprecation shim (see ``ServeEngine``), but new code writes::

    eng = ServeEngine(model, params, ServeConfig(n_slots=4, max_len=512))
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

from repro.serve.slo import SloConfig


@dataclass(frozen=True)
class ServeConfig:
    """Engine construction knobs.

    n_slots: decode-pool width — concurrently DECODING requests.
    max_len: KV-ring capacity per slot.  ``try_add`` rejects requests with
        ``len(prompt) + max_new > max_len`` (the ring would wrap).
    prefill_chunk: prompt tokens processed per unit of admission work.  The
        engine runs at most ``chunks_per_step`` chunks of prefill per decode
        step, so this bounds the decode-stall an admission can inflict on
        live slots (one chunk forward instead of one full-prompt forward).
        ``0`` disables chunking: each admission prefills its whole prompt in
        one forward (the pre-pipeline blocking behaviour, still via the
        queue).
    chunks_per_step: admission-work budget per engine step.  1 (default)
        gives the paper-style overlap — one chunk of admission work rides
        along with every decode step; raise it to drain bursts faster.  On
        attention-only stacks the budget is spent as admission LANES: up to
        ``chunks_per_step`` PREFILLING requests advance together, one chunk
        each, in a single batched ragged-offset forward per step (so the
        per-step stall grows sub-linearly in the budget).  On the serial
        fallback (SWA whole-prompt admission, recurrent mixers) it is spent
        as sequential chunks of the single in-flight task.  Values below 1
        are clamped to 1 (admission cannot be paused through this knob).
    max_queue: bound on requests waiting in the admission queue (pending +
        in-flight prefill).  ``try_add`` returns False when full.  ``None``
        means unbounded.
    jit_prefill: jit-compile the per-chunk admission forwards
        (``model.prefill`` / ``model.extend``) with the request's DSLOT
        precision threaded as a traced argument — one compile per distinct
        chunk length (the fixed ``prefill_chunk`` plus each prompt's ragged
        tail), then every admission at every precision reuses the cache.
        Whole-prompt admission (``prefill_chunk == 0``, including the
        automatic SWA fallback) always runs eagerly: prompt lengths are
        unbounded, so jitting there would compile per distinct length.
        Disable for eager-mode debugging of the admission path.
    sample: token sampler ``(logits[, key]) -> (B,) i32``; ``None`` means
        greedy argmax.
    precision_policy: a ``repro.runtime`` precision policy consulted at
        enqueue for requests without an explicit ``n_planes`` and fed the
        planes-executed account on finish.  ``None`` disables.
    slo: SLO control-loop config (``repro.serve.slo.SloConfig``).  ``None``
        (default) disables load-driven plane shedding; a config builds one
        ``SloController`` owned by the engine.
    mesh: tensor-parallel device mesh (``jax.sharding.Mesh``, e.g. from
        ``repro.launch.mesh.make_test_mesh``).  The engine prepares the
        DSLOT weights N-sharded over ``mesh[tp_axis]`` and installs the
        mesh as the ``models/pspec.py`` constraint mesh, so every pooled
        decode step and batched admission lane issues ONE jitted sharded
        forward.  Token streams are bit-identical to ``mesh=None``
        (``tests/test_tensor_parallel.py``); see ``docs/distributed.md``.
    tp_axis: the mesh axis name the DSLOT N tiles shard over.
    """
    n_slots: int = 4
    max_len: int = 512
    prefill_chunk: int = 32
    chunks_per_step: int = 1
    max_queue: int | None = None
    jit_prefill: bool = True
    sample: Callable | None = None
    precision_policy: Any = None
    slo: SloConfig | None = None
    mesh: Any = None
    tp_axis: str = "model"
