"""Serving-layer configuration (engine-level knobs, not model config).

``ServeConfig`` controls the admission pipeline: how much prefill work the
engine is allowed to interleave with each pooled decode step, and how deep
the pending-request queue may grow.  Model-level execution knobs (DSLOT
precision, block geometry) stay in ``repro.configs.base.DslotConfig``.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ServeConfig:
    """Knobs for the chunked-prefill admission pipeline.

    prefill_chunk: prompt tokens processed per unit of admission work.  The
        engine runs at most ``chunks_per_step`` chunks of prefill per decode
        step, so this bounds the decode-stall an admission can inflict on
        live slots (one chunk forward instead of one full-prompt forward).
        ``0`` disables chunking: each admission prefills its whole prompt in
        one forward (the pre-pipeline blocking behaviour, still via the
        queue).
    chunks_per_step: admission-work budget per engine step.  1 (default)
        gives the paper-style overlap — one chunk of admission work rides
        along with every decode step; raise it to drain bursts faster.  On
        attention-only stacks the budget is spent as admission LANES: up to
        ``chunks_per_step`` PREFILLING requests advance together, one chunk
        each, in a single batched ragged-offset forward per step (so the
        per-step stall grows sub-linearly in the budget).  On the serial
        fallback (SWA whole-prompt admission, recurrent mixers) it is spent
        as sequential chunks of the single in-flight task.  Values below 1
        are clamped to 1 (admission cannot be paused through this knob).
    max_queue: bound on requests waiting in the admission queue (pending +
        in-flight prefill).  ``try_add`` returns False when full.  ``None``
        means unbounded.
    jit_prefill: jit-compile the per-chunk admission forwards
        (``model.prefill`` / ``model.extend``) with the request's DSLOT
        precision threaded as a traced argument — one compile per distinct
        chunk length (the fixed ``prefill_chunk`` plus each prompt's ragged
        tail), then every admission at every precision reuses the cache.
        Whole-prompt admission (``prefill_chunk == 0``, including the
        automatic SWA fallback) always runs eagerly: prompt lengths are
        unbounded, so jitting there would compile per distinct length.
        Disable for eager-mode debugging of the admission path.
    """
    prefill_chunk: int = 32
    chunks_per_step: int = 1
    max_queue: int | None = None
    jit_prefill: bool = True
