"""Serving-layer configuration (engine-level knobs, not model config).

``ServeConfig`` is the ONE construction argument of ``ServeEngine`` beyond
``(model, params)``: pool geometry, the chunked-prefill admission pipeline,
sampling, the precision policy, and the optional SLO control loop all live
here.  Model-level execution knobs (DSLOT precision, block geometry) stay
in ``repro.configs.base.DslotConfig``.

Before this, ``ServeEngine.__init__`` had accreted ``n_slots`` /
``max_len`` / ``sample`` / ``precision_policy`` keywords alongside a
partial ``serve_config`` — the old keywords still work through a
deprecation shim (see ``ServeEngine``), but new code writes::

    eng = ServeEngine(model, params, ServeConfig(n_slots=4, max_len=512))
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

from repro.serve.slo import SloConfig


@dataclass(frozen=True)
class ServeConfig:
    """Engine construction knobs.

    n_slots: decode-pool width — concurrently DECODING requests.
    max_len: KV-ring capacity per slot.  ``try_add`` rejects requests with
        ``len(prompt) + max_new > max_len`` (the ring would wrap).
    prefill_chunk: prompt tokens processed per unit of admission work.  The
        engine spends at most ``chunks_per_step`` chunks of prefill per
        decode step, so this bounds the decode-stall an admission can
        inflict on live slots (one chunk forward instead of one full-prompt
        forward).  Clamped to the KV-ring capacity — ``max_len``, or the
        sliding window when smaller (a wider chunk's pad phantoms would
        alias ring slots).  ``0`` disables chunking: each claimed admission
        prefills its whole remaining prompt in the tick's one batched
        forward; ``try_add`` then rejects prompts longer than the ring
        capacity (only reachable under SWA).
    chunks_per_step: admission-work budget per engine step, spent by the
        HYBRID tick.  It is both the LANE count — up to ``chunks_per_step``
        PREFILLING requests advance together, one chunk each, in a single
        batched ragged-offset forward per step (every zoo stack batches:
        attention, SWA, ssm, rglru) — and the sequential budget: leftover
        budget goes to extra chunks of the head (FIFO) task, so a lone
        admission drains ``chunks_per_step`` chunks per step.  1 (default)
        gives the paper-style overlap — one chunk of admission work rides
        along with every decode step; raise it to drain bursts faster.
        Values below 1 are clamped to 1 (admission cannot be paused through
        this knob).
    max_queue: bound on requests waiting in the admission queue (pending +
        in-flight prefill).  ``try_add`` returns False when full.  ``None``
        means unbounded.
    jit_prefill: jit-compile the batched lane forward (``model.extend``
        over the stacked lane state) with tokens padded to the fixed chunk
        width, ragged tails as a traced lengths vector, and per-lane DSLOT
        precision as a traced i32 vector — exactly ONE compile, total,
        shared by every admission at every precision and tail length.
        Whole-prompt admission (``prefill_chunk == 0``) always runs
        eagerly: per-tick widths are unbounded, so jitting there would
        compile per distinct width.  Disable for eager-mode debugging of
        the admission path.
    sample: token sampler ``(logits[, key]) -> (B,) i32``; ``None`` means
        greedy argmax.
    precision_policy: a ``repro.runtime`` precision policy consulted at
        enqueue for requests without an explicit ``n_planes`` and fed the
        planes-executed account on finish.  ``None`` disables.
    slo: SLO control-loop config (``repro.serve.slo.SloConfig``).  ``None``
        (default) disables load-driven plane shedding; a config builds one
        ``SloController`` owned by the engine.
    mesh: tensor-parallel device mesh (``jax.sharding.Mesh``, e.g. from
        ``repro.launch.mesh.make_test_mesh``).  The engine prepares the
        DSLOT weights N-sharded over ``mesh[tp_axis]`` and installs the
        mesh as the ``models/pspec.py`` constraint mesh, so every pooled
        decode step and batched admission lane issues ONE jitted sharded
        forward.  Token streams are bit-identical to ``mesh=None``
        (``tests/test_tensor_parallel.py``); see ``docs/distributed.md``.
    tp_axis: the mesh axis name the DSLOT N tiles shard over.
    default_deadline_steps: deadline (engine steps from enqueue) applied to
        requests that set no ``Request.deadline_steps`` of their own.  A
        request that has not finished within its deadline is EVICTED
        wherever it is — queued, mid-prefill, or decoding — with
        ``phase == "timeout"`` and a ``GenerateResult`` carrying whatever
        it produced; its slot and lane free the same step.  ``None``
        (default) disables engine-wide deadlines.
    max_step_retries: bounded retry budget for transient failures INSIDE
        one ``step()``: an exception from the admission tick or the pooled
        decode forward is retried up to this many times before the step
        gives that phase up (admission: the in-flight tasks are failed so a
        poisoned prompt cannot wedge the lane forever; decode: the pool
        stalls one step with state untouched).  ``step()`` never raises
        either way — see ``docs/serving.md``, "Failure modes and recovery".
    quarantine_nonfinite: detect non-finite (NaN/Inf) logit rows after
        every pooled decode step and QUARANTINE exactly the poisoned slot
        (``phase == "quarantined"``, slot freed, result attached).
        Surviving co-batched requests keep their exact token streams — the
        same isolation bar as cancel-mid-batch.  On by default; the check
        is one fused ``isfinite`` reduce inside the jitted step.
    faults: a ``repro.serve.faults.FaultPlan`` consulted at the engine's
        fault hook points — the deterministic fault-injection plane used by
        the chaos tests and ``bench_serve.py --chaos``.  ``None`` (default)
        injects nothing and skips every hook.
    """
    n_slots: int = 4
    max_len: int = 512
    prefill_chunk: int = 32
    chunks_per_step: int = 1
    max_queue: int | None = None
    jit_prefill: bool = True
    sample: Callable | None = None
    precision_policy: Any = None
    slo: SloConfig | None = None
    mesh: Any = None
    tp_axis: str = "model"
    default_deadline_steps: int | None = None
    max_step_retries: int = 2
    quarantine_nonfinite: bool = True
    faults: Any = None
