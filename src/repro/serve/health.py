"""Engine self-auditing: the slot/queue/lane/ring accounting invariants.

``check_invariants(engine)`` is the crash-consistency oracle the hardening
contract is stated against (``docs/serving.md``, "Failure modes and
recovery"): after ANY ``step()`` — including one that absorbed an injected
exception, quarantined a poisoned slot, evicted a timed-out request, or
retried a transient lane failure — the engine must still satisfy every
invariant here, and the next ``step()`` must be able to proceed.  The
chaos tests and ``bench_serve.py --chaos`` call it after every tick.

The invariants (violations are collected, not short-circuited, so one
corrupted run reports everything that went wrong):

* **slots** — ``slot_req`` has exactly ``n_slots`` entries; every occupied
  slot holds a live (not ``done``) request in the DECODING phase, uids are
  unique across the whole engine.
* **admission lanes** — every in-flight ``PrefillTask`` reserves a distinct
  in-range slot that the pool does not also consider occupied, holds a
  distinct in-range lane, and has consumed a sane prefix of
  its prompt (``0 <= offset < len(prompt)``, PREFILLING, not done).
* **queue** — only PENDING, not-done requests; ``queue_depth`` equals
  queued + in-flight; ``max_queue`` (when set) is respected.
* **ring positions** — for every DECODING slot, the model's absolute
  position counter equals ``len(prompt) + len(out)`` exactly (each engine
  step that decodes advances both by one) and never exceeds ``max_len``
  (the ``try_add`` ring-wrap guard, re-checked here against the live
  state).
* **terminal states** — a closed engine holds no work at all.

``check_invariants`` raises :class:`InvariantViolation` listing every
failure; ``audit_engine`` returns the list instead (the benchmark gates on
it being empty without paying exception plumbing per step).
"""

from __future__ import annotations

import numpy as np

import jax

from repro.serve.prefill import DECODING, PENDING, PREFILLING

__all__ = ["InvariantViolation", "audit_engine", "check_invariants"]


class InvariantViolation(AssertionError):
    """Engine accounting is corrupt; carries every violated invariant."""

    def __init__(self, problems: list[str]):
        self.problems = list(problems)
        super().__init__(
            "engine invariants violated:\n  - " + "\n  - ".join(problems))


def audit_engine(engine) -> list[str]:
    """Audit an engine's accounting; returns [] when every invariant holds.

    Pure inspection — nothing is mutated, no model work runs.  The one
    device interaction is a ``device_get`` of the (B,) position vector for
    the ring check, so calling this every step is cheap enough for tests
    and benchmarks (skip it in a production loop).
    """
    problems: list[str] = []
    pipe = engine.pipeline
    n_slots = engine.n_slots

    if len(engine.slot_req) != n_slots:
        problems.append(
            f"slot_req has {len(engine.slot_req)} entries, expected "
            f"{n_slots}")

    # ------------------------------------------------------------ slots
    seen_uids: dict[int, str] = {}
    for i, req in enumerate(engine.slot_req):
        if req is None:
            continue
        where = f"slot {i}"
        if req.uid in seen_uids:
            problems.append(f"uid {req.uid} in {where} AND "
                            f"{seen_uids[req.uid]}")
        seen_uids[req.uid] = where
        if req.done:
            problems.append(f"{where}: request {req.uid} is done but still "
                            "occupies the pool")
        if req.phase != DECODING:
            problems.append(f"{where}: request {req.uid} has phase "
                            f"{req.phase!r}, expected {DECODING!r}")

    # ------------------------------------------------- admission lanes
    held_slots: set[int] = set()
    held_lanes: set[int] = set()
    for task in pipe.active:
        req = task.req
        where = f"prefill task uid={req.uid}"
        if req.uid in seen_uids:
            problems.append(f"uid {req.uid} in {where} AND "
                            f"{seen_uids[req.uid]}")
        seen_uids[req.uid] = where
        if not (0 <= task.slot < n_slots):
            problems.append(f"{where}: slot {task.slot} out of range")
        elif engine.slot_req[task.slot] is not None:
            problems.append(f"{where}: reserved slot {task.slot} is ALSO "
                            "occupied by the decode pool")
        if task.slot in held_slots:
            problems.append(f"{where}: slot {task.slot} double-booked")
        held_slots.add(task.slot)
        if not (0 <= task.lane < pipe.lanes):
            problems.append(f"{where}: lane {task.lane} out of range "
                            f"[0, {pipe.lanes})")
        if task.lane in held_lanes:
            problems.append(f"{where}: lane {task.lane} double-booked")
        held_lanes.add(task.lane)
        if not (0 <= task.offset < len(req.prompt)):
            problems.append(
                f"{where}: offset {task.offset} outside prompt "
                f"[0, {len(req.prompt)})")
        if req.done:
            problems.append(f"{where}: request is done but still in flight")
        if req.phase != PREFILLING:
            problems.append(f"{where}: phase {req.phase!r}, expected "
                            f"{PREFILLING!r}")

    # ------------------------------------------------------------ queue
    for req in pipe.queue:
        where = f"queued uid={req.uid}"
        if req.uid in seen_uids:
            problems.append(f"uid {req.uid} in {where} AND "
                            f"{seen_uids[req.uid]}")
        seen_uids[req.uid] = where
        if req.done:
            problems.append(f"{where}: done request still queued")
        if req.phase != PENDING:
            problems.append(f"{where}: phase {req.phase!r}, expected "
                            f"{PENDING!r}")
    if engine.queue_depth != len(pipe.queue) + len(pipe.active):
        problems.append(
            f"queue_depth {engine.queue_depth} != queued "
            f"{len(pipe.queue)} + in-flight {len(pipe.active)}")
    if pipe.max_queue is not None and len(pipe) > pipe.max_queue:
        problems.append(f"admission backlog {len(pipe)} exceeds max_queue "
                        f"{pipe.max_queue}")

    # -------------------------------------------------- ring positions
    pos = engine.state.get("pos") if isinstance(engine.state, dict) else None
    if pos is not None:
        pos = np.asarray(jax.device_get(pos))
        for i, req in enumerate(engine.slot_req):
            if req is None:
                continue
            expect = len(req.prompt) + len(req.out)
            if int(pos[i]) != expect:
                problems.append(
                    f"slot {i}: ring position {int(pos[i])} != "
                    f"len(prompt)+len(out) = {expect} (uid {req.uid})")
            if int(pos[i]) > engine.max_len:
                problems.append(
                    f"slot {i}: ring position {int(pos[i])} exceeds "
                    f"max_len {engine.max_len} (uid {req.uid})")

    # --------------------------------------------------------- closed
    if getattr(engine, "closed", False):
        if seen_uids:
            problems.append(
                f"closed engine still holds work: {sorted(seen_uids)}")

    return problems


def check_invariants(engine) -> None:
    """Raise :class:`InvariantViolation` unless every invariant holds."""
    problems = audit_engine(engine)
    if problems:
        raise InvariantViolation(problems)
