"""Deterministic fault-injection plane for the serving engine.

The engine's hardening contracts (quarantine isolation, crash-consistent
``step()``, deadline eviction, graceful drain — see ``serve/engine.py`` and
``docs/serving.md`` "Failure modes and recovery") are only worth anything
if they can be *exercised on demand*: a NaN logit or a Mosaic lowering
exception shows up once a week in production and never in CI.  This module
makes failure a first-class, **replayable** input: a :class:`FaultPlan` is
a plain declarative list of :class:`Fault` records (what kind, which engine
step, which slot/request), the engine builds one :class:`FaultInjector` per
run from ``ServeConfig.faults``, and consults it at five fixed hook points:

===================  ========================================================
hook (where)          fault kinds it serves
===================  ========================================================
step begin (engine)  ``slow_step`` (artificial latency), ``cancel``
                     (cancel storms driven from the plan, so a storm is as
                     replayable as any other fault)
admission tick       ``admission_exception`` — raised from inside the
(engine)             engine's admission work, before any pipeline state
                     moves
lane forward         ``lane_exception`` — raised from inside
(prefill pipeline)   ``PrefillPipeline`` immediately before the (batched or
                     serial) chunk forward, the spot a real Mosaic/XLA
                     failure would surface
post-forward logits  ``nan_logits`` / ``inf_logits`` — poison one slot's
(engine)             logit row AFTER the jitted decode forward (an eager
                     ``where``, so nothing recompiles and nothing leaks
                     into other rows)
ring write (engine)  ``kv_corrupt`` — scribble NaN over one slot's
                     floating-point KV-ring rows after the step's state
                     commit (int leaves — ring positions — are left alone)
decode forward       ``decode_exception`` — raised before the jitted pooled
(engine)             decode call (exercises the bounded-retry path)
===================  ========================================================

Determinism and replay: a plan is immutable; an injector consumes its own
working copy and records every fault it actually fired (``fired`` — step,
kind, target) so a chaos run can be audited and replayed exactly.  Faults
whose target is a request (``uid=``) stay *pending* until the target is
resolvable (e.g. the request reaches a decode slot) and fire at the first
eligible step — the plan says "poison request 7 once it is decoding, from
step 5 on", not "hope request 7 is in slot 2 at step 5".  Exception faults
raise ``count`` times total (one per consult), so a ``count=2`` transient
fault exercises exactly two retries and then heals.

``FaultPlan.random(seed, ...)`` draws a seeded storm (same seed, same
plan) for property tests; the chaos benchmark (``bench_serve.py --chaos``)
composes a hand-written plan instead so its gates are analytic.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

import numpy as np

import jax.numpy as jnp

__all__ = ["Fault", "FaultPlan", "FaultInjector", "TransientFault",
           "FAULT_KINDS"]

FAULT_KINDS = ("nan_logits", "inf_logits", "kv_corrupt", "lane_exception",
               "admission_exception", "decode_exception", "cancel",
               "slow_step")

# exception kinds -> the hook (consult site) they fire at
_RAISE_SITES = {"lane_exception": "lane_forward",
                "admission_exception": "admission_tick",
                "decode_exception": "decode_forward"}


class TransientFault(RuntimeError):
    """The injected stand-in for a transient backend failure (a lane or
    decode forward raising).  The engine's retry machinery treats it like
    any other exception; tests match on this type to distinguish injected
    faults from real bugs."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One planned fault.

    kind: one of ``FAULT_KINDS``.
    step: first engine step (the ``ServeEngine.steps`` clock) the fault is
        eligible to fire.  Target-bound faults (``uid=``) wait past this
        step until the target is resolvable.
    slot: target pool slot (``nan_logits`` / ``inf_logits`` /
        ``kv_corrupt``).  Ignored when ``uid`` is set.
    uid: target request — resolved to whatever slot the request occupies
        when the fault fires (robust to admission timing).  For ``cancel``
        this is the request to cancel.
    count: exception faults raise this many times total (one per consult);
        other kinds fire once.
    value: payload — seconds for ``slow_step``.
    """
    kind: str
    step: int
    slot: int | None = None
    uid: int | None = None
    count: int = 1
    value: float | None = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(known: {FAULT_KINDS})")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, replayable set of faults (``ServeConfig.faults``).

    The plan is pure data: building an engine from the same plan (and the
    same workload) replays the same failure schedule.  ``seed`` records the
    draw that produced a :meth:`random` plan — informational, the faults
    tuple is already materialized.
    """
    faults: tuple[Fault, ...] = ()
    seed: int | None = None

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))

    def __len__(self) -> int:
        return len(self.faults)

    @classmethod
    def random(cls, seed: int, *, n_faults: int = 4, max_step: int = 32,
               n_slots: int = 4, uids: Iterable[int] = (),
               kinds: Iterable[str] = ("nan_logits", "lane_exception",
                                       "decode_exception", "kv_corrupt"),
               ) -> "FaultPlan":
        """A seeded storm: ``n_faults`` draws over ``kinds``, steps in
        ``[1, max_step]``, slot/uid targets drawn from the given ranges.
        Same seed, same plan — the chaos property tests lean on this."""
        rng = np.random.default_rng(seed)
        kinds = tuple(kinds)
        uids = tuple(uids)
        faults = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            step = int(rng.integers(1, max_step + 1))
            slot = uid = None
            if kind in ("nan_logits", "inf_logits", "kv_corrupt", "cancel"):
                if uids and (kind == "cancel" or rng.integers(2)):
                    uid = int(uids[int(rng.integers(len(uids)))])
                else:
                    slot = int(rng.integers(n_slots))
            count = int(rng.integers(1, 3)) \
                if kind in _RAISE_SITES else 1
            faults.append(Fault(kind=kind, step=step, slot=slot, uid=uid,
                                count=count))
        return cls(faults=tuple(faults), seed=seed)


@dataclasses.dataclass
class _Armed:
    """Injector-private mutable working copy of one planned fault."""
    fault: Fault
    remaining: int


class FaultInjector:
    """Consumes a :class:`FaultPlan` against a live engine run.

    The engine calls :meth:`begin_step` once per ``step()`` and then
    consults the hook methods below; each returns quickly when nothing is
    armed for the current step.  Every fault that actually fires is
    appended to ``fired`` as ``(step, kind, target)`` — the replay record.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._pending: list[_Armed] = [
            _Armed(fault=f, remaining=max(1, f.count)) for f in plan.faults]
        self.fired: list[tuple[int, str, int | None]] = []
        self.step = 0

    def begin_step(self, step: int) -> None:
        self.step = step

    @property
    def exhausted(self) -> bool:
        """True once every planned fault has fully fired."""
        return not self._pending

    # -------------------------------------------------------------- hooks

    def _take(self, kind: str, ready: Callable[[Fault], bool] | None = None
              ) -> list[Fault]:
        out = []
        for a in list(self._pending):
            f = a.fault
            if f.kind != kind or f.step > self.step:
                continue
            if ready is not None and not ready(f):
                continue                       # stays pending; retried later
            out.append(f)
            self._pending.remove(a)
        return out

    def raise_if(self, site: str) -> None:
        """Consult an exception hook (``"lane_forward"`` /
        ``"admission_tick"`` / ``"decode_forward"``): raises
        :class:`TransientFault` once per armed count, in plan order."""
        for a in self._pending:
            f = a.fault
            if (_RAISE_SITES.get(f.kind) == site and f.step <= self.step):
                a.remaining -= 1
                if a.remaining <= 0:
                    self._pending.remove(a)
                self.fired.append((self.step, f.kind, f.uid or f.slot))
                raise TransientFault(
                    f"injected {f.kind} at step {self.step} "
                    f"({a.remaining} remaining)")

    def slow_steps(self) -> list[Fault]:
        """Armed ``slow_step`` faults for this step (engine sleeps)."""
        out = self._take("slow_step")
        for f in out:
            self.fired.append((self.step, f.kind, None))
        return out

    def cancels(self) -> list[int]:
        """Request uids the plan cancels this step (cancel storms)."""
        out = self._take("cancel")
        uids = []
        for f in out:
            self.fired.append((self.step, f.kind, f.uid))
            if f.uid is not None:
                uids.append(f.uid)
        return uids

    def poison_logits(self, logits, resolve: Callable[[Fault], int | None]):
        """Post-forward logit hook: overwrite one slot's logit row with
        NaN/Inf.  ``resolve(fault)`` maps a fault to a pool slot (engine
        resolves ``uid`` targets; returns None while unresolvable, which
        keeps the fault pending).  Runs EAGERLY on the already-computed
        logits — nothing recompiles, no other row is touched."""
        poisoned = False
        for kind, val in (("nan_logits", jnp.nan), ("inf_logits", jnp.inf)):
            for f in self._take(kind, ready=lambda f: resolve(f) is not None):
                slot = resolve(f)
                self.fired.append((self.step, kind, slot))
                row = jnp.arange(logits.shape[0]) == slot
                logits = jnp.where(row[:, None], jnp.asarray(
                    val, logits.dtype), logits)
                poisoned = True
        return logits, poisoned

    def kv_corruptions(self, resolve: Callable[[Fault], int | None]
                       ) -> list[int]:
        """Ring-write hook: pool slots whose KV rows the engine must
        scribble this step (the engine owns the state layout)."""
        slots = []
        for f in self._take("kv_corrupt",
                            ready=lambda f: resolve(f) is not None):
            slot = resolve(f)
            self.fired.append((self.step, "kv_corrupt", slot))
            slots.append(slot)
        return slots

    def summary(self) -> dict:
        """JSON-ready account: what fired when, what never became firable."""
        return {
            "planned": len(self.plan),
            "fired": [{"step": s, "kind": k, "target": t}
                      for s, k, t in self.fired],
            "unfired": [dataclasses.asdict(a.fault) for a in self._pending],
        }
