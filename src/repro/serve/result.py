"""Unified generation result type for both serving paths.

``generate`` (the batch API) returns a :class:`GenerateResult`; the
slot-pool engine attaches one to every finished request
(``Request.result``).  Before this, the batch path returned an ad-hoc
``(tokens, stats_dict)`` tuple under ``return_stats=True`` while the engine
handed back mutated ``Request`` objects whose accounting lived in three
separate attributes — the same information, two shapes.

Conventions:

* ``tokens`` is a ``(B, T)`` array on the batch path and a ``list[int]``
  on the engine path (one request = one sequence).
* plane statistics (``planes_used_mean`` / ``skipped_frac``) are ``None``
  unless the model ran the DSLOT digit-serial path; on the batch path they
  are per-request ``(B,)`` arrays, on the engine path python floats.
* ``ttft_steps`` / ``steps`` are in the engine-steps clock and ``None`` on
  the batch path (no admission queue, so there is no TTFT to observe).
"""

from __future__ import annotations

import dataclasses
from typing import Any

__all__ = ["GenerateResult"]


@dataclasses.dataclass
class GenerateResult:
    """What one generation produced, and what it cost.

    tokens: generated tokens — (B, T) array (batch path) or list[int]
        (engine path).
    n_planes: the granted DSLOT plane budget the run decoded at (int,
        per-request (B,) array, or None when the digit-serial path is off).
    planes_used_mean: effective digit planes executed per output row —
        the paper's energy proxy (None when DSLOT is off).
    skipped_frac: fraction of the granted plane budget not executed —
        activation-side early termination plus the weight-side static MSR
        bound (the two compound; see planes_bounded_mean for the static
        share alone).
    planes_bounded_mean: mean digit planes per output tile never ISSUED
        because the prepare-time weight-side MSR bound capped the tile
        (request-independent, so a scalar on both paths; None when DSLOT
        is off or the prepared weights carry no bound).
    ttft_steps: engine steps from enqueue to first token (engine path).
    steps: engine steps from enqueue to finish (engine path) or the decode
        length (batch path).
    phase: terminal lifecycle phase — "done" on the batch path; the engine
        additionally evicts with "cancelled", "timeout" (deadline expired),
        "quarantined" (non-finite logits isolated) or "failed" (admission
        kept raising past the retry budget) — see ``docs/serving.md``,
        "Failure modes and recovery".
    uid / tier: request identity and QoS tier (engine path only).
    """
    tokens: Any
    n_planes: Any = None
    planes_used_mean: Any = None
    skipped_frac: Any = None
    planes_bounded_mean: Any = None
    ttft_steps: int | None = None
    steps: int | None = None
    phase: str = "done"
    uid: int | None = None
    tier: str | None = None

    @property
    def stats(self) -> dict:
        """The legacy ``generate(..., return_stats=True)`` stats dict."""
        if self.planes_used_mean is None:
            return {}
        return {"planes_used_mean": self.planes_used_mean,
                "skipped_frac": self.skipped_frac}
