"""SLO-aware precision-elastic control: trade digit planes for latency.

The paper's headline property — precision tunable at run time — lets this
serving stack do something no fixed-precision engine can: when load spikes,
*shed digit planes* instead of letting the queue blow up, and restore them
when the burst drains.  Because ``n_planes`` is a traced runtime argument
all the way into the kernel (zero retrace cost — see ``kernels/ops.py``),
the controller can move per-slot budgets every engine step for free.

:class:`SloController` closes that loop on load.  Each engine step it
ingests a :class:`SloSignals` snapshot (admission queue depth, the TTFTs of
requests that just produced their first token, whether the step carried
admission work, pooled planes-used) and maintains one *plane level* per QoS
tier.  ``ServeEngine._budget_vector`` then clamps every slot's granted
budget to its tier's current level, so shedding reaches the very next
pooled decode step.

QoS tiers (``Request.tier``):

* ``"reserved"`` — floor pinned at full precision (``n_bits``): never shed.
  The paid tier; the controller may raise a lower explicit budget to the
  floor.
* ``"standard"`` — full elastic range; shed only after degradable is at its
  floor.
* ``"degradable"`` — shed first, down to a 1-plane floor.  The free tier.

Control law (plain python, runs OUTSIDE jit between steps, like the
``repro.runtime`` policies):

* *pressure* when the queue is deeper than ``queue_high_water`` OR the
  rolling-window p95 TTFT (engine-steps domain) exceeds
  ``target_ttft_steps`` OR any request was deadline-evicted this step
  (``SloSignals.timed_out`` — a missed deadline is direct overload
  evidence, so it feeds shed decisions immediately);
* *slack* when the queue is empty, the window p95 is within target, and
  nothing timed out;
* **hysteresis**: shedding requires ``shed_patience`` consecutive pressure
  steps, restoring requires ``restore_patience`` consecutive slack steps,
  and any neutral step resets both counters — so budgets cannot oscillate
  on a boundary load.
* shed order: degradable -> standard -> (reserved only if its spec allows),
  one ``shed_step`` at a time; restore runs in the reverse order, so the
  most important tier recovers first.

The controller reuses :class:`repro.runtime.PolicyFeedback` for the
per-request planes-executed account the engine already produces: ``observe``
keeps a per-tier EMA of the planes actually used, which the overload
benchmark reports as the accuracy side of the Pareto sweep
(``benchmarks/bench_serve.py`` -> ``BENCH_serve.json``).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable, Mapping

from repro.runtime.policy import PolicyFeedback

__all__ = ["RESERVED", "STANDARD", "DEGRADABLE", "TIERS", "TierSpec",
           "default_tiers", "SloConfig", "SloSignals", "SloController"]

# QoS tier names (``Request.tier``).
RESERVED = "reserved"        # floor at full precision — never shed
STANDARD = "standard"        # full elastic range — shed after degradable
DEGRADABLE = "degradable"    # shed first, deepest floor
TIERS = (RESERVED, STANDARD, DEGRADABLE)


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """Plane floor/ceiling of one QoS tier, and where it sits in the shed
    order (lower ``shed_order`` sheds first)."""
    floor: int
    ceiling: int
    shed_order: int

    def clamp(self, n_planes: int, level: int) -> int:
        """Effective budget: granted ``n_planes`` capped by the controller
        ``level``, never below the tier floor."""
        return max(self.floor, min(int(n_planes), level))


def default_tiers(n_bits: int) -> dict[str, TierSpec]:
    """The stock three-tier table at a given digit width."""
    return {
        RESERVED: TierSpec(floor=n_bits, ceiling=n_bits, shed_order=2),
        STANDARD: TierSpec(floor=min(2, n_bits), ceiling=n_bits,
                           shed_order=1),
        DEGRADABLE: TierSpec(floor=1, ceiling=n_bits, shed_order=0),
    }


@dataclasses.dataclass(frozen=True)
class SloConfig:
    """Knobs of the SLO control loop (``ServeConfig.slo``).

    target_ttft_steps: p95 TTFT target, in ENGINE STEPS — the deterministic
        clock ``Request.ttft_steps`` is measured in (wall-clock targets
        would make the control law depend on host speed).
    queue_high_water: admission-queue depth treated as overload pressure.
    ttft_window: rolling window (samples) the p95 is computed over.
    shed_patience / restore_patience: consecutive pressure / slack steps
        required before acting — the hysteresis that stops oscillation.
        Restoring should be the slower of the two.
    shed_step / restore_step: planes moved per action.
    ttft_idle_expiry: consecutive idle updates (empty queue, no new first
        tokens) after which the rolling TTFT window is cleared.  Without
        this, the p95 of a fully-drained burst would read "hot" forever —
        no new arrivals means no new samples to roll the stale ones out —
        and budgets would never restore.
    tiers: override the ``default_tiers`` table (floors/ceilings are
        clamped to [1, n_bits] at controller construction).
    """
    target_ttft_steps: int = 8
    queue_high_water: int = 4
    ttft_window: int = 32
    shed_patience: int = 2
    restore_patience: int = 4
    shed_step: int = 1
    restore_step: int = 1
    ttft_idle_expiry: int = 8
    tiers: Mapping[str, TierSpec] | None = None


@dataclasses.dataclass
class SloSignals:
    """One engine step's load snapshot, fed to ``SloController.update``."""
    queue_depth: int                       # pending + prefilling requests
    ttft_steps: list[int] = dataclasses.field(default_factory=list)
    decode_stalled: bool = False           # step carried admission work
    planes_used_mean: float | None = None  # pooled per-row planes this step
    timed_out: int = 0                     # deadline evictions this step —
                                           # missed deadlines are the most
                                           # direct overload evidence there
                                           # is, so any count is pressure


class SloController:
    """Per-tier plane levels driven by load, with hysteresis.

    The engine owns exactly one controller (``ServeEngine.slo``) and calls
    ``update`` once per step before building the slot budget vector;
    ``budget_for`` maps a request's granted budget through its tier's
    current level.  All state is plain python — nothing here is traced.
    """

    def __init__(self, n_bits: int, cfg: SloConfig | None = None):
        self.cfg = cfg or SloConfig()
        self.n_bits = int(n_bits)
        tiers = dict(self.cfg.tiers) if self.cfg.tiers is not None \
            else default_tiers(self.n_bits)
        self.tiers: dict[str, TierSpec] = {
            name: TierSpec(floor=max(1, min(t.floor, self.n_bits)),
                           ceiling=max(1, min(t.ceiling, self.n_bits)),
                           shed_order=t.shed_order)
            for name, t in tiers.items()}
        # current allowance per tier; starts fully restored
        self.levels: dict[str, int] = {n: t.ceiling
                                       for n, t in self.tiers.items()}
        self.min_levels: dict[str, int] = dict(self.levels)
        self.shed_events = 0
        self.restore_events = 0
        self.steps = 0
        self.planes_used_ema: dict[str, float] = {}
        self._ttfts: deque[int] = deque(maxlen=self.cfg.ttft_window)
        self._hot = 0
        self._cool = 0
        self._idle = 0

    # ------------------------------------------------------------- queries

    def budget_for(self, tier: str, n_planes: int) -> int:
        """Effective plane budget for a slot: granted budget through the
        tier's floor/ceiling and current shed level."""
        spec = self.tiers[tier]
        return spec.clamp(n_planes, self.levels[tier])

    def floor(self, tier: str) -> int:
        return self.tiers[tier].floor

    def ttft_p95(self) -> float | None:
        """Rolling-window p95 TTFT (engine steps), None before any sample."""
        if not self._ttfts:
            return None
        xs = sorted(self._ttfts)
        return float(xs[min(len(xs) - 1, int(0.95 * (len(xs) - 1) + 0.5))])

    # ------------------------------------------------------------- control

    def update(self, sig: SloSignals) -> dict[str, int]:
        """Ingest one step's signals; returns the (possibly moved) levels."""
        self.steps += 1
        if sig.ttft_steps:
            self._ttfts.extend(int(t) for t in sig.ttft_steps)
            self._idle = 0
        elif sig.queue_depth == 0:
            # idle expiry: a drained burst's TTFTs stop describing current
            # load once nothing has arrived for a while (see SloConfig)
            self._idle += 1
            if self._idle >= self.cfg.ttft_idle_expiry:
                self._ttfts.clear()
        else:
            self._idle = 0
        p95 = self.ttft_p95()
        ttft_hot = p95 is not None and p95 > self.cfg.target_ttft_steps
        ttft_ok = p95 is None or p95 <= self.cfg.target_ttft_steps
        pressure = (sig.queue_depth > self.cfg.queue_high_water or ttft_hot
                    or sig.timed_out > 0)
        slack = sig.queue_depth == 0 and ttft_ok and sig.timed_out == 0
        if pressure:
            self._hot += 1
            self._cool = 0
        elif slack:
            self._cool += 1
            self._hot = 0
        else:                       # neutral: hysteresis counters reset
            self._hot = 0
            self._cool = 0
        if self._hot >= self.cfg.shed_patience:
            self._shed()
            self._hot = 0
        if self._cool >= self.cfg.restore_patience:
            self._restore()
            self._cool = 0
        for n, lv in self.levels.items():
            self.min_levels[n] = min(self.min_levels[n], lv)
        return dict(self.levels)

    def _order(self, reverse: bool = False) -> Iterable[str]:
        return sorted(self.tiers, key=lambda n: self.tiers[n].shed_order,
                      reverse=reverse)

    def _shed(self) -> bool:
        """Drop one tier by ``shed_step`` planes: the lowest-priority tier
        still above its floor.  Reserved (floor == ceiling) never moves."""
        for name in self._order():
            spec = self.tiers[name]
            if self.levels[name] > spec.floor:
                self.levels[name] = max(spec.floor,
                                        self.levels[name]
                                        - self.cfg.shed_step)
                self.shed_events += 1
                return True
        return False

    def _restore(self) -> bool:
        """Raise one tier by ``restore_step`` planes — reverse shed order,
        so the most important degraded tier recovers first."""
        for name in self._order(reverse=True):
            spec = self.tiers[name]
            if self.levels[name] < spec.ceiling:
                self.levels[name] = min(spec.ceiling,
                                        self.levels[name]
                                        + self.cfg.restore_step)
                self.restore_events += 1
                return True
        return False

    # ------------------------------------------------------------ feedback

    def observe(self, fb: PolicyFeedback) -> None:
        """Per-request planes-executed account (the same ``PolicyFeedback``
        the ``repro.runtime`` policies consume): per-tier EMA of the planes
        actually used — the accuracy side of the latency/accuracy trade,
        reported by the overload benchmark."""
        tier = fb.tier or STANDARD
        prev = self.planes_used_ema.get(tier)
        val = float(fb.planes_used_mean)
        self.planes_used_ema[tier] = val if prev is None \
            else 0.7 * prev + 0.3 * val

    def summary(self) -> dict:
        """JSON-ready controller account (benchmark / observability)."""
        return {
            "levels": dict(self.levels),
            "min_levels": dict(self.min_levels),
            "shed_events": self.shed_events,
            "restore_events": self.restore_events,
            "ttft_p95_steps": self.ttft_p95(),
            "planes_used_ema": {k: round(v, 3)
                                for k, v in self.planes_used_ema.items()},
        }
