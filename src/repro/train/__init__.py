"""train subpackage of the DSLOT-NN reproduction."""
