"""Sharding rules: FSDP x TP over the production mesh (DESIGN.md §5).

Two logical parallel dimensions:

* ``tp``   — the "model" mesh axis: Megatron-style tensor parallelism
  (column-parallel up-projections / attention QKV, row-parallel
  down-projections / attention output, vocab-sharded embedding + logits).
* ``fsdp`` — the "data" axis (and "pod" when present): ZeRO-3 storage
  sharding of the non-TP weight dimension; GSPMD inserts the all-gather at
  use and the reduce-scatter on gradients.

Rules are path-pattern based over the parameter pytree; stacked
scan-over-layers params (a leading ``n_groups`` axis, path contains
"groups") get their spec shifted right by one None.
"""

from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def mesh_axes(mesh: Mesh) -> tuple:
    """(fsdp_axes, tp_axis) for the given mesh."""
    names = mesh.axis_names
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    tp = "model" if "model" in names else None
    return fsdp, tp


# (regex over the flattened path, spec builder given (fsdp, tp))
_RULES: list[tuple[str, object]] = [
    (r"embed/embedding$",        lambda f, t: P(t, f)),
    (r"head/w$",                 lambda f, t: P(f, t)),
    (r"(wq|wk|wv)/w$",           lambda f, t: P(f, t)),
    (r"(wq|wk|wv)/b$",           lambda f, t: P(t)),
    (r"wo/w$",                   lambda f, t: P(t, f)),
    (r"wo/b$",                   lambda f, t: P(None)),
    (r"mlp/(up|gate)/w$",        lambda f, t: P(f, t)),
    (r"mlp/down/w$",             lambda f, t: P(t, f)),
    (r"moe/router$",             lambda f, t: P(f, None)),
    (r"moe/(up|gate)$",          lambda f, t: P(None, f, t)),
    (r"moe/down$",               lambda f, t: P(None, t, f)),
    (r"mixer/w_in$",             lambda f, t: P(f, t)),
    (r"mixer/w_gate$",           lambda f, t: P(f, t)),
    (r"mixer/(wa|wx)$",          lambda f, t: P(f, t)),
    (r"mixer/conv_w$",           lambda f, t: P(None, t)),
    (r"mixer/(conv_b|norm_scale|ba|bx|lam)$", lambda f, t: P(t)),
    (r"mixer/w_out$",            lambda f, t: P(t, f)),
    (r"mixer/(A_log|D_skip|dt_bias)$", lambda f, t: P(None)),
    (r"(norm\d?|normx|final_norm|enc_norm)/(scale|bias)$",
     lambda f, t: P(None)),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspec(path, leaf) -> P:
    s = _path_str(path)
    stacked = "groups" in s.split("/")
    for pat, rule in _RULES:
        if re.search(pat, s):
            def build(f, t):
                spec = rule(f, t)
                if stacked:
                    spec = P(None, *spec)
                # trim spec to array rank
                spec = P(*tuple(spec)[: leaf.ndim]) if len(tuple(spec)) > leaf.ndim else spec
                return spec
            return build
    return lambda f, t: P()


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def sanitize_spec(mesh: Mesh, spec: P, shape) -> P:
    """jit in_shardings require exact divisibility; drop (→ replicate) any
    axis that does not divide its dimension (e.g. granite's vocab 49155)."""
    out = []
    for i, axis in enumerate(tuple(spec)):
        if axis is None or i >= len(shape):
            out.append(None)
            continue
        out.append(axis if shape[i] % _axis_size(mesh, axis) == 0 else None)
    return P(*out)


def make_param_shardings(mesh: Mesh, params):
    """NamedShardings for a parameter pytree (works on ShapeDtypeStructs)."""
    fsdp, tp = mesh_axes(mesh)
    f = fsdp if fsdp else None

    def one(path, leaf):
        builder = param_pspec(path, leaf)
        spec = sanitize_spec(mesh, builder(f, tp), leaf.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def batch_pspec(mesh: Mesh, global_batch: int) -> P:
    """Shard the batch dim over (pod, data) when divisible, else replicate."""
    fsdp, _ = mesh_axes(mesh)
    n = 1
    for a in fsdp:
        n *= mesh.shape[a]
    if fsdp and global_batch % n == 0:
        return P(fsdp)
    return P()


def make_batch_shardings(mesh: Mesh, batch, global_batch: int,
                         batch_axis: int = 0):
    """Shard the batch dimension of every array in the batch pytree.
    ``batch_axis=1`` for grad-accumulation layout (M, mb, ...)."""
    spec = batch_pspec(mesh, global_batch)
    axes = tuple(spec)[:1]

    def one(leaf):
        nd = getattr(leaf, "ndim", 0)
        if nd <= batch_axis or not axes:
            return NamedSharding(mesh, P())
        s = P(*((None,) * batch_axis), axes[0])
        return NamedSharding(mesh, s)

    return jax.tree_util.tree_map(one, batch)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
