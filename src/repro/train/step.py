"""Train step: grad accumulation over microbatches + AdamW, pjit-ready.

Batch layout is ``(M, mb, ...)`` — microbatch axis first, per-device batch on
axis 1 (sharded over (pod, data)).  The microbatch loop is a ``lax.scan``
whose per-step gradients are accumulated in f32; with FSDP shardings GSPMD
turns the gradient sum into reduce-scatters that overlap the next
microbatch's compute (XLA async collectives) — the standard
communication-hiding schedule.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.model_zoo import Model, loss_fn
from repro.optim.adamw import (AdamWConfig, OptState, adamw_update,
                               init_opt_state)


class TrainState(NamedTuple):
    params: dict
    opt: OptState
    step: jax.Array


def init_train_state(model: Model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=init_opt_state(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(model: Model, opt_cfg: AdamWConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    batch: pytree of (M, mb, ...) arrays (tokens/labels/frontend/src_embeds).
    """

    def microbatch_grads(params, batch):
        def micro(carry, mb):
            g_acc, loss_acc, aux_acc = carry
            (tot, (ce, aux)), grads = jax.value_and_grad(
                lambda p: loss_fn(model, p, mb), has_aux=True)(params)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
            return (g_acc, loss_acc + ce, aux_acc + aux), None

        M = jax.tree.leaves(batch)[0].shape[0]
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g, loss, aux), _ = jax.lax.scan(
            micro, (g0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            batch)
        inv = 1.0 / M
        return jax.tree.map(lambda x: x * inv, g), loss * inv, aux * inv

    def train_step(state: TrainState, batch):
        grads, loss, aux = microbatch_grads(state.params, batch)
        params, opt, om = adamw_update(state.params, grads, state.opt, opt_cfg)
        metrics = {"loss": loss, "aux": aux, **om}
        return TrainState(params=params, opt=opt, step=state.step + 1), metrics

    return train_step
