"""DSLOT-NN core: online (MSDF) arithmetic, early termination, baselines."""

from .digits import (fixed_to_sd, first_negative_prefix, sd_from_value,
                     sd_prefix_values, sd_split_posneg, sd_to_value)
from .early_term import TerminationReport, early_termination
from .online import (DELTA_ADD, DELTA_MULT, online_add, online_add_tree,
                     online_emit, online_mult_sp)
from .pe import PESchedule, pe_output_scale, pe_schedule, pe_sop_digits
from .quantize import QTensor, dequantize, quantize, quantize_unsigned
from .sip import SIPSchedule, sip_schedule, sip_sop, sip_sop_trace
from .cycle_model import FPGAModel, TABLE1_PUBLISHED, table1_model
from .conv import (DSLOTConvResult, dslot_conv2d_stats, extract_windows,
                   im2col, sip_conv2d)
from .csd import (binary_digit_count, csd_matmul, csd_planes_nonzero,
                  csd_recode, essential_digit_count)
from .msr import msr_depths, msr_histogram, quantize_weights, tile_plane_bound

__all__ = [
    "fixed_to_sd", "first_negative_prefix", "sd_from_value",
    "sd_prefix_values", "sd_split_posneg", "sd_to_value",
    "TerminationReport", "early_termination",
    "DELTA_ADD", "DELTA_MULT", "online_add", "online_add_tree",
    "online_emit", "online_mult_sp",
    "PESchedule", "pe_output_scale", "pe_schedule", "pe_sop_digits",
    "QTensor", "dequantize", "quantize", "quantize_unsigned",
    "SIPSchedule", "sip_schedule", "sip_sop", "sip_sop_trace",
    "FPGAModel", "TABLE1_PUBLISHED", "table1_model",
    "DSLOTConvResult", "dslot_conv2d_stats", "extract_windows", "im2col",
    "sip_conv2d",
    "binary_digit_count", "csd_matmul", "csd_planes_nonzero", "csd_recode",
    "essential_digit_count",
    "msr_depths", "msr_histogram", "quantize_weights", "tile_plane_bound",
]
