"""DSLOT-NN processing engine (paper Fig. 3) and its cycle schedule (eq. 6).

A PE holds ``k*k`` serial-parallel online multipliers (weights parallel /
stationary, activations digit-serial) feeding a digit-pipelined reduction tree
of online adders; it emits the window's SOP digit stream MSDF.  Because every
tree stage scales by 1/2 (bit-growth bookkeeping), a PE with S tree stages
emits ``SOP / 2^S`` — ``pe_output_scale`` reports the factor to undo.

The cycle schedule is *analytic* (the functional simulation produces values and
digit indices; eq. 6 maps digit indices to hardware cycles):

    Num_cycles = delta_x + delta_+ * ceil(log2(k*k))
               + delta_+ * ceil(log2(N)) + p_out                    (eq. 6)
    p_out      = p_mult + ceil(log2(k*k))                           (eq. 7)

so SOP digit j is available at cycle ``pipeline_fill + j`` where
``pipeline_fill = delta_x + delta_+ * (S_tree + S_fmaps)``.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .online import (DELTA_ADD, DELTA_MULT, online_add_tree, online_mult_sp)

__all__ = ["PESchedule", "pe_schedule", "pe_sop_digits", "pe_output_scale"]


class PESchedule(NamedTuple):
    """Analytic timing of one PE evaluation (all counts in cycles)."""
    delta_mult: int
    delta_add: int
    tree_stages: int       # ceil(log2(k*k))
    fmap_stages: int       # ceil(log2(N)) — cross-feature-map reduction
    p_mult: int            # product digits emitted by each OLM
    p_out: int             # SOP digits (eq. 7)
    pipeline_fill: int     # cycles before the first SOP digit appears
    total_cycles: int      # eq. 6

    def cycle_of_digit(self, j: jax.Array | int) -> jax.Array | int:
        """Hardware cycle at which SOP digit j (1-based) is available."""
        return self.pipeline_fill + j


def pe_schedule(k: int, n_fmaps: int = 1, p_mult: int = 16,
                delta_mult: int = DELTA_MULT, delta_add: int = DELTA_ADD
                ) -> PESchedule:
    """Paper eq. 6/7.  Defaults reproduce the paper's 33-cycle example:
    k=5, N=1, p_mult=16 -> p_out=21, Num_cycles=33."""
    tree_stages = max(0, math.ceil(math.log2(k * k)))
    fmap_stages = max(0, math.ceil(math.log2(n_fmaps))) if n_fmaps > 1 else 0
    p_out = p_mult + tree_stages
    fill = delta_mult + delta_add * tree_stages + delta_add * fmap_stages
    total = fill + p_out
    return PESchedule(delta_mult=delta_mult, delta_add=delta_add,
                      tree_stages=tree_stages, fmap_stages=fmap_stages,
                      p_mult=p_mult, p_out=p_out, pipeline_fill=fill,
                      total_cycles=total)


def pe_output_scale(schedule: PESchedule) -> float:
    """SOP = emitted_value * 2^(tree_stages + fmap_stages)."""
    return float(2 ** (schedule.tree_stages + schedule.fmap_stages))


def pe_sop_digits(x_digits: jax.Array, w_frac: jax.Array,
                  schedule: PESchedule) -> jax.Array:
    """Run one PE: ``k*k`` OLMs + online-adder tree, fully vectorized.

    ``x_digits``: (n_in_digits, taps, *batch) SD streams — the ``k*k`` window
        activations, digit-serial (taps = k*k, or k*k*N flattened with the
        feature-map reduction folded into the same tree).
    ``w_frac``:   (taps, *batch-broadcastable) parallel weight fractions,
        ``|w| < 1`` (stationary operand of the serial-parallel OLM).

    Returns the SOP digit stream ``(p_out, *batch)`` representing
    ``sum_taps(x*w) / 2^stages`` MSDF.
    """
    prods = online_mult_sp(x_digits, w_frac, n_out=schedule.p_mult,
                           delta=schedule.delta_mult)  # (p_mult, taps, *batch)
    streams = jnp.moveaxis(prods, 1, 0)                # (taps, p_mult, *batch)
    sop, stages = online_add_tree(streams, n_out=schedule.p_out,
                                  delta=schedule.delta_add)
    expected = schedule.tree_stages + schedule.fmap_stages
    if stages > expected:
        raise ValueError(f"tree deeper than schedule: {stages} > {expected}")
    return sop
