"""Early detection + termination of negative activations (paper Algorithm 1).

The ReLU unit accumulates the SOP's redundant output digits ``z+[j]``/``z-[j]``
and terminates the PE as soon as the concatenated prefix satisfies
``z+[j] < z-[j]`` — i.e. the prefix *value* went negative.  MSDF emission makes
this sound: once negative, the remaining digits (each weighted below the prefix
LSB) cannot restore positivity, so the convolution is ineffectual under ReLU
and its remaining cycles are skipped.

This module evaluates Algorithm 1 over whole batches of SOP digit streams and
returns per-SOP cycle accounting against the PE schedule (eq. 6) — the data
behind the paper's Fig. 8 (negative-activation rates) and Fig. 9 (cycle
savings).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .digits import first_negative_prefix, sd_prefix_values
from .pe import PESchedule

__all__ = ["TerminationReport", "early_termination"]


class TerminationReport(NamedTuple):
    """Per-SOP outcome of Algorithm 1 (leading axes = batch of SOPs)."""
    is_negative: jax.Array        # bool — termination signal ever fired
    term_digit: jax.Array         # int32 — 1-based digit index of firing (p_out+1 if never)
    cycles_used: jax.Array        # int32 — hardware cycles actually spent (eq. 6 schedule)
    cycles_full: int              # int — cycles without early termination
    cycles_saved: jax.Array       # int32 — cycles_full - cycles_used
    savings_frac: jax.Array       # float32 — cycles_saved / cycles_full

    @property
    def negative_rate(self):
        return jnp.mean(self.is_negative.astype(jnp.float32))

    @property
    def mean_savings(self):
        return jnp.mean(self.savings_frac)


def early_termination(sop_digits: jax.Array, schedule: PESchedule
                      ) -> TerminationReport:
    """Apply Algorithm 1 to SOP digit streams ``(p_out, *batch)``.

    A PE that never fires runs ``schedule.total_cycles``; one that fires at
    digit j stops at cycle ``pipeline_fill + j`` (the comparator sits on the
    output digits, so fill cycles are always paid).
    """
    p_out = sop_digits.shape[0]
    term = first_negative_prefix(sop_digits)            # (batch,), p_out+1 if none
    fired = term <= p_out
    full = int(schedule.total_cycles)
    used = jnp.where(fired, schedule.pipeline_fill + term, full).astype(jnp.int32)
    saved = (full - used).astype(jnp.int32)
    return TerminationReport(
        is_negative=fired,
        term_digit=term.astype(jnp.int32),
        cycles_used=used,
        cycles_full=full,
        cycles_saved=saved,
        savings_frac=saved.astype(jnp.float32) / float(full),
    )


def prefix_sign_trace(sop_digits: jax.Array) -> jax.Array:
    """Sign of every prefix value — diagnostic view of the comparator input."""
    return jnp.sign(sd_prefix_values(sop_digits))
