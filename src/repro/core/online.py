"""Online (MSDF) arithmetic operators: serial-parallel multiplier and adder.

Faithful functional models of the paper's two datapath primitives:

* ``online_mult_sp`` — the serial-parallel online multiplier of [15]
  (paper Fig. 2a): serial SD input ``x`` digit-by-digit MSDF, parallel constant
  operand ``Y``; output digits MSDF after an online delay ``delta = 2``.
* ``online_add`` — the digit-serial online adder (paper Fig. 2b, [16]):
  both inputs and the output are SD MSDF streams, ``delta = 2``.  To absorb the
  carry/bit growth of addition the adder emits the *scaled* sum ``(a + b) / 2``,
  mirroring the paper's ``p_out`` bit-growth bookkeeping (eq. 7): a depth-S
  reduction tree yields ``sum / 2^S`` with the scaling removed at dequantize.

Both are instances of one generic recurrence (DESIGN.md §4.1): with scaled
residual ``W[t] = 2^{t-δ} (V[t] - z[t-δ])`` where ``V`` accumulates the inputs,

    W[t] = 2 W[t-1] + u_t 2^{-δ}  - z_{t-δ},
    z_j  = 0 if |v| < 1/2 else sign(v)   (exact-residual selection),

which keeps ``|W| <= 3/4`` for the operand bounds used here, so each emitted
digit is in {-1,0,1} and the stream converges to the true value.  Hardware uses
truncated-estimate selection for short critical paths; the digit-serial
semantics, online delays and cycle schedules are identical (DESIGN.md §2).

Exactness: when the true result is a multiple of ``2^-n_out`` the final residual
is an integer bounded by 3/4, hence zero — the emitted stream is bit-exact.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["online_emit", "online_mult_sp", "online_add", "online_add_tree",
           "DELTA_MULT", "DELTA_ADD"]

DELTA_MULT = 2  # paper §II-A.1: delta_x = 2
DELTA_ADD = 2   # paper §II-A.2: delta_+ = 2


def _select(v: jax.Array) -> jax.Array:
    """Radix-2 SD digit selection on the exact residual (thresholds ±1/2)."""
    return jnp.where(v >= 0.5, 1, jnp.where(v <= -0.5, -1, 0)).astype(jnp.int8)


def online_emit(u_stream: jax.Array, n_out: int, delta: int) -> jax.Array:
    """Generic MSDF digit emission.

    ``u_stream``: (T, *batch) float32 — the per-cycle value increments; the
    represented value is ``sum_t u_t 2^-t``.  Emits ``n_out`` SD digits with
    online delay ``delta``: cycle t consumes ``u_t`` (zero once exhausted) and,
    for ``t > delta``, emits digit ``z_{t-delta}``.

    Requires ``|u_t| <= 1`` and total-value bound < 1 (callers guarantee this).
    Returns (n_out, *batch) int8.
    """
    T = u_stream.shape[0]
    batch_shape = u_stream.shape[1:]
    total = n_out + delta
    pad = total - T
    if pad < 0:
        raise ValueError(f"u_stream longer ({T}) than n_out+delta ({total})")
    if pad:
        u_stream = jnp.concatenate(
            [u_stream, jnp.zeros((pad,) + batch_shape, jnp.float32)], axis=0)

    scale = 2.0 ** (-delta)
    w0 = jnp.zeros(batch_shape, jnp.float32)

    # First `delta` cycles only accumulate (no digit emitted).
    def fill(w, u_t):
        return 2.0 * w + u_t * scale, None

    w, _ = jax.lax.scan(fill, w0, u_stream[:delta])

    def emit(w, u_t):
        v = 2.0 * w + u_t * scale
        z = _select(v)
        return v - z.astype(jnp.float32), z

    _, digits = jax.lax.scan(emit, w, u_stream[delta:])
    return digits


def online_mult_sp(x_digits: jax.Array, y: jax.Array, n_out: int,
                   delta: int = DELTA_MULT) -> jax.Array:
    """Serial-parallel online multiplier (paper Fig. 2a, [15]).

    ``x_digits``: (n_in, *batch) SD stream, ``|x| < 1``.
    ``y``: parallel operand, broadcastable to ``batch``; ``|y| < 1`` required
    (the invariant needs ``|y| <= 1 - 2^-n``; int8 q-format weights satisfy it).
    Emits ``n_out`` product digits MSDF with online delay ``delta`` (=2).

    For full precision of an n×m-bit product choose ``n_out >= n + m``
    (paper uses p_mult = 16 for 8-bit operands).
    """
    y = jnp.asarray(y, jnp.float32)
    u = x_digits.astype(jnp.float32) * y  # u_t = x_t * Y, |u_t| <= |Y| < 1
    return online_emit(u, n_out=n_out, delta=delta)


def online_add(a_digits: jax.Array, b_digits: jax.Array, n_out: int,
               delta: int = DELTA_ADD) -> jax.Array:
    """Digit-serial online adder emitting the scaled sum ``(a + b) / 2``.

    Both inputs are SD MSDF streams (padded with zero digits if lengths differ).
    ``u_t = (a_t + b_t)/2 in [-1, 1]`` keeps the generic invariant; the output
    stream represents ``(A + B)/2`` exactly given enough output digits.
    """
    Ta, Tb = a_digits.shape[0], b_digits.shape[0]
    T = max(Ta, Tb)

    def pad_to(d, T):
        if d.shape[0] == T:
            return d
        pad = jnp.zeros((T - d.shape[0],) + d.shape[1:], d.dtype)
        return jnp.concatenate([d, pad], axis=0)

    a = pad_to(a_digits, T).astype(jnp.float32)
    b = pad_to(b_digits, T).astype(jnp.float32)
    u = (a + b) * 0.5
    return online_emit(u, n_out=n_out, delta=delta)


def online_add_tree(streams: jax.Array, n_out: int,
                    delta: int = DELTA_ADD) -> tuple[jax.Array, int]:
    """Digit-pipelined reduction tree of online adders (paper Fig. 3).

    ``streams``: (n_terms, n_digits, *batch) SD streams.  Pads the term axis to
    the next power of two with zero streams and reduces pairwise; a depth-S tree
    emits the scaled SOP ``sum(streams) / 2^S``.

    Returns ``(digits, n_stages)`` — the output stream (n_out, *batch) and the
    tree depth S = ceil(log2(n_terms)) used by the cycle model (paper eq. 6).
    """
    n_terms = streams.shape[0]
    stages = 0
    level = streams  # (terms, digits, *batch)
    while level.shape[0] > 1:
        if level.shape[0] % 2:
            level = jnp.concatenate(
                [level, jnp.zeros((1,) + level.shape[1:], level.dtype)], axis=0)
        # One vectorized online_add per tree level: pair terms along axis 0.
        a, b = level[0::2], level[1::2]
        flat_a = jnp.moveaxis(a, 0, 1)  # (digits, pairs, *batch)
        flat_b = jnp.moveaxis(b, 0, 1)
        summed = online_add(flat_a, flat_b, n_out=n_out, delta=delta)
        level = jnp.moveaxis(summed, 1, 0)  # (pairs, n_out, *batch)
        stages += 1
    return level[0], stages
