"""Analytic FPGA timing / power / performance-density model (paper §III-B).

The paper's Table I is a Virtex-7 implementation; this container has no FPGA,
so Table I is reproduced through an analytic model:

* **Critical paths** follow eqs. 8-11 with per-primitive delays calibrated so
  the modeled CPDs equal the published ones (30.075 ns SIP / 15.436 ns DSLOT).
* **Throughput** uses pipelined initiation intervals (II).  DSLOT PEs are
  digit-pipelined: a window occupies an OLM for the ``p_mult`` digits it emits
  (+1 reload bubble) -> II_DSLOT = p_mult + 1 = 17 cycles.  SIP accepts a new
  window every ``n_bits + S_tree`` cycles (serial feed + pipelined reduction)
  -> II_SIP = 12.  With the published CPD/power these IIs reproduce Table I's
  GOPS/W within ~1 % (38.1 vs 37.69 and 25.19 vs 25.17) — the reverse-
  engineered assumption is recorded in EXPERIMENTS.md.
* **Early termination** shortens the *average* DSLOT II by the measured
  cycles-saved fraction, which is where the paper's energy savings come from.

Everything is deterministic python/float — no hardware is pretended to run.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

__all__ = ["FPGAModel", "TABLE1_PUBLISHED", "table1_model"]

# Published Virtex-7 numbers (paper Table I).
TABLE1_PUBLISHED = {
    "stripes": dict(luts=830, dynamic_power_mw=22.0, cpd_ns=30.075,
                    gops_per_watt=25.17),
    "dslot": dict(luts=1302, dynamic_power_mw=20.0, cpd_ns=15.436,
                  gops_per_watt=37.69),
}

# Calibrated primitive delays (ns) on Virtex-7 fabric.  Chosen so eqs. 8-11
# hit the published CPDs exactly; individually they sit in the usual range for
# 7-series LUT+carry logic (~0.5-2.5 ns per level incl. routing).
_T_AND = 0.500
_T_CPA8 = 4.415          # 8-bit ripple CPA stage      (eq. 8: 5 deep)
_T_CPA21 = 7.500         # 21-bit accumulator CPA      (eq. 8)
_T_MUX21 = 0.550         # [2:1] mux                   (eq. 9)
_T_32ADDER = 0.900       # [3:2] carry-save adder      (eq. 9)
_T_CPA4 = 1.800          # 4-bit CPA in selection      (eq. 9)
_T_SELM = 0.936          # selection logic             (eq. 9)
_T_XOR = 0.350           # output recode               (eq. 9)
_T_FA = 0.940            # full adder                  (eq. 10)
_T_FF = 0.300            # flip-flop clk->q            (eq. 10)


def t_sip(k: int = 5) -> float:
    """Paper eq. 8: t_AND + 5*t_CPA-8 + t_CPA-21 (k=5 -> 5 tree stages)."""
    stages = math.ceil(math.log2(k * k))
    return _T_AND + stages * _T_CPA8 + _T_CPA21


def t_olm() -> float:
    """Paper eq. 9."""
    return _T_MUX21 + _T_32ADDER + _T_CPA4 + _T_SELM + _T_XOR


def t_ola() -> float:
    """Paper eq. 10: 2*t_FA + t_FF."""
    return 2.0 * _T_FA + _T_FF


def t_dslot(k: int = 5) -> float:
    """Paper eq. 11: t_OLM + 5*t_OLA."""
    stages = math.ceil(math.log2(k * k))
    return t_olm() + stages * t_ola()


@dataclass(frozen=True)
class FPGAModel:
    """Throughput/energy model of one engine configuration (4 PEs, k=5)."""
    name: str
    cpd_ns: float
    dynamic_power_mw: float
    luts: int
    init_interval_cycles: float   # cycles between successive windows (pipelined)
    n_pes: int = 4
    k: int = 5

    @property
    def ops_per_window(self) -> int:
        # k*k MACs = 2*k*k ops per PE per window.
        return 2 * self.k * self.k * self.n_pes

    @property
    def gops(self) -> float:
        window_time_ns = self.init_interval_cycles * self.cpd_ns
        return self.ops_per_window / window_time_ns  # ops/ns == GOPS

    @property
    def gops_per_watt(self) -> float:
        return self.gops / (self.dynamic_power_mw * 1e-3)

    def energy_per_window_nj(self) -> float:
        return (self.dynamic_power_mw * 1e-3) * \
            (self.init_interval_cycles * self.cpd_ns)

    def with_early_termination(self, mean_cycle_savings_frac: float
                               ) -> "FPGAModel":
        """Average-case model: early termination shortens the effective II."""
        return FPGAModel(
            name=f"{self.name}+early-term",
            cpd_ns=self.cpd_ns,
            dynamic_power_mw=self.dynamic_power_mw,
            luts=self.luts,
            init_interval_cycles=self.init_interval_cycles
            * (1.0 - mean_cycle_savings_frac),
            n_pes=self.n_pes, k=self.k)


def table1_model(p_mult: int = 16, n_bits: int = 8, k: int = 5
                 ) -> dict[str, FPGAModel]:
    """Instantiate both engines with modeled CPDs and calibrated IIs."""
    stages = math.ceil(math.log2(k * k))
    return {
        "stripes": FPGAModel(
            name="stripes-SIP",
            cpd_ns=t_sip(k),
            dynamic_power_mw=TABLE1_PUBLISHED["stripes"]["dynamic_power_mw"],
            luts=TABLE1_PUBLISHED["stripes"]["luts"],
            init_interval_cycles=n_bits + (stages - 1),   # 8 + 4 = 12
            k=k),
        "dslot": FPGAModel(
            name="DSLOT-NN",
            cpd_ns=t_dslot(k),
            dynamic_power_mw=TABLE1_PUBLISHED["dslot"]["dynamic_power_mw"],
            luts=TABLE1_PUBLISHED["dslot"]["luts"],
            init_interval_cycles=p_mult + 1,              # 17
            k=k),
    }
