"""The paper's evaluation network (Fig. 6): bias-free MNIST CNN.

conv 5x5 (no bias, per §III-A) -> ReLU -> 2x2 maxpool -> dense -> softmax.
Trained in float; inference of the first three layers runs through the
DSLOT-NN digit-serial engine (Fig. 7 dataflow) for the Fig. 8/9 statistics.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dslot_mnist import MnistCNNConfig


class CNNParams(NamedTuple):
    conv: jax.Array    # (M, k, k)
    dense: jax.Array   # (M*12*12, 10)


def init_cnn(cfg: MnistCNNConfig, key) -> CNNParams:
    k1, k2 = jax.random.split(key)
    side = (cfg.image_size - cfg.kernel_size + 1) // cfg.pool
    conv = jax.random.normal(k1, (cfg.conv_channels, cfg.kernel_size,
                                  cfg.kernel_size)) * 0.2
    dense = jax.random.normal(
        k2, (cfg.conv_channels * side * side, cfg.n_classes)) * 0.05
    return CNNParams(conv=conv, dense=dense)


def forward(params: CNNParams, images: jax.Array, cfg: MnistCNNConfig
            ) -> jax.Array:
    """images: (B, 28, 28) in [0,1] -> logits (B, 10).  Bias-free."""
    x = jax.lax.conv_general_dilated(
        images[:, None], params.conv[:, None], (1, 1), "VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))       # (B, M, 24, 24)
    x = jnp.maximum(x, 0.0)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                              (1, 1, cfg.pool, cfg.pool),
                              (1, 1, cfg.pool, cfg.pool), "VALID")
    return x.reshape(x.shape[0], -1) @ params.dense


def train_cnn(cfg: MnistCNNConfig, images: np.ndarray, labels: np.ndarray,
              *, epochs: int = 20, batch: int = 64, lr: float = 2e-2,
              seed: int = 0) -> tuple[CNNParams, float]:
    """Plain SGD+momentum training; returns (params, final accuracy)."""
    params = init_cnn(cfg, jax.random.PRNGKey(seed))
    mom = jax.tree.map(jnp.zeros_like, params)

    def loss_fn(p, xb, yb):
        logits = forward(p, xb, cfg)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    @jax.jit
    def step(p, m, xb, yb):
        l, g = jax.value_and_grad(loss_fn)(p, xb, yb)
        m = jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, g)
        p = jax.tree.map(lambda pp, mm: pp - lr * mm, p, m)
        return p, m, l

    n = len(images)
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = order[i:i + batch]
            params, mom, _ = step(params, mom,
                                  jnp.asarray(images[idx]),
                                  jnp.asarray(labels[idx]))
    logits = forward(params, jnp.asarray(images), cfg)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(labels)))
    return params, acc
