"""The paper's evaluation network (Fig. 6): bias-free MNIST CNN.

conv 5x5 (no bias, per §III-A) -> ReLU -> 2x2 maxpool -> dense -> softmax.
Trained in float (``forward``/``train_cnn``); inference runs through the
DSLOT digit-plane engine via the unified layer API with a prepare/execute
split: ``prepare_cnn`` lowers the trained weights once (+ optional
``calibrate_cnn`` for fixed activation scales), ``forward_dslot`` executes
at a per-call runtime precision, reporting per-layer ``planes_used`` — the
TPU-tile analogue of the paper's Fig. 8/9 statistics.  The cycle-accurate per-window simulation of the FPGA
datapath lives in ``core.conv.dslot_conv2d_stats``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dslot_mnist import MnistCNNConfig


class CNNParams(NamedTuple):
    conv: jax.Array    # (M, k, k)
    dense: jax.Array   # (M*12*12, 10)


class PreparedCNN(NamedTuple):
    """Prepared (weight-stationary) DSLOT state of the MNIST CNN: layer
    configs + params with attached ``DslotWeights``.  Build once with
    ``prepare_cnn``; optionally ``calibrate_cnn``; then every
    ``forward_dslot`` call is pure execution at a runtime precision."""
    conv_layer: object                   # layers.DslotConv2d
    head_layer: object                   # layers.DslotDense
    conv_params: dict
    head_params: dict


class DslotForwardResult(NamedTuple):
    logits: jax.Array                    # (B, n_classes)
    layer_stats: dict                    # name -> DslotLayerStats


def init_cnn(cfg: MnistCNNConfig, key) -> CNNParams:
    k1, k2 = jax.random.split(key)
    side = (cfg.image_size - cfg.kernel_size + 1) // cfg.pool
    conv = jax.random.normal(k1, (cfg.conv_channels, cfg.kernel_size,
                                  cfg.kernel_size)) * 0.2
    dense = jax.random.normal(
        k2, (cfg.conv_channels * side * side, cfg.n_classes)) * 0.05
    return CNNParams(conv=conv, dense=dense)


def forward(params: CNNParams, images: jax.Array, cfg: MnistCNNConfig
            ) -> jax.Array:
    """images: (B, 28, 28) in [0,1] -> logits (B, 10).  Bias-free."""
    x = jax.lax.conv_general_dilated(
        images[:, None], params.conv[:, None], (1, 1), "VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))       # (B, M, 24, 24)
    x = jnp.maximum(x, 0.0)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                              (1, 1, cfg.pool, cfg.pool),
                              (1, 1, cfg.pool, cfg.pool), "VALID")
    return x.reshape(x.shape[0], -1) @ params.dense


def prepare_cnn(params: CNNParams, cfg: MnistCNNConfig, *,
                use_pallas: bool = False, block_k: int | None = None,
                block_m: int = 128, block_n: int = 8) -> PreparedCNN:
    """One-time DSLOT lowering of the trained CNN (weight-stationary).

    Every matmul-shaped layer routes through ``DslotConv2d``/``DslotDense``;
    the fused conv+ReLU gets per-tile early termination, the logits head
    (no ReLU) runs all planes.  ``block_n`` defaults small because the CNN
    has few output channels/classes; ``use_pallas`` selects the Pallas
    kernel (interpret mode off-TPU).
    """
    from repro.layers import DslotConv2d, DslotDense

    k, m = cfg.kernel_size, cfg.conv_channels
    side = (cfg.image_size - k + 1) // cfg.pool
    conv = DslotConv2d(
        in_channels=1, out_channels=m, kernel_size=k, name="conv1",
        n_bits=cfg.n_bits, relu=True,
        block_m=block_m, block_n=min(block_n, m), block_k=block_k,
        use_pallas=use_pallas)
    head = DslotDense(
        d_in=m * side * side, d_out=cfg.n_classes, name="dense1",
        n_bits=cfg.n_bits, relu=False, signed=False,
        block_m=block_m, block_n=min(block_n, cfg.n_classes),
        block_k=block_k, use_pallas=use_pallas)
    # conv weights (M, k, k) -> layer layout (k, k, 1, M)
    wc = jnp.transpose(params.conv, (1, 2, 0))[:, :, None, :]
    return PreparedCNN(conv_layer=conv, head_layer=head,
                       conv_params=conv.prepare({"w": wc}),
                       head_params=head.prepare({"w": params.dense}))


def _pool_flatten(x: jax.Array, cfg: MnistCNNConfig) -> jax.Array:
    """Fused-maxpool + layout shuffle between the two DSLOT layers."""
    B, Ho, Wo, m = x.shape
    Hp, Wp = Ho // cfg.pool, Wo // cfg.pool
    x = x[:, :Hp * cfg.pool, :Wp * cfg.pool, :]
    x = x.reshape(B, Hp, cfg.pool, Wp, cfg.pool, m).max(axis=(2, 4))
    # float forward flattens (M, H, W); the dslot path is NHWC — match the
    # trained dense layout by moving channels first before flattening.
    return jnp.transpose(x, (0, 3, 1, 2)).reshape(B, -1)


def calibrate_cnn(prep: PreparedCNN, images: jax.Array,
                  cfg: MnistCNNConfig) -> PreparedCNN:
    """Fix both layers' activation-quantization scales from a sample batch,
    removing the data-dependent ``jnp.max`` from the execute hot path."""
    conv_params = prep.conv_layer.calibrate(prep.conv_params,
                                            images[..., None])
    x, _ = prep.conv_layer.apply(conv_params, images[..., None])
    head_params = prep.head_layer.calibrate(prep.head_params,
                                            _pool_flatten(x, cfg))
    return prep._replace(conv_params=conv_params, head_params=head_params)


def forward_dslot(params: CNNParams | PreparedCNN, images: jax.Array,
                  cfg: MnistCNNConfig,
                  *, use_pallas: bool = False, n_planes=None,
                  block_k: int | None = None, block_m: int = 128,
                  block_n: int = 8) -> DslotForwardResult:
    """Inference through the digit-plane engine via the unified layer API.

    Pass a ``PreparedCNN`` (from ``prepare_cnn``) for the amortized
    weight-stationary path; raw ``CNNParams`` are prepared on the fly (the
    one-shot convenience path — use_pallas/block_* apply only then).
    ``n_planes`` is a RUNTIME precision: int, i32 scalar, or per-image (B,)
    vector; changing it re-executes but never re-prepares.
    """
    if not isinstance(params, PreparedCNN):
        params = prepare_cnn(params, cfg, use_pallas=use_pallas,
                             block_k=block_k, block_m=block_m,
                             block_n=block_n)
    x, conv_stats = params.conv_layer.apply(
        params.conv_params, images[..., None], n_planes=n_planes)  # (B,Ho,Wo,M)
    flat = _pool_flatten(x, cfg)
    logits, head_stats = params.head_layer.apply(
        params.head_params, flat, n_planes=n_planes)
    return DslotForwardResult(
        logits=logits,
        layer_stats={"conv1": conv_stats, "dense1": head_stats})


def train_cnn(cfg: MnistCNNConfig, images: np.ndarray, labels: np.ndarray,
              *, epochs: int = 20, batch: int = 64, lr: float = 2e-2,
              seed: int = 0) -> tuple[CNNParams, float]:
    """Plain SGD+momentum training; returns (params, final accuracy)."""
    params = init_cnn(cfg, jax.random.PRNGKey(seed))
    mom = jax.tree.map(jnp.zeros_like, params)

    def loss_fn(p, xb, yb):
        logits = forward(p, xb, cfg)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    @jax.jit
    def step(p, m, xb, yb):
        l, g = jax.value_and_grad(loss_fn)(p, xb, yb)
        m = jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, g)
        p = jax.tree.map(lambda pp, mm: pp - lr * mm, p, m)
        return p, m, l

    n = len(images)
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = order[i:i + batch]
            params, mom, _ = step(params, mom,
                                  jnp.asarray(images[idx]),
                                  jnp.asarray(labels[idx]))
    logits = forward(params, jnp.asarray(images), cfg)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(labels)))
    return params, acc
