"""Stripes' bit-serial inner-product unit (SIP) — the paper's baseline (Fig. 10/11).

LSB-first bit-serial multiply-accumulate: each cycle i ANDs input bit ``x_i``
with the parallel weight word, reduces the k*k partial products through an
adder tree, and shift-adds into an accumulator.  After ``n`` cycles the SOP is
complete.  Two structural facts drive the paper's comparison:

* the result's sign is known only after the FINAL cycle (LSB-first carries can
  flip the sign at any point) -> no early termination is possible;
* the critical path chains the AND array, the tree of carry-propagate adders
  and the wide accumulator (paper eq. 8), roughly 2x the DSLOT path (eq. 11).

The functional model below is bit-exact int32 arithmetic (it IS conventional
binary multiply-accumulate, evaluated serially) and doubles as the oracle for
the online-arithmetic path: both must dequantize to identical SOPs.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["SIPSchedule", "sip_schedule", "sip_sop", "sip_sop_trace"]


class SIPSchedule(NamedTuple):
    n_bits: int            # serial input precision (cycles of bit feed)
    tree_stages: int       # ceil(log2(k*k)) CPA stages per cycle (area/CPD model)
    total_cycles: int      # cycles to a usable SOP (sign known only here)


def sip_schedule(k: int, n_bits: int = 8) -> SIPSchedule:
    tree_stages = max(0, math.ceil(math.log2(k * k)))
    # One bit per cycle; the reduction tree + accumulator are combinational
    # within the (long) cycle — matching the paper's eq. 8 critical path.
    return SIPSchedule(n_bits=n_bits, tree_stages=tree_stages,
                       total_cycles=n_bits)


def sip_sop(x_q: jax.Array, w_q: jax.Array, n_bits: int = 8) -> jax.Array:
    """Bit-exact SIP evaluation of ``sum_taps x*w`` on integer operands.

    ``x_q``: (taps, *batch) non-negative int32 (post-ReLU activations, as in the
    paper's pipeline), ``w_q``: (taps, *bcast) signed int32 weights (parallel).
    Returns int32 SOP, identical to ``sum(x_q * w_q)`` — evaluated serially.
    """
    x_q = jnp.asarray(x_q, jnp.int32)
    w_q = jnp.asarray(w_q, jnp.int32)

    def cycle(acc, i):
        bit = (x_q >> i) & 1                      # serial LSB-first input bit
        pp = bit * w_q                            # AND array (PPG, Fig. 11a)
        sopp = jnp.sum(pp, axis=0)                # reduction tree
        return acc + (sopp << i), None            # shift-add accumulator

    acc0 = jnp.zeros(jnp.broadcast_shapes(x_q.shape, w_q.shape)[1:], jnp.int32)
    acc, _ = jax.lax.scan(cycle, acc0, jnp.arange(n_bits, dtype=jnp.int32))
    return acc


def sip_sop_trace(x_q: jax.Array, w_q: jax.Array, n_bits: int = 8) -> jax.Array:
    """Accumulator value after every cycle — shows why early negative
    detection fails for LSB-first arithmetic: the partial accumulator's sign is
    uncorrelated with the final sign until the last (highest-weight) bits land.
    Returns (n_bits, *batch) int32.
    """
    x_q = jnp.asarray(x_q, jnp.int32)
    w_q = jnp.asarray(w_q, jnp.int32)

    def cycle(acc, i):
        bit = (x_q >> i) & 1
        acc = acc + (jnp.sum(bit * w_q, axis=0) << i)
        return acc, acc

    acc0 = jnp.zeros(jnp.broadcast_shapes(x_q.shape, w_q.shape)[1:], jnp.int32)
    _, trace = jax.lax.scan(cycle, acc0, jnp.arange(n_bits, dtype=jnp.int32))
    return trace
