"""DSLOT-NN convolution: fused conv + ReLU + maxpool dataflow (paper Figs. 4-7).

Four PEs evaluate the four convolution windows of one 2x2 pooling group in
parallel; each PE's SOP digits stream MSDF through the Algorithm-1 comparator,
negative windows terminate early (their ReLU output is 0 by construction), and
the surviving values feed the pooling unit directly — no intermediate feature
map is written (the paper's "simultaneous computation of the first three
layers").

Numerical contract (kept bit-exact, tested):
    x is quantized unsigned to ``x_q`` (n-1 magnitude bits, digit stream of
    n digits valued ``x_q / 2^n``), w signed to ``w_q`` (fraction ``w_q/2^n``).
    A PE with S tree stages emits ``SOP_int / 2^(2n+S)`` where
    ``SOP_int = sum x_q*w_q`` — integer-exact, so the digit-serial path equals
    the SIP/conventional path exactly, and equals float conv up to quantization.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .digits import fixed_to_sd
from .early_term import TerminationReport, early_termination
from .pe import PESchedule, pe_schedule, pe_sop_digits
from .quantize import QTensor, quantize, quantize_unsigned
from .sip import sip_sop

__all__ = ["DSLOTConvResult", "extract_windows", "im2col",
           "dslot_conv2d_stats", "sip_conv2d"]


class DSLOTConvResult(NamedTuple):
    y_conv: jax.Array            # (B, Ho, Wo, M) dequantized conv output (pre-ReLU)
    y_pooled: jax.Array          # (B, Ho//2, Wo//2, M) fused ReLU+maxpool output
    report: TerminationReport    # per-(B,Ho,Wo,M) Algorithm-1 accounting
    schedule: PESchedule
    x_scale: jax.Array
    w_scale: jax.Array


def im2col(x: jax.Array, k: int, stride: int = 1,
           padding: str = "valid") -> jax.Array:
    """Multi-channel im2col: (B, H, W, C) -> (B, Ho, Wo, k*k*C).

    ``padding``: "valid" (no pad) or "same" (zero-pad so that
    Ho = ceil(H / stride), matching ``jax.lax.conv_general_dilated`` with
    SAME padding — the standard CNN-stack convention).  Column ordering is
    (ki, kj, c) — matmul against weights reshaped from (k, k, C, M) to
    (k*k*C, M) reproduces a conventional convolution.  This is the lowering
    used by ``layers.DslotConv2d`` to route conv layers through the
    digit-plane matmul kernel.
    """
    if padding not in ("valid", "same"):
        raise ValueError(f"padding must be 'valid' or 'same', got {padding!r}")
    B, H, W, C = x.shape
    if padding == "same":
        # XLA SAME: total pad = (ceil(H/s) - 1) * s + k - H, split low/high
        # with the extra pixel on the high side.
        Ho = -(-H // stride)
        Wo = -(-W // stride)
        ph = max((Ho - 1) * stride + k - H, 0)
        pw = max((Wo - 1) * stride + k - W, 0)
        x = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2),
                        (pw // 2, pw - pw // 2), (0, 0)))
        H, W = x.shape[1], x.shape[2]
    Ho = (H - k) // stride + 1
    Wo = (W - k) // stride + 1
    i = (stride * jnp.arange(Ho)[:, None, None, None]
         + jnp.arange(k)[None, None, :, None])                 # (Ho,1,k,1)
    j = (stride * jnp.arange(Wo)[None, :, None, None]
         + jnp.arange(k)[None, None, None, :])                 # (1,Wo,1,k)
    win = x[:, i, j]                       # (B, Ho, Wo, k, k, C)
    return win.reshape(B, Ho, Wo, k * k * C)


def extract_windows(x: jax.Array, k: int) -> jax.Array:
    """im2col: (B, H, W) -> (B, Ho, Wo, k*k), valid padding, stride 1."""
    return im2col(x[..., None], k)


def _digit_streams(x_q: jax.Array, n_bits: int) -> jax.Array:
    """SD digit streams (n_bits, ...) valued ``x_q / 2^n_bits`` (exact)."""
    return fixed_to_sd(x_q, n_bits)


def dslot_conv2d_stats(x: jax.Array, w: jax.Array, *, n_bits: int = 8,
                       pool: int = 2) -> DSLOTConvResult:
    """Run the full DSLOT-NN digit-serial simulation of conv+ReLU+maxpool.

    ``x``: (B, H, W) float input feature map (paper: single input fmap).
    ``w``: (M, k, k) float kernels (M output feature maps).

    Every output pixel's SOP is computed digit-serially through k*k online
    multipliers + the online adder tree, monitored by Algorithm 1.
    """
    M, k, k2 = w.shape
    assert k == k2, "square kernels only"
    schedule = pe_schedule(k=k, n_fmaps=1, p_mult=2 * n_bits)

    xq: QTensor = quantize_unsigned(x, n_bits=n_bits)
    wq: QTensor = quantize(w, n_bits=n_bits)

    win = extract_windows(xq.q, k)                      # (B,Ho,Wo,kk) int32
    B, Ho, Wo, KK = win.shape
    flat = win.reshape(B * Ho * Wo, KK).T               # (kk, NW)

    # digit streams valued q/2^n  (|.| < 1/2): (n_bits, kk, NW)
    x_digits = _digit_streams(flat, n_bits)

    # parallel weight fractions w_q/2^n, |.| < 1/2: (M, kk) -> per-M broadcast
    w_frac = wq.q.reshape(M, KK).astype(jnp.float32) * (2.0 ** -n_bits)

    def one_channel(wf):                                # wf: (kk,)
        sop = pe_sop_digits(x_digits, wf[:, None], schedule)   # (p_out, NW)
        return sop

    sop_digits = jax.vmap(one_channel)(w_frac)          # (M, p_out, NW)
    sop_digits = jnp.moveaxis(sop_digits, 0, -1)        # (p_out, NW, M)

    report = early_termination(sop_digits, schedule)

    # Exact integer SOP from the digit stream: value * 2^(2n + S).
    from .digits import sd_to_value
    S = schedule.tree_stages + schedule.fmap_stages
    sop_int = sd_to_value(sop_digits) * (2.0 ** (2 * n_bits + S))
    # Dequantize: x = (x_q/2^{n-1}) sx, w = (w_q/2^{n-1}) sw
    #  => SOP_real = SOP_int * sx*sw / 2^{2(n-1)}
    scale = xq.scale * wq.scale * (2.0 ** -(2 * (n_bits - 1)))
    y = (sop_int * scale).reshape(B, Ho, Wo, M)

    relu = jnp.maximum(y, 0.0)
    Hp, Wp = Ho // pool, Wo // pool
    pooled = relu[:, :Hp * pool, :Wp * pool, :]
    pooled = pooled.reshape(B, Hp, pool, Wp, pool, M).max(axis=(2, 4))

    report = report._replace(
        is_negative=report.is_negative.reshape(B, Ho, Wo, M),
        term_digit=report.term_digit.reshape(B, Ho, Wo, M),
        cycles_used=report.cycles_used.reshape(B, Ho, Wo, M),
        cycles_saved=report.cycles_saved.reshape(B, Ho, Wo, M),
        savings_frac=report.savings_frac.reshape(B, Ho, Wo, M),
    )
    return DSLOTConvResult(y_conv=y, y_pooled=pooled, report=report,
                           schedule=schedule, x_scale=xq.scale, w_scale=wq.scale)


def sip_conv2d(x: jax.Array, w: jax.Array, *, n_bits: int = 8) -> jax.Array:
    """Same convolution through the Stripes SIP baseline (bit-exact integer)."""
    M, k, _ = w.shape
    xq = quantize_unsigned(x, n_bits=n_bits)
    wq = quantize(w, n_bits=n_bits)
    win = extract_windows(xq.q, k)                      # (B,Ho,Wo,kk)
    B, Ho, Wo, KK = win.shape
    flat = win.reshape(B * Ho * Wo, KK).T               # (kk, NW)
    sop = jax.vmap(lambda wf: sip_sop(flat, wf[:, None], n_bits=n_bits))(
        wq.q.reshape(M, KK))                            # (M, NW)
    scale = xq.scale * wq.scale * (2.0 ** -(2 * (n_bits - 1)))
    return (sop.T.astype(jnp.float32) * scale).reshape(B, Ho, Wo, M)
