"""Signed-digit (SD) radix-2 number system — the substrate of online arithmetic.

DSLOT-NN (paper §II-A) computes with a symmetric radix-2 redundant digit set
{-1, 0, 1}.  A value ``x`` with ``|x| < 1`` is represented most-significant-
digit-first (MSDF) as ``x = sum_i d_i * 2^-i`` (i = 1..n), each digit stored in
hardware as a bit pair ``(x+, x-)`` with ``d = x+ - x-`` (paper eq. 2).

In this functional simulation a digit *stream* is an ``int8`` array whose
LEADING axis is the digit index (MSDF order): ``digits.shape == (n, *batch)``.

All routines are pure JAX, vectorized over arbitrary trailing batch shapes, and
exact: residuals and prefix values are multiples of ``2^-p`` for small ``p`` and
are represented exactly in float32 (tests assert bit-exact roundtrips).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "sd_from_value",
    "sd_to_value",
    "sd_prefix_values",
    "sd_split_posneg",
    "sd_from_bits_lsb",
    "fixed_to_sd",
    "first_negative_prefix",
]


def sd_from_value(x: jax.Array, n_digits: int) -> jax.Array:
    """Convert ``x`` (float, ``|x| < 1``) into ``n_digits`` SD radix-2 digits, MSDF.

    Greedy exact-residual selection: ``w <- x``; per digit ``v = 2w``;
    ``d = sign(v)`` if ``|v| >= 1/2`` else ``0``; ``w <- v - d``.  The residual
    obeys ``|w| <= 1`` throughout and the representation error after ``n``
    digits is ``|x - value(d_1..d_n)| = |w_n| * 2^-n <= 2^-n``; it is *zero*
    whenever ``x`` is a multiple of ``2^-n_digits``.

    Returns int8 digits of shape ``(n_digits, *x.shape)``.
    """
    x = jnp.asarray(x, jnp.float32)

    def step(w, _):
        v = 2.0 * w
        d = jnp.where(v >= 0.5, 1, jnp.where(v <= -0.5, -1, 0)).astype(jnp.int8)
        w = v - d.astype(jnp.float32)
        return w, d

    _, digits = jax.lax.scan(step, x, None, length=n_digits)
    return digits


def sd_to_value(digits: jax.Array) -> jax.Array:
    """Value of an SD digit stream: ``sum_i d_i 2^-i`` (leading axis = i)."""
    n = digits.shape[0]
    weights = 2.0 ** -jnp.arange(1, n + 1, dtype=jnp.float32)
    return jnp.tensordot(weights, digits.astype(jnp.float32), axes=(0, 0))


def sd_prefix_values(digits: jax.Array) -> jax.Array:
    """Prefix values ``z[j] = sum_{i<=j} d_i 2^-i`` for every j (MSDF scan).

    Shape-preserving: output ``(n, *batch)`` float32.  This is what the paper's
    Algorithm-1 comparator observes (``z+[j] < z-[j]``  <=>  ``z[j] < 0``).
    """
    n = digits.shape[0]
    weights = 2.0 ** -jnp.arange(1, n + 1, dtype=jnp.float32)
    weights = weights.reshape((n,) + (1,) * (digits.ndim - 1))
    return jnp.cumsum(digits.astype(jnp.float32) * weights, axis=0)


def sd_split_posneg(digits: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Hardware bit-pair view (paper eq. 2): ``d = x+ - x-``; returns (x+, x-)."""
    pos = (digits > 0).astype(jnp.int8)
    neg = (digits < 0).astype(jnp.int8)
    return pos, neg


def sd_from_bits_lsb(bits: jax.Array) -> jax.Array:
    """Reinterpret conventional bits (values {0,1}, leading axis = bit index
    MSB-first) as SD digits — any non-redundant representation is a valid SD one.
    """
    return bits.astype(jnp.int8)


def fixed_to_sd(q: jax.Array, n_bits: int) -> jax.Array:
    """Exact SD recoding of a signed fixed-point integer ``q in [-(2^n-1), 2^n-1]``
    interpreted as the fraction ``q / 2^n``.  Returns ``(n_bits, *q.shape)`` int8.

    Uses sign-magnitude binary: ``|q|``'s bits (MSB first) times ``sign(q)`` —
    digits in {-1,0,1}, exact, no residual.
    """
    q = jnp.asarray(q, jnp.int32)
    sign = jnp.sign(q).astype(jnp.int8)
    mag = jnp.abs(q)
    shifts = jnp.arange(n_bits - 1, -1, -1, dtype=jnp.int32)
    shifts = shifts.reshape((n_bits,) + (1,) * q.ndim)
    bits = ((mag[None] >> shifts) & 1).astype(jnp.int8)
    return bits * sign[None]


def first_negative_prefix(digits: jax.Array) -> jax.Array:
    """Index (1-based digit position) of the first strictly-negative prefix value,
    or ``n+1`` if no prefix ever goes negative.  Paper Algorithm 1: the cycle at
    which the termination signal fires.

    Soundness (paper §II-B.2, proven in DESIGN.md §4.1): a negative prefix at
    digit j implies ``z[j] <= -2^-j`` while all remaining digits contribute
    ``< 2^-j``, so the final SOP is strictly negative — terminating is safe.
    """
    n = digits.shape[0]
    prefix = sd_prefix_values(digits)
    neg = prefix < 0.0
    idx = jnp.argmax(neg, axis=0)  # first True, or 0 if none
    any_neg = jnp.any(neg, axis=0)
    return jnp.where(any_neg, idx + 1, n + 1).astype(jnp.int32)
