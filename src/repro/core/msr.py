"""Weight-side Most-Significant-Run (MSR) analysis for the DSLOT engine.

SNIPPETS.md's Low-Cost-AI-Accelerator study defines the MSR of an int8
weight as the run of identical leading bits (sign extension) in its
two's-complement representation and measures that >= 99% of trained weights
across MLP / LeNet / ResNet-18 / AlexNet carry a 4-bit MSR — i.e. their
magnitude fits in the low 4 bits.  In digit-plane terms: the most
significant digit planes of most weights are pure sign padding.

This module provides the prepare-time half of the weight-side sparsity
pipeline (ISSUE 7 / ROADMAP "Weight-side digit sparsity"):

* ``msr_depths`` / ``msr_histogram`` — per-weight MSR depth of the
  int-quantized weights plus the MSR-N cumulative fractions (the analogue
  of the SNIPPETS table), used by ``bench_kernel.py --msr-profile``.
* ``tile_plane_bound`` — the *exact* static per-(N-tile) plane upper bound
  baked into ``DslotWeights.msr_bound`` by ``kernels.ops.dslot_prepare``.

Exactness note (why the bound is {0, n_bits} and not the raw MSR depth):
the DSLOT kernel digit-serializes the **activations**, not the weights —
every digit plane multiplies the *full-precision* weight tile.  Truncating
activation planes based on weight magnitude therefore changes the f32
output, so a magnitude-derived partial bound (e.g. "this tile's weights
all have MSR 4, run 4 planes") is NOT bit-exact and is reported here as
profiling only.  The bounds that ARE output-exact are the degenerate
endpoints of the MSR spectrum, detected on the raw stored weights:

* a tile whose weight columns are **exactly zero** (MSR depth == n_bits at
  any quantization — in particular every pure-N-padding tile) contributes
  nothing in any mode: bound 0;
* under ``relu=True`` with **unsigned** activation quantization (digits in
  {0, 1}), a tile whose weights are all <= 0 can only accumulate <= 0, so
  its ReLU output is identically zero: bound 0.

Everything in between is the CSD/Booth enumeration prototype's territory
(``core.csd``): sub-plane weight sparsity needs a digit-granular datapath,
not a plane-granular one.  See ``docs/kernel.md`` ("Weight-side digit
sparsity") for the crosswalk to Bit-Pragmatic / Laconic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["msr_depths", "msr_histogram", "quantize_weights",
           "tile_plane_bound"]


def quantize_weights(w: jax.Array, n_bits: int = 8) -> jax.Array:
    """Symmetric signed ``n_bits`` quantization of a weight tensor.

    Profiling-only (the kernel consumes full-precision weights): maps
    ``max|w|`` to ``2^(n_bits-1) - 1``.  Returns int32 values in
    ``[-(2^(n_bits-1)-1), 2^(n_bits-1)-1]``.
    """
    qmax = float(2 ** (n_bits - 1) - 1)
    amax = jnp.maximum(jnp.max(jnp.abs(w.astype(jnp.float32))), 1e-12)
    return jnp.clip(jnp.round(w.astype(jnp.float32) / (amax / qmax)),
                    -qmax, qmax).astype(jnp.int32)


def msr_depths(w_q: jax.Array, n_bits: int = 8) -> jax.Array:
    """Per-weight MSR depth of int-quantized weights (int32, same shape).

    Depth = number of leading bits of the ``n_bits``-wide two's-complement
    representation equal to the sign bit = ``n_bits - bitlength(|w_q|)``
    (a weight with ``|w_q| < 2^(n_bits - r)`` has an ``r``-bit MSR; zero
    has the full ``n_bits``).  SNIPPETS.md "MSR-N" = fraction of weights
    with depth >= N.
    """
    m = jnp.abs(jnp.asarray(w_q, jnp.int32))
    shifts = jnp.arange(n_bits, dtype=jnp.int32)
    shifts = shifts.reshape(shifts.shape + (1,) * m.ndim)
    bitlen = jnp.sum((m[None] >> shifts) > 0, axis=0, dtype=jnp.int32)
    return n_bits - bitlen


def msr_histogram(w: jax.Array, n_bits: int = 8) -> dict:
    """MSR depth distribution of a weight tensor (quantized on the fly).

    Returns ``{"n_bits", "depth_counts": [c_0..c_n_bits],
    "msr_ge": {"3": f, "4": f, "5": f, "6": f}}`` — ``msr_ge["4"]`` is the
    SNIPPETS table's MSR-4 column (>= 98.9% on trained nets).
    """
    depths = msr_depths(quantize_weights(w, n_bits), n_bits)
    counts = jnp.bincount(depths.reshape(-1), length=n_bits + 1)
    counts = [int(c) for c in jax.device_get(counts)]
    total = max(1, sum(counts))
    return {
        "n_bits": n_bits,
        "depth_counts": counts,
        "msr_ge": {str(nn): sum(counts[nn:]) / total
                   for nn in (3, 4, 5, 6) if nn <= n_bits},
    }


def tile_plane_bound(w_p: jax.Array, block_n: int, *, n_bits: int,
                     relu: bool, signed: bool) -> jax.Array:
    """Exact static plane upper bound per N-tile of padded/sorted weights.

    ``w_p``: (Kp, Np) with ``Np % block_n == 0`` — the weights exactly as
    ``dslot_prepare`` stores them (post sort, post padding), so tile
    membership matches the kernel grid.  Returns an (Nt,) int32 table:
    0 for tiles proven inert (see module docstring), ``n_bits`` otherwise.
    Running extra planes beyond the bound is always exact, so consumers may
    clamp it upward freely; the kernel takes
    ``min(n_planes_rt, row_budget, msr_bound[j])``.
    """
    Kp, Np = w_p.shape
    assert Np % block_n == 0, (Np, block_n)
    tiles = w_p.astype(jnp.float32).reshape(Kp, Np // block_n, block_n)
    inert = jnp.all(tiles == 0.0, axis=(0, 2))
    if relu and not signed:
        # unsigned activation digits are {0, 1}: an all-non-positive tile
        # accumulates <= 0 and ReLU zeroes it — bound 0 is output-exact.
        inert = jnp.logical_or(inert, jnp.all(tiles <= 0.0, axis=(0, 2)))
    return jnp.where(inert, 0, n_bits).astype(jnp.int32)
