"""Fixed-point quantization for the digit-serial datapath.

The paper uses 8-bit fixed-point operands interpreted as fractions (the online
modules work on fractional numbers so operand alignment is trivial, §II-A).
We quantize symmetrically to ``n_bits`` with values ``q / 2^n in (-1, 1)``:

    q = clip(round(x / s), -(2^{n-1} - 1), 2^{n-1} - 1) — per-tensor scale s

so the *fraction* fed to the online operators is ``q * 2^{-(n-1)} * ... `` — we
keep q as an integer and the fraction ``frac = q / 2^{n-1}``; note ``|frac| <=
(2^{n-1}-1)/2^{n-1} < 1`` as the OLM invariant requires.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["QTensor", "quantize", "dequantize", "quantize_unsigned"]


class QTensor(NamedTuple):
    """Symmetric fixed-point tensor: ``value ~= frac * scale``.

    ``q``     int32 integers in [-(2^{n-1}-1), 2^{n-1}-1]
    ``scale`` float32 per-tensor scale applied to the *fraction* q / 2^{n-1}
    ``n_bits`` total fraction bits (n-1 magnitude bits)
    """
    q: jax.Array
    scale: jax.Array
    n_bits: int

    @property
    def frac(self) -> jax.Array:
        """Fractional value in (-1, 1) fed digit-serially to online operators."""
        return self.q.astype(jnp.float32) * (2.0 ** -(self.n_bits - 1))

    @property
    def value(self) -> jax.Array:
        return self.frac * self.scale


def quantize(x: jax.Array, n_bits: int = 8, scale: jax.Array | None = None
             ) -> QTensor:
    """Symmetric signed quantization to ``n_bits`` (default int8-like)."""
    x = jnp.asarray(x, jnp.float32)
    qmax = 2 ** (n_bits - 1) - 1
    if scale is None:
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    q = jnp.clip(jnp.round(x / scale * qmax), -qmax, qmax).astype(jnp.int32)
    # value = (q / 2^{n-1}) * scale_eff  with  scale_eff = scale * 2^{n-1}/qmax
    scale_eff = jnp.asarray(scale, jnp.float32) * (2.0 ** (n_bits - 1) / qmax)
    return QTensor(q=q, scale=scale_eff, n_bits=n_bits)


def quantize_unsigned(x: jax.Array, n_bits: int = 8,
                      scale: jax.Array | None = None) -> QTensor:
    """Unsigned quantization for post-ReLU activations (paper feeds the image
    pixels serially as non-negative fractions).  Digits stay in {0, 1}."""
    x = jnp.asarray(x, jnp.float32)
    qmax = 2 ** (n_bits - 1) - 1   # keep |frac| < 1 with the same n-1 split
    if scale is None:
        scale = jnp.maximum(jnp.max(x), 1e-12)
    q = jnp.clip(jnp.round(x / scale * qmax), 0, qmax).astype(jnp.int32)
    scale_eff = jnp.asarray(scale, jnp.float32) * (2.0 ** (n_bits - 1) / qmax)
    return QTensor(q=q, scale=scale_eff, n_bits=n_bits)


def dequantize(t: QTensor) -> jax.Array:
    return t.value
