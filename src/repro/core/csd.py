"""CSD / Booth nonzero-digit enumeration prototype (essential digits only).

Bit-Pragmatic and Laconic (PAPERS.md) process only the *essential* —
nonzero — digits of a serial operand instead of scanning every bit
position.  The DSLOT dense-plane scan issues all ``n_bits`` MSDF planes of
the quantized activations (minus what early termination kills); most of
those digits are zero, and plain binary is not even the sparsest encoding.

This module recodes quantized activations into **Canonical Signed Digit**
form — the unique minimal-weight radix-2 signed-digit representation
(digits in {-1, 0, +1}, no two adjacent nonzeros), computed via the
non-adjacent-form identity ``NAF(m) = bits(3m) - bits(m)`` — and provides
the integer-domain evaluation + work accounting the
``bench_kernel.py --msr-profile`` head-to-head uses:

* ``csd_recode`` — (P, ...) MSDF digit planes, ``P = n_bits + 1`` (CSD of
  an ``n``-bit magnitude can carry into weight ``2^n``), value-exact.
* ``essential_digit_count`` / ``binary_digit_count`` — nonzero digits under
  CSD vs plain sign-magnitude binary (Laconic's "essential digit" metric
  vs Pragmatic's "essential bit" metric) vs the ``n_bits * size`` dense
  digit slots the plane scan issues.
* ``csd_matmul`` — exact integer matmul over the CSD planes, plus the
  number of planes that carry any nonzero digit (what a plane-granular
  engine could skip) — asserted bit-equal to ``q @ w_q`` in the bench.

A hardware DSLOT datapath would consume these via per-digit (position,
sign) pairs; on the TPU's plane-granular MXU the win shows up as fewer
nonzero planes and a strictly lower essential-digit count.  This is the
prototype half of ISSUE 7's weight-side sparsity pipeline — the exact
static-plane-bound half lives in ``core.msr``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["binary_digit_count", "csd_matmul", "csd_planes_nonzero",
           "csd_recode", "essential_digit_count"]


def csd_recode(q: jax.Array, n_bits: int = 8) -> jax.Array:
    """MSDF CSD digit planes of integer ``q``: (n_bits + 1, *q.shape) int8.

    Plane ``p`` carries weight ``2^(n_bits - p)`` (most significant first),
    so ``q == sum_p 2^(n_bits - p) * planes[p]`` exactly for
    ``|q| < 2^n_bits``.  Signed inputs recode as ``sign(q) * CSD(|q|)`` —
    still minimal-weight, digits in {-1, 0, +1}, no two adjacent nonzeros
    (the NAF property).
    """
    q = jnp.asarray(q, jnp.int32)
    m = jnp.abs(q)
    t = 3 * m
    # NAF digit at weight 2^j is bit_{j+1}(3m) - bit_{j+1}(m); plane p has
    # j = n_bits - p, hence shift n_bits - p + 1.
    shifts = n_bits + 1 - jnp.arange(n_bits + 1, dtype=jnp.int32)
    shifts = shifts.reshape(shifts.shape + (1,) * q.ndim)
    digits = ((t[None] >> shifts) & 1) - ((m[None] >> shifts) & 1)
    return (digits * jnp.sign(q)[None]).astype(jnp.int8)


def essential_digit_count(planes: jax.Array) -> jax.Array:
    """Number of nonzero digits in a digit-plane tensor (i32 scalar)."""
    return jnp.sum((jnp.asarray(planes, jnp.int32) != 0).astype(jnp.int32))


def binary_digit_count(q: jax.Array, n_bits: int = 8) -> jax.Array:
    """Nonzero digits of plain sign-magnitude binary (popcount of |q|).

    This is what the dense-plane scan actually multiplies by something
    nonzero — Pragmatic's essential-bit count; the scan still *issues*
    ``n_bits * q.size`` digit slots.
    """
    m = jnp.abs(jnp.asarray(q, jnp.int32))
    shifts = jnp.arange(n_bits, dtype=jnp.int32)
    shifts = shifts.reshape(shifts.shape + (1,) * m.ndim)
    return jnp.sum(((m[None] >> shifts) & 1).astype(jnp.int32))


def csd_planes_nonzero(planes: jax.Array) -> jax.Array:
    """How many of the P digit planes carry any nonzero digit (i32).

    The plane-granular analogue of essential-digit processing: an all-zero
    CSD plane needs no MXU pass at all (cf. the MSR static bound, which
    proves this per weight tile instead of per activation plane).
    """
    P = planes.shape[0]
    flat = jnp.asarray(planes, jnp.int32).reshape(P, -1)
    return jnp.sum(jnp.any(flat != 0, axis=1).astype(jnp.int32))


def csd_matmul(q: jax.Array, w_q: jax.Array, n_bits: int = 8
               ) -> tuple[jax.Array, jax.Array]:
    """Exact integer matmul over CSD planes: ``(q @ w_q, planes_nonzero)``.

    ``q``: (M, K) int, ``|q| < 2^n_bits``; ``w_q``: (K, N) int.  Evaluates
    ``sum_p 2^(n_bits-p) * (planes[p] @ w_q)`` in int32 — bit-equal to
    ``q @ w_q`` (asserted in ``bench_kernel.py --msr-profile``; keep
    ``2^n_bits * K * max|w_q|`` inside int32 range).  Also returns the
    nonzero-plane count — the MXU passes an essential-digit engine issues
    versus the dense scan's ``n_bits``.
    """
    planes = csd_recode(q, n_bits)
    w_i = jnp.asarray(w_q, jnp.int32)
    scales = jnp.int32(1) << (n_bits - jnp.arange(n_bits + 1,
                                                  dtype=jnp.int32))

    def body(acc, step):
        plane, scale = step
        return acc + scale * jnp.dot(plane.astype(jnp.int32), w_i), None

    M, N = q.shape[0], w_q.shape[1]
    acc, _ = jax.lax.scan(body, jnp.zeros((M, N), jnp.int32),
                          (planes, scales))
    return acc, csd_planes_nonzero(planes)
