"""Collective-matmul: ppermute-pipelined TP all-gather overlapped with MXU.

The canonical GSPMD lowering of a column-parallel matmul with a
sequence-sharded activation is ``all-gather(x) ; dot`` — the gather sits on
the critical path.  The collective-matmul schedule (Wang et al., ASPLOS'23)
decomposes it into TP rounds:

    round r on device d:  y[rows of slice (d+r) % n, own N-cols] = cur @ W_d
                          cur <- ppermute(cur)     (next x slice arrives
                                                    while this matmul runs)

so each ICI hop hides behind one matmul slice.  Implemented with shard_map —
the per-device program is explicit and XLA schedules the ppermute
asynchronously on real TPUs.

Layouts:  x (S, K) sharded P(axis, None) — sequence-sharded activation;
          w (K, N) sharded P(None, axis) — column-parallel weight;
          y (S, N) sharded P(None, axis).
Bit-identical (up to f32 accumulation) to the plain lowering; equivalence is
tested on an 8-device CPU mesh.  Used as a §Perf hillclimb for
collective-bound cells.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def collective_matmul_ag(x, w, mesh: Mesh, axis: str = "model"):
    """Pipelined all-gather matmul (see module docstring)."""
    n = mesh.shape[axis]

    def body(xl, wl):                       # xl: (S/n, K), wl: (K, N/n)
        idx = jax.lax.axis_index(axis)
        s_local = xl.shape[0]
        y0 = jnp.zeros((s_local * n, wl.shape[1]), jnp.float32)
        if hasattr(jax.lax, "pvary"):       # newer jax: mark device-varying
            y0 = jax.lax.pvary(y0, (axis,))
        # device i sends to i-1: after r rounds, device d holds slice (d+r)%n
        perm = [(i, (i - 1) % n) for i in range(n)]

        def round_step(carry, r):
            y, cur = carry
            src = (idx + r) % n
            part = jnp.einsum("sk,kn->sn", cur.astype(jnp.float32),
                              wl.astype(jnp.float32))
            y = jax.lax.dynamic_update_slice(y, part, (src * s_local, 0))
            cur = jax.lax.ppermute(cur, axis, perm)
            return (y, cur), None

        (y, _), _ = jax.lax.scan(round_step, (y0, xl),
                                 jnp.arange(n, dtype=jnp.int32))
        return y

    return shard_map(body, mesh=mesh,
                     in_specs=(P(axis, None), P(None, axis)),
                     out_specs=P(None, axis))(x, w)


def plain_matmul_ag(x, w, mesh: Mesh, axis: str = "model"):
    """Reference: the unpipelined lowering (all-gather then one big dot)."""

    def body(xl, wl):
        xg = jax.lax.all_gather(xl, axis, axis=0, tiled=True)
        return jnp.einsum("sk,kn->sn", xg.astype(jnp.float32),
                          wl.astype(jnp.float32))

    return shard_map(body, mesh=mesh,
                     in_specs=(P(axis, None), P(None, axis)),
                     out_specs=P(None, axis))(x, w)
