"""distributed subpackage of the DSLOT-NN reproduction."""
