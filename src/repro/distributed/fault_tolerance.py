"""Fault-tolerant training driver: checkpoint/restart, failure injection,
straggler detection, elastic mesh downsize.

At 1000+ nodes the failure model is: some host dies mid-step (preemption,
ECC, network), the job controller notices via missed heartbeats, and the
fleet restarts on the surviving topology from the last committed checkpoint.
This module reproduces that control plane in-process:

* ``ResilientTrainer`` — wraps a train loop with periodic async checkpoints,
  catches injected ``NodeFailure``s, restores the last committed state
  (verifying integrity CRCs) and continues; on a topology change it rebuilds
  the mesh and **reshards** the restored state (elastic restart).
* ``StragglerMonitor`` — EWMA + p95 watchdog over per-step times with a
  pluggable clock; flags persistent outliers for re-dispatch (the action at
  scale is to evict the host; here the flag + policy decision are the
  testable artifact).
* ``HeartbeatTracker`` — deadline-based failure detector for the controller.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.checkpoint.checkpointer import Checkpointer


class NodeFailure(RuntimeError):
    """Injected/observed loss of a worker."""

    def __init__(self, msg: str, lost_nodes: int = 1):
        super().__init__(msg)
        self.lost_nodes = lost_nodes


@dataclass
class HeartbeatTracker:
    deadline_s: float = 10.0
    last_seen: dict = field(default_factory=dict)

    def beat(self, node: int, now: float) -> None:
        self.last_seen[node] = now

    def dead_nodes(self, now: float) -> list[int]:
        return [n for n, t in self.last_seen.items()
                if now - t > self.deadline_s]


class StragglerMonitor:
    """Flags ranks whose step time exceeds ``factor`` x the fleet p95."""

    def __init__(self, n_ranks: int, factor: float = 1.5,
                 patience: int = 3, ewma: float = 0.3):
        self.n = n_ranks
        self.factor = factor
        self.patience = patience
        self.ewma = ewma
        self.mean = np.zeros(n_ranks)
        self.strikes = np.zeros(n_ranks, np.int64)

    def observe(self, step_times: np.ndarray) -> list[int]:
        """step_times: (n_ranks,) seconds.  Returns ranks to re-dispatch."""
        self.mean = (1 - self.ewma) * self.mean + self.ewma * step_times
        p95 = np.percentile(self.mean, 95)
        slow = self.mean > self.factor * max(p95, 1e-9)
        self.strikes = np.where(slow, self.strikes + 1, 0)
        return [int(r) for r in np.nonzero(self.strikes >= self.patience)[0]]


@dataclass
class TrainerReport:
    steps_done: int
    restarts: int
    reshards: int
    losses: list
    flagged_stragglers: list


class ResilientTrainer:
    """Checkpointed, restartable step loop.

    ``make_mesh_and_step(n_lost)`` builds (mesh, state_shardings, step_fn)
    for the current surviving topology — called once at start and again after
    every failure (n_lost accumulates), which is where elastic downsizing
    happens.  ``inject`` maps step -> NodeFailure for tests.
    """

    def __init__(self, *, checkpointer: Checkpointer,
                 make_mesh_and_step: Callable,
                 ckpt_every: int = 10):
        self.ck = checkpointer
        self.make = make_mesh_and_step
        self.ckpt_every = ckpt_every

    def run(self, state, data_iter, n_steps: int,
            inject: dict | None = None) -> tuple[object, TrainerReport]:
        inject = inject or {}
        restarts = reshards = 0
        lost = 0
        losses: list[float] = []
        flagged: list[int] = []

        mesh, shardings, step_fn, place = self.make(lost)
        step = int(np.asarray(state.step))
        last_committed = step
        self.ck.save(step, state)

        while step < n_steps:
            try:
                if step in inject:
                    failure = inject.pop(step)
                    raise failure
                batch = data_iter(step)
                state, metrics = step_fn(state, place(batch))
                losses.append(float(np.asarray(metrics["loss"])))
                step += 1
                if step % self.ckpt_every == 0:
                    self.ck.wait()
                    self.ck.save_async(step, state)
                    last_committed = step
            except NodeFailure as e:
                restarts += 1
                lost += e.lost_nodes
                self.ck.wait()
                # rebuild on the surviving topology, restore, reshard
                mesh, shardings, step_fn, place = self.make(lost)
                reshards += 1 if e.lost_nodes else 0
                restore_step = self.ck.latest_step()
                state = self.ck.restore(restore_step, state, shardings)
                step = int(restore_step)
        self.ck.wait()
        return state, TrainerReport(steps_done=step, restarts=restarts,
                                    reshards=reshards, losses=losses,
                                    flagged_stragglers=flagged)
