"""Expert-parallel MoE dispatch via all_to_all (shard_map).

The pjit path in ``repro.models.moe`` lets GSPMD shard the expert einsum
(experts' d_ff over the model axis).  True expert parallelism instead places
``E / ep`` experts per device and routes tokens with two all_to_alls:

    tokens -> [a2a] -> expert-local FFN -> [a2a back] -> combine

which turns the expert weights' all-gather traffic into activation-sized
a2a traffic — the right trade when tokens-per-device << expert size (the
mixtral-8x22b regime).  Used as a §Perf alternative; numerical equivalence
with the dense-einsum path is tested on an 8-device CPU mesh.

This implementation keeps the capacity-slot layout of ``apply_moe``: after
the (T, K) -> (E, C, D) dispatch buffer is built locally, the E axis is
exchanged so each device holds its experts' slots for ALL source devices,
runs the FFN, and the inverse a2a returns outputs to token owners.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.mlp import _ACTS
from repro.models.moe import moe_capacity


def apply_moe_ep(p, x, cfg, mesh: Mesh, axis: str = "model"):
    """Expert-parallel MoE forward.  x: (B, S, D) sharded P((pod,data)...)
    on batch; experts sharded over ``axis``.  Requires E % mesh[axis] == 0.
    Returns (y, aux) like ``apply_moe``."""
    E, K = cfg.n_experts, cfg.top_k
    ep = mesh.shape[axis]
    assert E % ep == 0, (E, ep)
    act = _ACTS[cfg.act]

    def body(xl, router, up, gate, down):
        # xl: (Bl, S, D) tokens local to this device along batch;
        # up/gate/down: (E/ep, D, F) — this device's experts.
        Bl, S, D = xl.shape
        T = Bl * S
        C = moe_capacity(cfg, T)
        flat = xl.reshape(T, D)
        logits = jnp.einsum("td,de->te", flat.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

        me = jnp.mean(probs, axis=0)
        onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
        ce = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
        aux = E * jnp.sum(me * ce) / K

        flat_choice = onehot.reshape(T * K, E)
        ranks = jnp.cumsum(flat_choice, axis=0) - flat_choice
        rank = jnp.sum(ranks * flat_choice, axis=-1).reshape(T, K)
        keep = rank < C
        slot = expert_idx * C + jnp.minimum(rank, C - 1).astype(jnp.int32)

        buf = jnp.zeros((E * C, D), flat.dtype)
        contrib = jnp.where(keep[..., None], 1.0, 0.0).astype(flat.dtype)
        buf = buf.at[slot.reshape(-1)].add(
            (flat[:, None, :] * contrib).reshape(T * K, D))
        xb = buf.reshape(E, C, D)

        # ---- a2a: exchange the expert axis; gain a source-device axis.
        # (E, C, D) -> (ep, E/ep, C, D) -> a2a over ep -> each device holds
        # its E/ep experts x (ep sources) x C slots.
        xb = xb.reshape(ep, E // ep, C, D)
        xb = jax.lax.all_to_all(xb, axis, split_axis=0, concat_axis=0,
                                tiled=False)                 # (ep, E/ep, C, D)
        xb = jnp.moveaxis(xb, 0, 1).reshape(E // ep, ep * C, D)

        h = jnp.einsum("ecd,edf->ecf", xb, up)
        if cfg.glu:
            h = act(jnp.einsum("ecd,edf->ecf", xb, gate)) * h
        else:
            h = act(h)
        yb = jnp.einsum("ecf,efd->ecd", h, down)             # (E/ep, ep*C, D)

        # ---- inverse a2a back to token owners
        yb = jnp.moveaxis(yb.reshape(E // ep, ep, C, D), 1, 0)
        yb = jax.lax.all_to_all(yb, axis, split_axis=0, concat_axis=0,
                                tiled=False)
        yb = yb.reshape(E * C, D)

        gathered = yb[slot.reshape(-1)].reshape(T, K, D)
        w = (gate_vals * keep).astype(gathered.dtype)
        y = jnp.einsum("tkd,tk->td", gathered, w).reshape(Bl, S, D)
        return y, aux.astype(jnp.float32)[None]

    fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = fsdp if fsdp else None
    # outputs are replicated across the model axis by construction (every
    # model rank holds the same tokens); the static vma checker cannot prove
    # data-dependent replication, so it is disabled.
    try:
        sm = shard_map(body, mesh=mesh,
                       in_specs=(P(bspec), P(), P(axis), P(axis), P(axis)),
                       out_specs=(P(bspec), P(axis)), check_vma=False)
    except TypeError:                                  # older kwarg name
        sm = shard_map(body, mesh=mesh,
                       in_specs=(P(bspec), P(), P(axis), P(axis), P(axis)),
                       out_specs=(P(bspec), P(axis)), check_rep=False)
    y, aux = sm(x, p["router"], p["up"], p.get("gate", p["up"]), p["down"])
    return y, jnp.mean(aux)
