"""Expert-parallel MoE dispatch via all_to_all (shard_map).

The pjit path in ``repro.models.moe`` lets GSPMD shard the expert einsum
(experts' d_ff over the model axis).  True expert parallelism instead places
``E / ep`` experts per device and routes tokens with two all_to_alls:

    tokens -> [a2a] -> expert-local FFN -> [a2a back] -> combine

which turns the expert weights' all-gather traffic into activation-sized
a2a traffic — the right trade when tokens-per-device << expert size (the
mixtral-8x22b regime).  Used as a §Perf alternative; numerical equivalence
with the dense-einsum path is tested on an 8-device CPU mesh.

This implementation keeps the capacity-slot layout of ``apply_moe``: after
the (T, K) -> (E, C, D) dispatch buffer is built locally, the E axis is
exchanged so each device holds its experts' slots for ALL source devices,
runs the FFN, and the inverse a2a returns outputs to token owners.

Per-expert plane budgets (``expert_planes``): the DSLOT digit-serial idea
applied at expert granularity — each expert's input activations are
truncated to that expert's most significant ``expert_planes[e]`` digit
planes (MSDF order) before its FFN runs, so cold/degradable experts spend
fewer digit planes than hot ones.  The budget vector shards over ``axis``
with the expert weights (each device truncates only its own experts,
after the first a2a).  Budgets >= ``n_bits`` are EXACT no-ops, preserving
the dense-forward equivalence; budgets below truncate deterministically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.mlp import _ACTS
from repro.models.moe import moe_capacity


def _truncate_planes(xb, planes, n_bits):
    """Keep each local expert's top ``planes[e]`` MSDF digit planes of its
    (C, D) input slice.  ``planes >= n_bits`` rows pass through untouched
    (bit-exact): the where() below selects the raw input, so quantization
    round-off never leaks into full-budget experts."""
    qmax = float(2 ** (n_bits - 1) - 1)
    amax = jnp.maximum(jnp.max(jnp.abs(xb), axis=(1, 2)), 1e-12)  # (E/ep,)
    step = (amax / qmax)[:, None, None]
    q = jnp.clip(jnp.round(xb / step), -qmax, qmax).astype(jnp.int32)
    shift = jnp.clip(n_bits - planes, 0, n_bits).astype(jnp.int32)
    kept = jnp.right_shift(jnp.abs(q), shift[:, None, None])
    kept = jnp.left_shift(kept, shift[:, None, None])
    xq = (jnp.sign(q) * kept).astype(xb.dtype) * step
    return jnp.where((planes < n_bits)[:, None, None], xq, xb)


def apply_moe_ep(p, x, cfg, mesh: Mesh, axis: str = "model",
                 expert_planes=None, n_bits: int = 8):
    """Expert-parallel MoE forward.  x: (B, S, D) sharded P((pod,data)...)
    on batch; experts sharded over ``axis``.  Requires E % mesh[axis] == 0.
    Returns (y, aux) like ``apply_moe``.

    ``expert_planes``: optional (E,) i32 per-expert digit-plane budget
    (module docstring) — entries >= ``n_bits`` are exact no-ops.
    """
    E, K = cfg.n_experts, cfg.top_k
    ep = mesh.shape[axis]
    assert E % ep == 0, (E, ep)
    act = _ACTS[cfg.act]
    planes_all = (jnp.full((E,), n_bits, jnp.int32) if expert_planes is None
                  else jnp.asarray(expert_planes, jnp.int32))
    assert planes_all.shape == (E,), planes_all.shape

    def body(xl, router, up, gate, down, planes):
        # xl: (Bl, S, D) tokens local to this device along batch;
        # up/gate/down: (E/ep, D, F) — this device's experts.
        Bl, S, D = xl.shape
        T = Bl * S
        C = moe_capacity(cfg, T)
        flat = xl.reshape(T, D)
        logits = jnp.einsum("td,de->te", flat.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

        me = jnp.mean(probs, axis=0)
        onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
        ce = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
        aux = E * jnp.sum(me * ce) / K

        flat_choice = onehot.reshape(T * K, E)
        ranks = jnp.cumsum(flat_choice, axis=0) - flat_choice
        rank = jnp.sum(ranks * flat_choice, axis=-1).reshape(T, K)
        keep = rank < C
        slot = expert_idx * C + jnp.minimum(rank, C - 1).astype(jnp.int32)

        buf = jnp.zeros((E * C, D), flat.dtype)
        contrib = jnp.where(keep[..., None], 1.0, 0.0).astype(flat.dtype)
        buf = buf.at[slot.reshape(-1)].add(
            (flat[:, None, :] * contrib).reshape(T * K, D))
        xb = buf.reshape(E, C, D)

        # ---- a2a: exchange the expert axis; gain a source-device axis.
        # (E, C, D) -> (ep, E/ep, C, D) -> a2a over ep -> each device holds
        # its E/ep experts x (ep sources) x C slots.
        xb = xb.reshape(ep, E // ep, C, D)
        xb = jax.lax.all_to_all(xb, axis, split_axis=0, concat_axis=0,
                                tiled=False)                 # (ep, E/ep, C, D)
        xb = jnp.moveaxis(xb, 0, 1).reshape(E // ep, ep * C, D)

        # per-expert digit-plane budget: truncate this device's experts'
        # inputs to their granted MSDF planes (exact no-op at full budget)
        xb = _truncate_planes(xb, planes, n_bits)

        h = jnp.einsum("ecd,edf->ecf", xb, up)
        if cfg.glu:
            h = act(jnp.einsum("ecd,edf->ecf", xb, gate)) * h
        else:
            h = act(h)
        yb = jnp.einsum("ecf,efd->ecd", h, down)             # (E/ep, ep*C, D)

        # ---- inverse a2a back to token owners
        yb = jnp.moveaxis(yb.reshape(E // ep, ep, C, D), 1, 0)
        yb = jax.lax.all_to_all(yb, axis, split_axis=0, concat_axis=0,
                                tiled=False)
        yb = yb.reshape(E * C, D)

        gathered = yb[slot.reshape(-1)].reshape(T, K, D)
        w = (gate_vals * keep).astype(gathered.dtype)
        y = jnp.einsum("tkd,tk->td", gathered, w).reshape(Bl, S, D)
        return y, aux.astype(jnp.float32)[None]

    fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = fsdp if fsdp else None
    # outputs are replicated across the model axis by construction (every
    # model rank holds the same tokens); the static vma checker cannot prove
    # data-dependent replication, so it is disabled.
    in_specs = (P(bspec), P(), P(axis), P(axis), P(axis), P(axis))
    try:
        sm = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=(P(bspec), P(axis)), check_vma=False)
    except TypeError:                                  # older kwarg name
        sm = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=(P(bspec), P(axis)), check_rep=False)
    y, aux = sm(x, p["router"], p["up"], p.get("gate", p["up"]), p["down"],
                planes_all)
    return y, jnp.mean(aux)
