"""Gradient compression with error feedback (for cross-pod all-reduce).

At 2+ pods the gradient all-reduce crosses the slow inter-pod links
(DCN/optical, far below ICI bandwidth), so the pod axis gets a compressed
reduction:

* ``int8_compress`` — per-tensor symmetric int8 quantization (8x smaller
  payload) with error-feedback residual so quantization noise is unbiased
  over steps (Seide et al. / 1-bit Adam lineage).
* ``topk_compress`` — magnitude top-k sparsification (k as a fraction),
  error feedback accumulates the dropped mass.

Both return (payload, state) and compose with any reduction: the payloads
are linear, so all-reduce(payload) then decompress ≈ all-reduce(grads).
Convergence under compression is covered by tests/test_distributed.py.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    """Per-tensor error-feedback residuals (same pytree as grads)."""
    residual: dict


def init_ef_state(grads) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads))


# ----------------------------------------------------------------- int8

def int8_compress(grads, ef: EFState):
    """-> ((q int8 tree, scale tree), new_ef).  q*scale ~= g + residual."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        err = x - q.astype(jnp.float32) * scale
        return q, scale, err

    out = jax.tree.map(one, grads, ef.residual)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    scale = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return (q, scale), EFState(residual=err)


def int8_decompress(payload):
    q, scale = payload
    return jax.tree.map(lambda qq, s: qq.astype(jnp.float32) * s, q, scale)


# ----------------------------------------------------------------- top-k

def topk_compress(grads, ef: EFState, frac: float = 0.01):
    """Keep the top `frac` fraction of entries by magnitude (per tensor);
    -> ((values, indices) tree, new_ef)."""
    def one(g, r):
        x = (g.astype(jnp.float32) + r).reshape(-1)
        k = max(1, int(x.size * frac))
        vals, idx = jax.lax.top_k(jnp.abs(x), k)
        kept = x[idx]
        err = x.at[idx].set(0.0).reshape(g.shape)
        return kept, idx, err

    out = jax.tree.map(one, grads, ef.residual)
    tup = lambda t: isinstance(t, tuple)
    vals = jax.tree.map(lambda t: t[0], out, is_leaf=tup)
    idx = jax.tree.map(lambda t: t[1], out, is_leaf=tup)
    err = jax.tree.map(lambda t: t[2], out, is_leaf=tup)
    return (vals, idx), EFState(residual=err)


def topk_decompress(payload, like):
    vals, idx = payload

    def one(v, i, g):
        flat = jnp.zeros(g.size, jnp.float32).at[i].set(v)
        return flat.reshape(g.shape)

    return jax.tree.map(one, vals, idx, like)


def compressed_ratio(grads, payload) -> float:
    """Payload bytes / raw fp32 bytes — the wire saving."""
    raw = sum(g.size * 4 for g in jax.tree.leaves(grads))
    comp = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(payload))
    return comp / max(raw, 1)
