"""Synthetic MNIST-like digit glyphs (offline container — no downloads).

Procedurally renders 28x28 digit glyphs per class with stroke jitter,
translation and pixel noise.  Used to (a) train the paper's bias-free CNN
(Fig. 6) and (b) reproduce the per-class negative-activation / cycle-saving
statistics (Figs. 8-9) *qualitatively* — the exact percentages depend on the
true MNIST distribution (caveat recorded in EXPERIMENTS.md).
"""

from __future__ import annotations

import numpy as np

_SEGS = {
    # seven-segment-ish strokes in a 20x20 box: (r0, c0, r1, c1)
    0: [(0, 2, 0, 14), (18, 2, 18, 14), (0, 2, 18, 2), (0, 14, 18, 14)],
    1: [(0, 8, 18, 8), (0, 8, 4, 4)],
    2: [(0, 2, 0, 14), (0, 14, 9, 14), (9, 2, 9, 14), (9, 2, 18, 2),
        (18, 2, 18, 14)],
    3: [(0, 2, 0, 14), (9, 4, 9, 14), (18, 2, 18, 14), (0, 14, 18, 14)],
    4: [(0, 2, 9, 2), (9, 2, 9, 14), (0, 14, 18, 14)],
    5: [(0, 2, 0, 14), (0, 2, 9, 2), (9, 2, 9, 14), (9, 14, 18, 14),
        (18, 2, 18, 14)],
    6: [(0, 2, 0, 14), (0, 2, 18, 2), (9, 2, 9, 14), (9, 14, 18, 14),
        (18, 2, 18, 14)],
    7: [(0, 2, 0, 14), (0, 14, 18, 6)],
    8: [(0, 2, 0, 14), (9, 2, 9, 14), (18, 2, 18, 14), (0, 2, 18, 2),
        (0, 14, 18, 14)],
    9: [(0, 2, 0, 14), (0, 2, 9, 2), (9, 2, 9, 14), (0, 14, 18, 14),
        (18, 2, 18, 14)],
}


def _draw(digit: int, rng: np.random.Generator) -> np.ndarray:
    img = np.zeros((28, 28), np.float32)
    dr = rng.integers(1, 7)
    dc = rng.integers(1, 7)
    thick = rng.integers(1, 3)
    for (r0, c0, r1, c1) in _SEGS[digit]:
        n = max(abs(r1 - r0), abs(c1 - c0)) + 1
        rs = np.linspace(r0, r1, n).round().astype(int) + dr
        cs = np.linspace(c0, c1, n).round().astype(int) + dc
        jr = rng.integers(-1, 2)
        jc = rng.integers(-1, 2)
        for t in range(thick):
            r = np.clip(rs + jr + t, 0, 27)
            c = np.clip(cs + jc, 0, 27)
            img[r, c] = 1.0
    img += rng.normal(0, 0.08, img.shape).astype(np.float32)
    img = np.clip(img, 0.0, 1.0)
    return img


def synth_mnist(n_per_class: int, seed: int = 0
                ) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images (N, 28, 28) float32 in [0,1], labels (N,) int32)."""
    rng = np.random.default_rng(seed)
    imgs, labels = [], []
    for d in range(10):
        for _ in range(n_per_class):
            imgs.append(_draw(d, rng))
            labels.append(d)
    order = rng.permutation(len(imgs))
    return (np.stack(imgs)[order], np.asarray(labels, np.int32)[order])
