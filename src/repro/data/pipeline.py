"""Deterministic sharded data pipeline (no external datasets in-container).

A "virtual dataset" derives every token from a counter-mode hash of
(seed, sample, position): reproducible across restarts, sharded by host
without coordination (each host materializes only its slice — exactly how a
1000-node deployment would stream from a blob store), and cheap enough to
generate on the fly.  Structure is injected (short Markov motifs) so losses
actually decrease during the example training runs.

``make_global_batch`` assembles a jax.Array on any mesh via
``make_array_from_callback`` — each process provides only the shards it owns.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _hash_tokens(seed: int, sample_idx: np.ndarray, seq_len: int,
                 vocab: int) -> np.ndarray:
    """counter-mode splitmix64 -> tokens (n, seq_len) int32, with motif
    structure: token_t depends on token_{t-1} for learnability."""
    n = sample_idx.shape[0]
    pos = np.arange(seq_len, dtype=np.uint64)[None, :]
    x = (sample_idx.astype(np.uint64)[:, None] * np.uint64(0x9E3779B97F4A7C15)
         + pos * np.uint64(0xBF58476D1CE4E5B9) + np.uint64(seed))
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    x = x ^ (x >> np.uint64(31))
    raw = (x % np.uint64(vocab)).astype(np.int64)
    # motif: every odd position repeats an affine function of its predecessor
    out = raw.copy()
    out[:, 1::2] = (out[:, 0::2][:, : out[:, 1::2].shape[1]] * 7 + 13) % vocab
    return out.astype(np.int32)


class TokenPipeline:
    """Iterator of training batches shaped (M, mb, S) for grad accumulation."""

    def __init__(self, *, vocab: int, seq_len: int, global_batch: int,
                 microbatches: int = 1, seed: int = 0):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.microbatches = microbatches
        self.seed = seed
        self._cursor = 0

    def next_host_batch(self) -> dict:
        idx = np.arange(self._cursor, self._cursor + self.global_batch)
        self._cursor += self.global_batch
        toks = _hash_tokens(self.seed, idx, self.seq_len + 1, self.vocab)
        M, B = self.microbatches, self.global_batch // self.microbatches
        return {
            "tokens": toks[:, :-1].reshape(M, B, self.seq_len),
            "labels": toks[:, 1:].reshape(M, B, self.seq_len),
        }

    def state(self) -> dict:
        return {"cursor": self._cursor, "seed": self.seed}

    def restore(self, st: dict) -> None:
        self._cursor = int(st["cursor"])
        self.seed = int(st["seed"])


def make_global_batch(mesh: Mesh, host_batch: dict, shardings) -> dict:
    """Assemble global jax.Arrays from per-host numpy (single-process here;
    in multi-process each host passes only its slice via the callback)."""

    def one(arr, sh):
        arr = np.asarray(arr)
        return jax.make_array_from_callback(
            arr.shape, sh, lambda idx: arr[idx])

    return jax.tree.map(one, host_batch, shardings)
