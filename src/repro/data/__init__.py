"""data subpackage of the DSLOT-NN reproduction."""
